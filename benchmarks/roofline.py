"""Roofline table generator: reads experiments/dryrun/*.json (written by
launch/dryrun.py) and emits the per-(arch x shape x mesh) three-term
roofline table for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_records(mesh: str = "single") -> list[dict]:
    recs = []
    for f in sorted(DRYRUN_DIR.glob(f"*_{mesh}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def fmt_table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | roofline frac | 6ND/HLO | status |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok":
            rows.append(
                f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - | "
                f"{r['status']}: {r.get('reason', r.get('error',''))[:40]} |")
            continue
        t = r["roofline"]
        ratio = r.get("useful_compute_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"{t['dominant'].replace('_s','')} | {t['roofline_fraction']:.3f} | "
            f"{ratio:.3f} | ok |" if ratio is not None else
            f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - | ok |")
    return "\n".join(rows)


def run(full: bool = False, out: dict | None = None) -> None:
    for mesh in ("single", "multi"):
        recs = load_records(mesh)
        if not recs:
            print(f"roofline/{mesh},0.00,no dry-run artifacts (run "
                  f"python -m repro.launch.dryrun --all)")
            continue
        ok = [r for r in recs if r["status"] == "ok"]
        skipped = [r for r in recs if r["status"] == "skipped"]
        failed = [r for r in recs if r["status"] not in ("ok", "skipped")]
        print(f"roofline/{mesh},0.00,cells={len(recs)};ok={len(ok)};"
              f"skipped={len(skipped)};failed={len(failed)}")
        if out is not None:
            out[mesh] = {"table": fmt_table(recs), "n_ok": len(ok),
                         "n_failed": len(failed)}


if __name__ == "__main__":
    run()
    print()
    print(fmt_table(load_records("single")))
