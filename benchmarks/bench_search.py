"""Search efficiency (paper Fig. 21): average distance computations,
comparisons and wall time for 100 kNN queries at k in {5,10,15,20,50,100},
per heuristic vs the BCCF baseline, plus recall@k vs exact brute force.

Runs through the ``repro.api.OverlapIndex`` facade — one index object per
(dataset, method), one cached SearchPlan per (k, mode); the warm pass and
the timed pass hit the same compiled executor.

``--smoke`` shrinks datasets and the k sweep for CI; the artifact
(BENCH_search.json) is written either way so the perf trajectory stays
diffable across commits.

``--shards N`` runs the sweep under the sharded device layout (forest
bucket rows + delta buffers split over N devices, one shard_map island per
search) and HARD-GATES on divergence: every sharded result is compared
bitwise against the single-device layout on the same forest — any mismatch
exits non-zero.  On CPU the flag also forces a host mesh by setting
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax
initializes, so ``python -m benchmarks.bench_search --smoke --shards 4``
works on a laptop/CI runner with no extra environment.

``--route N`` runs the sweep under the ROUTED layout (the multi-host
routing tier over the same N-shard islands: replicated routing table,
per-query host pruning, cost-model fanout decision) and hard-gates every
result bitwise against BOTH the plain sharded fan-all layout and the
single-device layout.  Each record additionally carries the routing
tier's decision counts (targeted/fan-all batches, eligible and pruned
host totals, estimated cross-host bytes under either fanout), so the
artifact shows the work the router removed, per (dataset, method, k).
"""
from __future__ import annotations

import os
import sys

# Must run before ANY jax import (jax reads XLA_FLAGS once at init): give
# the process enough host devices for the requested shard/host count.
for _flag in ("--shards", "--route"):
    if _flag in sys.argv:
        _n = int(sys.argv[sys.argv.index(_flag) + 1])
        _flags = os.environ.get("XLA_FLAGS", "")
        if _n > 1 and "xla_force_host_platform_device_count" not in _flags:
            os.environ["XLA_FLAGS"] = (
                f"{_flags} --xla_force_host_platform_device_count={_n}".strip()
            )

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    METHODS,
    baseline_config,
    emit,
    facade_config,
    load_datasets,
    record,
    write_artifact,
)
from repro.api import OverlapIndex
from repro.core import knn_exact

K_VALUES = (5, 10, 15, 20, 50, 100)
K_VALUES_SMOKE = (5, 20)
N_QUERIES = 100


def _queries(x: np.ndarray, n: int, seed: int = 7) -> np.ndarray:
    g = np.random.default_rng(seed)
    idx = g.choice(len(x), n, replace=False)
    return (x[idx] + 0.05 * x.std() * g.normal(size=(n, x.shape[1]))).astype(np.float32)


def _run_one(ix: OverlapIndex, q, k, mode):
    ix.search(q, k=k, mode=mode)  # warm: plan + shape specialization
    t0 = time.perf_counter()
    res = ix.search(q, k=k, mode=mode)
    dt = time.perf_counter() - t0
    return res, dt


def _router_counts(ix: OverlapIndex) -> dict:
    """Flat cumulative snapshot of metrics()['router'] (zeros when absent)
    so per-(k, mode) deltas can be attached to bench records."""
    rm = ix.metrics().get("router") or {}
    fan = rm.get("fanout") or {}
    eb = rm.get("est_bytes") or {}
    return dict(
        route_queries=int(rm.get("queries", 0)),
        route_eligible=int(rm.get("eligible_hosts", 0)),
        route_pruned=int(rm.get("pruned_hosts", 0)),
        route_targeted=int(fan.get("targeted", 0)),
        route_all=int(fan.get("all", 0)),
        route_bytes_targeted=float(eb.get("targeted", 0.0)),
        route_bytes_all=float(eb.get("all", 0.0)),
    )


def run(
    full: bool = False,
    out: dict | None = None,
    *,
    kernel: bool = True,
    quantize: bool = False,
    smoke: bool = False,
    shards: int = 1,
    route: int = 0,
    obs: bool = True,
) -> None:
    """``kernel`` routes all search distances through the kernels/ops
    dispatch layer (fused Pallas bucket scan on TPU); ``quantize`` stores
    bucket members int8 on device.  Recall is reported either way, so the
    kernelized path's exactness (mode='all' vs brute force) is visible.

    ``shards > 1`` runs the sweep under the sharded layout and compares
    every result bitwise against a single-device index built over the same
    dataset (builds are deterministic, so the forests are identical) —
    divergence is a hard failure, not a warning.

    ``route > 1`` runs the sweep under the routed layout (routing tier
    over ``route`` shard islands) instead, gating bitwise against BOTH the
    fan-all sharded layout and the single-device layout, and attaches the
    router's per-sweep decision counts to every record.  Mutually
    exclusive with ``shards > 1``.
    """
    if route > 1 and shards > 1:
        raise SystemExit("--route and --shards are mutually exclusive")
    routed = route > 1
    n_hosts = route if routed else shards
    k_values = K_VALUES_SMOKE if smoke else K_VALUES
    diverged: list[str] = []
    for ds in load_datasets(full, smoke=smoke):
        q = _queries(ds.x, N_QUERIES)
        de, ie = knn_exact(jnp.asarray(ds.x), jnp.asarray(q), k=max(k_values))
        ie = np.asarray(ie)
        indexes = {
            method: OverlapIndex.build(
                ds.x, facade_config(
                    ds, method, shards=n_hosts, route=routed, obs=obs,
                    kernel=kernel, quantize=quantize,
                )
            )
            for method in METHODS
        }
        indexes["bccf"] = OverlapIndex.baseline(
            ds.x, baseline_config(
                ds, shards=n_hosts, route=routed, obs=obs, kernel=kernel,
                quantize=quantize,
            )
        )
        refs: dict = {}
        refs_fanall: dict = {}
        if n_hosts > 1:
            # single-device references for the bitwise divergence gate
            refs = {
                method: OverlapIndex.build(
                    ds.x, facade_config(
                        ds, method, kernel=kernel, quantize=quantize
                    )
                )
                for method in METHODS
            }
            refs["bccf"] = OverlapIndex.baseline(
                ds.x, baseline_config(ds, kernel=kernel, quantize=quantize)
            )
        if routed:
            # fan-all references: the plain sharded layout on the same mesh
            refs_fanall = {
                method: OverlapIndex.build(
                    ds.x, facade_config(
                        ds, method, shards=n_hosts, kernel=kernel,
                        quantize=quantize,
                    )
                )
                for method in METHODS
            }
            refs_fanall["bccf"] = OverlapIndex.baseline(
                ds.x, baseline_config(
                    ds, shards=n_hosts, kernel=kernel, quantize=quantize
                )
            )
        for method, ix in indexes.items():
            mode = "all" if method == "bccf" else "forest"
            for k in k_values:
                r0 = _router_counts(ix) if routed else None
                res, dt = _run_one(ix, q, k, mode)
                stats = res.stats
                route_fields = {}
                if routed:
                    r1 = _router_counts(ix)
                    route_fields = {key: r1[key] - r0[key] for key in r1}
                if n_hosts > 1:
                    ref = refs[method].search(q, k=k, mode=mode)
                    if not (
                        np.array_equal(res.dists, ref.dists)
                        and np.array_equal(res.ids, ref.ids)
                    ):
                        diverged.append(f"{ds.name}/{method}/k{k}:single")
                if routed:
                    ref = refs_fanall[method].search(q, k=k, mode=mode)
                    if not (
                        np.array_equal(res.dists, ref.dists)
                        and np.array_equal(res.ids, ref.ids)
                    ):
                        diverged.append(f"{ds.name}/{method}/k{k}:fanall")
                recall = float(np.mean([
                    len(set(res.ids[i].tolist()) & set(ie[i, :k].tolist())) / k
                    for i in range(len(q))
                ]))
                derived = (
                    f"dataset={ds.name};method={method};k={k};"
                    f"dist={stats['distances'].mean():.0f};"
                    f"bound_dist={stats['bound_distances'].mean():.0f};"
                    f"cmp={stats['comparisons'].mean():.0f};"
                    f"buckets={stats['buckets_visited'].mean():.1f};"
                    f"recall={recall:.3f};time_ms={dt*1e3/len(q):.3f}"
                )
                if routed:
                    derived += (
                        f";route_targeted={route_fields['route_targeted']};"
                        f"route_all={route_fields['route_all']};"
                        f"route_pruned={route_fields['route_pruned']}"
                    )
                emit(f"search/{ds.name}/{method}/k{k}", dt * 1e6 / len(q), derived)
                record(
                    "search", f"{ds.name}/{method}/k{k}",
                    dataset=ds.name, method=method, k=k, shards=n_hosts,
                    routed=routed,
                    dist=float(stats["distances"].mean()),
                    bound_dist=float(stats["bound_distances"].mean()),
                    cmp=float(stats["comparisons"].mean()),
                    buckets=float(stats["buckets_visited"].mean()),
                    recall=recall,
                    us_per_query=dt * 1e6 / len(q),
                    **route_fields,
                )
                if out is not None:
                    out[f"{ds.name}/{method}/k{k}"] = {
                        "dist": float(stats["distances"].mean()),
                        "cmp": float(stats["comparisons"].mean()),
                        "recall": recall,
                        "ms_per_query": dt * 1e3 / len(q),
                    }
            emit(f"search/{ds.name}/{method}/plans", 0.0,
                 f"plan_cache={ix.plans.stats()}")
    write_artifact("search", meta=dict(
        full=full, smoke=smoke, kernel=kernel, quantize=quantize,
        shards=n_hosts, route=route, obs=obs,
    ))
    if diverged:
        layout = "routed" if routed else "sharded"
        raise SystemExit(
            f"{layout} search diverged from reference on {len(diverged)} "
            f"configurations: {', '.join(diverged)}"
        )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--no-kernel", action="store_true",
                    help="bypass kernels/ops dispatch (pure-jnp reference path)")
    ap.add_argument("--quantize", action="store_true",
                    help="int8 bucket member storage (device_forest knob)")
    ap.add_argument("--shards", type=int, default=1,
                    help="run under the sharded device layout (N devices on "
                    "the 'model' axis) and hard-gate bitwise vs single")
    ap.add_argument("--route", type=int, default=0,
                    help="run under the ROUTED layout (routing tier over N "
                    "shard islands) and hard-gate bitwise vs fan-all AND "
                    "single; records carry routing decision counts")
    ap.add_argument("--no-obs", action="store_true",
                    help="disable the telemetry registry (repro.obs) — for "
                    "measuring the metrics layer's own overhead")
    a = ap.parse_args()
    run(full=a.full, kernel=not a.no_kernel, quantize=a.quantize,
        smoke=a.smoke, shards=a.shards, route=a.route, obs=not a.no_obs)
