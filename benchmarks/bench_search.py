"""Search efficiency (paper Fig. 21): average distance computations,
comparisons and wall time for 100 kNN queries at k in {5,10,15,20,50,100},
per heuristic vs the BCCF baseline, plus recall@k vs exact brute force.

Runs through the ``repro.api.OverlapIndex`` facade — one index object per
(dataset, method), one cached SearchPlan per (k, mode); the warm pass and
the timed pass hit the same compiled executor.

``--smoke`` shrinks datasets and the k sweep for CI; the artifact
(BENCH_search.json) is written either way so the perf trajectory stays
diffable across commits.

``--shards N`` runs the sweep under the sharded device layout (forest
bucket rows + delta buffers split over N devices, one shard_map island per
search) and HARD-GATES on divergence: every sharded result is compared
bitwise against the single-device layout on the same forest — any mismatch
exits non-zero.  On CPU the flag also forces a host mesh by setting
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax
initializes, so ``python -m benchmarks.bench_search --smoke --shards 4``
works on a laptop/CI runner with no extra environment.
"""
from __future__ import annotations

import os
import sys

# Must run before ANY jax import (jax reads XLA_FLAGS once at init): give
# the process enough host devices for the requested shard count.
if "--shards" in sys.argv:
    _n = int(sys.argv[sys.argv.index("--shards") + 1])
    _flags = os.environ.get("XLA_FLAGS", "")
    if _n > 1 and "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            f"{_flags} --xla_force_host_platform_device_count={_n}".strip()
        )

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    METHODS,
    baseline_config,
    emit,
    facade_config,
    load_datasets,
    record,
    write_artifact,
)
from repro.api import OverlapIndex
from repro.core import knn_exact

K_VALUES = (5, 10, 15, 20, 50, 100)
K_VALUES_SMOKE = (5, 20)
N_QUERIES = 100


def _queries(x: np.ndarray, n: int, seed: int = 7) -> np.ndarray:
    g = np.random.default_rng(seed)
    idx = g.choice(len(x), n, replace=False)
    return (x[idx] + 0.05 * x.std() * g.normal(size=(n, x.shape[1]))).astype(np.float32)


def _run_one(ix: OverlapIndex, q, k, mode):
    ix.search(q, k=k, mode=mode)  # warm: plan + shape specialization
    t0 = time.perf_counter()
    res = ix.search(q, k=k, mode=mode)
    dt = time.perf_counter() - t0
    return res, dt


def run(
    full: bool = False,
    out: dict | None = None,
    *,
    kernel: bool = True,
    quantize: bool = False,
    smoke: bool = False,
    shards: int = 1,
    obs: bool = True,
) -> None:
    """``kernel`` routes all search distances through the kernels/ops
    dispatch layer (fused Pallas bucket scan on TPU); ``quantize`` stores
    bucket members int8 on device.  Recall is reported either way, so the
    kernelized path's exactness (mode='all' vs brute force) is visible.

    ``shards > 1`` runs the sweep under the sharded layout and compares
    every result bitwise against a single-device index built over the same
    dataset (builds are deterministic, so the forests are identical) —
    divergence is a hard failure, not a warning.
    """
    k_values = K_VALUES_SMOKE if smoke else K_VALUES
    diverged: list[str] = []
    for ds in load_datasets(full, smoke=smoke):
        q = _queries(ds.x, N_QUERIES)
        de, ie = knn_exact(jnp.asarray(ds.x), jnp.asarray(q), k=max(k_values))
        ie = np.asarray(ie)
        indexes = {
            method: OverlapIndex.build(
                ds.x, facade_config(
                    ds, method, shards=shards, obs=obs, kernel=kernel,
                    quantize=quantize,
                )
            )
            for method in METHODS
        }
        indexes["bccf"] = OverlapIndex.baseline(
            ds.x, baseline_config(
                ds, shards=shards, obs=obs, kernel=kernel, quantize=quantize
            )
        )
        refs = {}
        if shards > 1:
            # single-device references for the bitwise divergence gate
            refs = {
                method: OverlapIndex.build(
                    ds.x, facade_config(
                        ds, method, kernel=kernel, quantize=quantize
                    )
                )
                for method in METHODS
            }
            refs["bccf"] = OverlapIndex.baseline(
                ds.x, baseline_config(ds, kernel=kernel, quantize=quantize)
            )
        for method, ix in indexes.items():
            mode = "all" if method == "bccf" else "forest"
            for k in k_values:
                res, dt = _run_one(ix, q, k, mode)
                stats = res.stats
                if shards > 1:
                    ref = refs[method].search(q, k=k, mode=mode)
                    if not (
                        np.array_equal(res.dists, ref.dists)
                        and np.array_equal(res.ids, ref.ids)
                    ):
                        diverged.append(f"{ds.name}/{method}/k{k}")
                recall = float(np.mean([
                    len(set(res.ids[i].tolist()) & set(ie[i, :k].tolist())) / k
                    for i in range(len(q))
                ]))
                derived = (
                    f"dataset={ds.name};method={method};k={k};"
                    f"dist={stats['distances'].mean():.0f};"
                    f"bound_dist={stats['bound_distances'].mean():.0f};"
                    f"cmp={stats['comparisons'].mean():.0f};"
                    f"buckets={stats['buckets_visited'].mean():.1f};"
                    f"recall={recall:.3f};time_ms={dt*1e3/len(q):.3f}"
                )
                emit(f"search/{ds.name}/{method}/k{k}", dt * 1e6 / len(q), derived)
                record(
                    "search", f"{ds.name}/{method}/k{k}",
                    dataset=ds.name, method=method, k=k, shards=shards,
                    dist=float(stats["distances"].mean()),
                    bound_dist=float(stats["bound_distances"].mean()),
                    cmp=float(stats["comparisons"].mean()),
                    buckets=float(stats["buckets_visited"].mean()),
                    recall=recall,
                    us_per_query=dt * 1e6 / len(q),
                )
                if out is not None:
                    out[f"{ds.name}/{method}/k{k}"] = {
                        "dist": float(stats["distances"].mean()),
                        "cmp": float(stats["comparisons"].mean()),
                        "recall": recall,
                        "ms_per_query": dt * 1e3 / len(q),
                    }
            emit(f"search/{ds.name}/{method}/plans", 0.0,
                 f"plan_cache={ix.plans.stats()}")
    write_artifact("search", meta=dict(
        full=full, smoke=smoke, kernel=kernel, quantize=quantize,
        shards=shards, obs=obs,
    ))
    if diverged:
        raise SystemExit(
            f"sharded search diverged from single-device on {len(diverged)} "
            f"configurations: {', '.join(diverged)}"
        )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--no-kernel", action="store_true",
                    help="bypass kernels/ops dispatch (pure-jnp reference path)")
    ap.add_argument("--quantize", action="store_true",
                    help="int8 bucket member storage (device_forest knob)")
    ap.add_argument("--shards", type=int, default=1,
                    help="run under the sharded device layout (N devices on "
                    "the 'model' axis) and hard-gate bitwise vs single")
    ap.add_argument("--no-obs", action="store_true",
                    help="disable the telemetry registry (repro.obs) — for "
                    "measuring the metrics layer's own overhead")
    a = ap.parse_args()
    run(full=a.full, kernel=not a.no_kernel, quantize=a.quantize,
        smoke=a.smoke, shards=a.shards, obs=not a.no_obs)
