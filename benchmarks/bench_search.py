"""Search efficiency (paper Fig. 21): average distance computations,
comparisons and wall time for 100 kNN queries at k in {5,10,15,20,50,100},
per heuristic vs the BCCF baseline, plus recall@k vs exact brute force."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    METHODS,
    emit,
    index_config,
    load_datasets,
    record,
    write_artifact,
)
from repro.core import build_baseline, build_index, knn_exact, knn_search_host

K_VALUES = (5, 10, 15, 20, 50, 100)
N_QUERIES = 100


def _queries(x: np.ndarray, n: int, seed: int = 7) -> np.ndarray:
    g = np.random.default_rng(seed)
    idx = g.choice(len(x), n, replace=False)
    return (x[idx] + 0.05 * x.std() * g.normal(size=(n, x.shape[1]))).astype(np.float32)


def _run_one(forest, q, k, mode, kernel=True, quantize=False):
    # warm compile
    knn_search_host(forest, q[:2], k=k, mode=mode, kernel=kernel, quantize=quantize)
    t0 = time.perf_counter()
    d, ids, stats = knn_search_host(
        forest, q, k=k, mode=mode, kernel=kernel, quantize=quantize
    )
    dt = time.perf_counter() - t0
    return d, ids, stats, dt


def run(
    full: bool = False,
    out: dict | None = None,
    *,
    kernel: bool = True,
    quantize: bool = False,
) -> None:
    """``kernel`` routes all search distances through the kernels/ops
    dispatch layer (fused Pallas bucket scan on TPU); ``quantize`` stores
    bucket members int8 on device.  Recall is reported either way, so the
    kernelized path's exactness (mode='all' vs brute force) is visible."""
    for ds in load_datasets(full):
        q = _queries(ds.x, N_QUERIES)
        de, ie = knn_exact(jnp.asarray(ds.x), jnp.asarray(q), k=max(K_VALUES))
        ie = np.asarray(ie)
        forests = {}
        for method in METHODS:
            forests[method], _ = build_index(ds.x, index_config(ds, method))
        forests["bccf"], _ = build_baseline(ds.x, index_config(ds, "vbm"))
        for method, forest in forests.items():
            mode = "all" if method == "bccf" else "forest"
            for k in K_VALUES:
                d, ids, stats, dt = _run_one(forest, q, k, mode, kernel, quantize)
                recall = float(np.mean([
                    len(set(ids[i].tolist()) & set(ie[i, :k].tolist())) / k
                    for i in range(len(q))
                ]))
                derived = (
                    f"dataset={ds.name};method={method};k={k};"
                    f"dist={stats['distances'].mean():.0f};"
                    f"bound_dist={stats['bound_distances'].mean():.0f};"
                    f"cmp={stats['comparisons'].mean():.0f};"
                    f"buckets={stats['buckets_visited'].mean():.1f};"
                    f"recall={recall:.3f};time_ms={dt*1e3/len(q):.3f}"
                )
                emit(f"search/{ds.name}/{method}/k{k}", dt * 1e6 / len(q), derived)
                record(
                    "search", f"{ds.name}/{method}/k{k}",
                    dataset=ds.name, method=method, k=k,
                    dist=float(stats["distances"].mean()),
                    bound_dist=float(stats["bound_distances"].mean()),
                    cmp=float(stats["comparisons"].mean()),
                    buckets=float(stats["buckets_visited"].mean()),
                    recall=recall,
                    us_per_query=dt * 1e6 / len(q),
                )
                if out is not None:
                    out[f"{ds.name}/{method}/k{k}"] = {
                        "dist": float(stats["distances"].mean()),
                        "cmp": float(stats["comparisons"].mean()),
                        "recall": recall,
                        "ms_per_query": dt * 1e3 / len(q),
                    }
    write_artifact("search", meta=dict(full=full, kernel=kernel, quantize=quantize))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--no-kernel", action="store_true",
                    help="bypass kernels/ops dispatch (pure-jnp reference path)")
    ap.add_argument("--quantize", action="store_true",
                    help="int8 bucket member storage (device_forest knob)")
    a = ap.parse_args()
    run(full=a.full, kernel=not a.no_kernel, quantize=a.quantize)
