"""Structure evaluation (paper Figs. 6-19): bucket-size distributions,
nodes per level, internal/leaf counts and tree heights, per heuristic and
dataset."""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import METHODS, emit, facade_config, load_datasets
from repro.api import OverlapIndex


def run(full: bool = False, out: dict | None = None) -> None:
    for ds in load_datasets(full):
        for method in METHODS:
            t0 = time.perf_counter()
            ix = OverlapIndex.build(ds.x, facade_config(ds, method))
            dt = time.perf_counter() - t0
            s = ix.build_report.detail["structure"]
            buckets = [b for t in s["trees"] for b in t["bucket_sizes"]]
            levels: dict[int, int] = {}
            for t in s["trees"]:
                for lv, n in t["nodes_per_level"].items():
                    levels[int(lv)] = levels.get(int(lv), 0) + n
            derived = (
                f"dataset={ds.name};method={method};trees={s['n_trees']};"
                f"internal={s['total_internal']};leaves={s['total_leaves']};"
                f"height={s['max_height']};bucket_mean={np.mean(buckets):.1f};"
                f"bucket_median={np.median(buckets):.0f};"
                f"bucket_max={max(buckets)};"
                f"peak_level={max(levels, key=levels.get)}"
            )
            emit(f"structure/{ds.name}/{method}", dt * 1e6, derived)
            if out is not None:
                out[f"{ds.name}/{method}"] = {
                    "structure": s, "levels": levels,
                    "bucket_mean": float(np.mean(buckets)),
                }


if __name__ == "__main__":
    run()
