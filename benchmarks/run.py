"""Benchmark orchestrator — one module per paper table/figure.

Output format: ``name,us_per_call,derived`` CSV rows.

  structure     — paper Figs. 6-19  (tree structure evaluation)
  construction  — paper Fig. 20    (build-phase distance/comparison counts)
  search        — paper Fig. 21    (kNN search efficiency vs k)
  retrieval     — framework feature microbench (kNN-LM datastore scan)
  roofline      — §Roofline rollup from the dry-run artifacts

``--full`` uses paper-scale dataset sizes (62,702 / 1M rows); the default
is scaled for CI.  ``--only <name>`` runs one suite.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, "src")

from benchmarks import (  # noqa: E402
    bench_construction,
    bench_retrieval,
    bench_search,
    bench_structure,
    roofline,
)

SUITES = {
    "structure": bench_structure.run,
    "construction": bench_construction.run,
    "search": bench_search.run,
    "retrieval": bench_retrieval.run,
    "roofline": roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale datasets")
    ap.add_argument("--only", choices=list(SUITES))
    ap.add_argument("--json-out", default="experiments/bench_results.json")
    args = ap.parse_args()

    results: dict[str, dict] = {}
    suites = {args.only: SUITES[args.only]} if args.only else SUITES
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        out: dict = {}
        try:
            fn(full=args.full, out=out)
        except Exception as e:  # keep the harness running
            print(f"{name}/ERROR,0.00,{type(e).__name__}: {e}")
        results[name] = out
    path = Path(args.json_out)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(results, indent=1, default=str))


if __name__ == "__main__":
    main()
