"""Shared benchmark utilities: datasets, configs, CSV + JSON artifacts."""
from __future__ import annotations

import json
import os
import platform
import sys
import time
from dataclasses import dataclass

import numpy as np

sys.path.insert(0, "src")  # allow `python -m benchmarks.run` without install

from repro.api import (  # noqa: E402
    Config,
    IndexConfig,
    LayoutConfig,
    ObsConfig,
    SearchConfig,
)
from repro.data.synthetic import tracking_like, ward_like  # noqa: E402

METHODS = ("dbm", "obm", "vbm")


@dataclass(frozen=True)
class BenchDataset:
    name: str
    x: np.ndarray
    eps: float
    min_pts: int
    xi_min: float
    xi_max: float
    c_max: int


def load_datasets(full: bool = False, smoke: bool = False) -> list[BenchDataset]:
    """Paper Table 1 datasets (synthetic stand-ins; --full = paper sizes,
    ``smoke`` = CI sizes that keep every code path but finish in seconds).

    eps / MinPts are re-derived for the synthetic generators with the same
    procedure the paper implies (k-dist elbow); the paper's absolute values
    (eps=248 / 91) are tied to its private data scales.
    """
    if smoke:
        n_track, n_ward = 3_000, 6_000
    elif full:
        n_track, n_ward = 62_702, 1_000_000
    else:
        n_track, n_ward = 12_000, 40_000
    track = tracking_like(n_track)
    ward = ward_like(n_ward)
    return [
        BenchDataset("Tracking", track, eps=6.0, min_pts=16, xi_min=0.4,
                     xi_max=0.8, c_max=max(4, int(np.sqrt(n_track)))),
        BenchDataset("WARD", ward, eps=2.0, min_pts=23, xi_min=0.4,
                     xi_max=0.8, c_max=max(4, int(np.sqrt(n_ward)))),
    ]


def index_config(ds: BenchDataset, method: str) -> IndexConfig:
    return IndexConfig(
        method=method, xi_min=ds.xi_min, xi_max=ds.xi_max,
        eps=ds.eps, min_pts=ds.min_pts, c_max=ds.c_max,
    )


def layout_config(shards: int = 1, route: bool = False) -> LayoutConfig:
    """Device layout for a bench run: single below 2 shards, else the
    sharded island layout — or, with ``route=True``, the routed layout
    (routing tier over the same islands).  The caller is responsible for
    forcing a host mesh via XLA_FLAGS before jax initializes."""
    if shards <= 1:
        return LayoutConfig()
    if route:
        return LayoutConfig(kind="routed", shards=shards)
    return LayoutConfig(kind="sharded", shards=shards)


def facade_config(
    ds: BenchDataset, method: str, *, shards: int = 1, route: bool = False,
    obs: bool = True, **search,
) -> Config:
    """Full Config tree for OverlapIndex.build over a bench dataset.
    ``obs=False`` disables the telemetry registry (overhead comparisons)."""
    return Config(
        index=index_config(ds, method),
        search=SearchConfig(**search),
        layout=layout_config(shards, route),
        obs=ObsConfig(enabled=obs),
    )


def baseline_config(
    ds: BenchDataset, *, shards: int = 1, route: bool = False,
    obs: bool = True, **search,
) -> Config:
    """BCCF baseline config: documented 'kmeans' pivot semantics, explicit
    so the honored-pivot warning never fires in benchmarks."""
    import dataclasses

    return Config(
        index=dataclasses.replace(index_config(ds, "vbm"), pivot_method="kmeans"),
        search=SearchConfig(**search),
        layout=layout_config(shards, route),
        obs=ObsConfig(enabled=obs),
    )


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.2f},{derived}")


# ---------------------------------------------------------------------------
# Machine-readable artifacts: every benchmark run writes BENCH_<name>.json
# so the perf trajectory is diffable across commits (the CSV lines above are
# for eyeballs; these files are for tooling/CI).
# ---------------------------------------------------------------------------

_RECORDS: dict[str, list[dict]] = {}


def record(bench: str, name: str, **fields) -> None:
    """Append one datapoint to the ``bench`` artifact (written at exit of
    the benchmark's run() via ``write_artifact``)."""
    _RECORDS.setdefault(bench, []).append(dict(name=name, **fields))


def write_artifact(bench: str, meta: dict | None = None) -> str:
    """Write BENCH_<bench>.json into $REPRO_BENCH_DIR (default: CWD).

    Schema: {"bench", "meta": {backend, jax, numpy, python, unix_time},
    "records": [{"name", ...datapoint fields}]}.  Returns the path.
    """
    import jax

    out_dir = os.environ.get("REPRO_BENCH_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{bench}.json")
    payload = {
        "bench": bench,
        "meta": {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "numpy": np.__version__,
            "python": platform.python_version(),
            "unix_time": time.time(),
            **(meta or {}),
        },
        # pop: a second run() in the same process must not concatenate its
        # records onto this artifact's
        "records": _RECORDS.pop(bench, []),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"# wrote {path} ({len(payload['records'])} records)")
    return path


# ---------------------------------------------------------------------------
# BENCH-artifact history: the substrate of the rolling-median regression
# gate (benchmarks/check_regress.py).  One JSONL line per (run, dataset,
# method) keeps the us_per_query trajectory across CI runs; windowed medians
# over that series flag SUSTAINED regressions while staying blind to
# single-run noise (the HomebrewNLP wandblog early-warning idiom).
# ---------------------------------------------------------------------------


def history_entries(payload: dict) -> list[dict]:
    """Collapse one BENCH artifact payload into per-(dataset, method)
    history lines: the MEDIAN us_per_query across the run's k sweep (one
    scalar per series per run keeps the gate's window semantics simple).

    Sharded-layout records (``shards > 1``) get a ``/s<N>`` method suffix:
    tier-2 CI appends its 4-shard timings into the SAME history file as
    tier-1, and the suffix keeps them a separate gated series instead of
    corrupting the single-device medians.  Routed-layout records (the
    routing tier over the same islands; ``routed`` truthy on the record)
    get ``/r<N>`` instead — their timings include the routing prefix and
    must gate as their own series too."""
    by: dict[tuple[str, str], list[float]] = {}
    for r in payload.get("records", []):
        if "us_per_query" in r and "dataset" in r and "method" in r:
            method = str(r["method"])
            shards = int(r.get("shards", 1))
            if shards > 1:
                tag = "r" if r.get("routed") else "s"
                method = f"{method}/{tag}{shards}"
            key = (str(r["dataset"]), method)
            by.setdefault(key, []).append(float(r["us_per_query"]))
    t = float(payload.get("meta", {}).get("unix_time", 0.0))
    return [
        {
            "t": t,
            "bench": payload.get("bench", "?"),
            "dataset": ds,
            "method": m,
            "us_per_query": float(np.median(v)),
            "n_points": len(v),
        }
        for (ds, m), v in sorted(by.items())
    ]


def load_history(path: str) -> list[dict]:
    """Read a JSONL history file; a missing file is an empty history."""
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def append_history(path: str, entries: list[dict]) -> None:
    """Append history lines (see ``history_entries``) to a JSONL file."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "a") as f:
        for e in entries:
            f.write(json.dumps(e, sort_keys=True) + "\n")


def history_series(entries: list[dict]) -> dict[tuple[str, str], list[float]]:
    """(dataset, method) -> us_per_query series in file (= run) order."""
    series: dict[tuple[str, str], list[float]] = {}
    for e in entries:
        key = (str(e["dataset"]), str(e["method"]))
        series.setdefault(key, []).append(float(e["us_per_query"]))
    return series


def rolling_median(values: list[float], window: int) -> float:
    """Median of the newest ``window`` values (all of them when shorter)."""
    if not values:
        return float("nan")
    return float(np.median(values[-window:]))
