"""Shared benchmark utilities: datasets, configs, CSV output."""
from __future__ import annotations

import sys
import time
from dataclasses import dataclass

import numpy as np

sys.path.insert(0, "src")  # allow `python -m benchmarks.run` without install

from repro.core import IndexConfig  # noqa: E402
from repro.data.synthetic import tracking_like, ward_like  # noqa: E402

METHODS = ("dbm", "obm", "vbm")


@dataclass(frozen=True)
class BenchDataset:
    name: str
    x: np.ndarray
    eps: float
    min_pts: int
    xi_min: float
    xi_max: float
    c_max: int


def load_datasets(full: bool = False) -> list[BenchDataset]:
    """Paper Table 1 datasets (synthetic stand-ins; --full = paper sizes).

    eps / MinPts are re-derived for the synthetic generators with the same
    procedure the paper implies (k-dist elbow); the paper's absolute values
    (eps=248 / 91) are tied to its private data scales.
    """
    if full:
        n_track, n_ward = 62_702, 1_000_000
    else:
        n_track, n_ward = 12_000, 40_000
    track = tracking_like(n_track)
    ward = ward_like(n_ward)
    return [
        BenchDataset("Tracking", track, eps=6.0, min_pts=16, xi_min=0.4,
                     xi_max=0.8, c_max=max(4, int(np.sqrt(n_track)))),
        BenchDataset("WARD", ward, eps=2.0, min_pts=23, xi_min=0.4,
                     xi_max=0.8, c_max=max(4, int(np.sqrt(n_ward)))),
    ]


def index_config(ds: BenchDataset, method: str) -> IndexConfig:
    return IndexConfig(
        method=method, xi_min=ds.xi_min, xi_max=ds.xi_max,
        eps=ds.eps, min_pts=ds.min_pts, c_max=ds.c_max,
    )


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.2f},{derived}")
