"""Rolling-median bench regression gate: fail CI only on SUSTAINED
us_per_query regression, never on single-run noise.

The idiom (HomebrewNLP's wandblog early warning, pointed at by ROADMAP's
serving-telemetry item): keep a history of per-run medians per (dataset,
method) series, compare the median of the newest ``--window`` runs against
the median of everything before that window, and flag only when the
CURRENT window's median exceeds ``--threshold`` x the baseline median.  A
single noisy run cannot move a window median; a genuine 2x slowdown that
persists for a window of runs flips the gate deterministically.

Warm-up semantics: with fewer than ``--min-runs`` total runs in a series
(default: two windows' worth) the verdict is WARN-ONLY — the gate reports
but never fails, so a fresh history (new runner fleet, new series) hard-
gates only once its own baseline exists.

Typical CI wiring (.github/workflows/ci.yml):

    python -m benchmarks.check_regress \
        --artifact BENCH_search.json \
        --history .bench_history/search_history.jsonl \
        --seed benchmarks/history/search_history.jsonl \
        --window 5 --update --gate

``--history`` persists across runs via actions/cache; ``--seed`` bootstraps
an empty history from the committed baseline; ``--update`` appends this
run's entries after checking (so the gate never judges a run against
itself); ``--gate`` turns sustained regressions into a non-zero exit.
"""
from __future__ import annotations

import argparse
import json
import sys

from benchmarks.common import (
    append_history,
    history_entries,
    history_series,
    load_history,
    rolling_median,
)

OK, REGRESSED, INSUFFICIENT = "ok", "REGRESSED", "insufficient-history"


def check_series(
    values: list[float],
    *,
    window: int,
    threshold: float,
    min_runs: int,
) -> tuple[str, dict]:
    """Verdict for one series whose LAST element is the run under test.

    Returns (status, detail): ``ok`` / ``REGRESSED`` / ``insufficient-
    history``.  ``detail`` carries the window median, the baseline median,
    and their ratio for reporting."""
    n = len(values)
    current = rolling_median(values, window)
    baseline_vals = values[:-window] if n > window else []
    if n < min_runs or not baseline_vals:
        return INSUFFICIENT, {
            "runs": n,
            "min_runs": min_runs,
            "current_median": current,
        }
    baseline = rolling_median(baseline_vals, len(baseline_vals))
    ratio = current / baseline if baseline > 0 else float("inf")
    detail = {
        "runs": n,
        "current_median": current,
        "baseline_median": baseline,
        "ratio": ratio,
        "threshold": threshold,
    }
    return (REGRESSED if ratio > threshold else OK), detail


def run_check(
    artifact_path: str,
    history_path: str,
    *,
    seed_path: str | None = None,
    window: int = 5,
    threshold: float = 1.5,
    min_runs: int | None = None,
    update: bool = False,
    gate: bool = False,
) -> int:
    """The whole gate; returns the process exit code (0 pass / 1 fail)."""
    if min_runs is None:
        min_runs = 2 * window
    with open(artifact_path) as f:
        payload = json.load(f)
    current = history_entries(payload)
    if not current:
        print(f"# {artifact_path}: no (dataset, method, us_per_query) "
              "records — nothing to gate")
        return 0

    past = load_history(history_path)
    seeded = False
    if not past and seed_path:
        past = load_history(seed_path)
        seeded = bool(past)
        if seeded:
            print(f"# history {history_path} empty; seeded "
                  f"{len(past)} entries from {seed_path}")
    series = history_series(past)

    failures = []
    for entry in current:
        key = (entry["dataset"], entry["method"])
        values = series.get(key, []) + [entry["us_per_query"]]
        status, detail = check_series(
            values, window=window, threshold=threshold, min_runs=min_runs
        )
        name = f"{key[0]}/{key[1]}"
        if status == INSUFFICIENT:
            print(f"regress/{name}: {status} ({detail['runs']}/"
                  f"{detail['min_runs']} runs, current median "
                  f"{detail['current_median']:.1f} us) — warn-only")
        else:
            print(f"regress/{name}: {status} window-median "
                  f"{detail['current_median']:.1f} us vs baseline "
                  f"{detail['baseline_median']:.1f} us "
                  f"(x{detail['ratio']:.2f}, gate x{threshold:.2f}, "
                  f"{detail['runs']} runs)")
        if status == REGRESSED:
            failures.append(name)

    if update:
        if seeded:
            append_history(history_path, past)  # materialize the seed once
        append_history(history_path, current)
        print(f"# appended {len(current)} entries to {history_path}")

    if failures:
        msg = (f"sustained regression (rolling median over window={window}) "
               f"in {len(failures)} series: {', '.join(failures)}")
        if gate:
            print(f"FAIL: {msg}", file=sys.stderr)
            return 1
        print(f"WARN (no --gate): {msg}")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--artifact", default="BENCH_search.json",
                    help="BENCH artifact of the run under test")
    ap.add_argument("--history", required=True,
                    help="JSONL history file (persisted across CI runs)")
    ap.add_argument("--seed", default=None,
                    help="committed baseline JSONL used when --history "
                    "does not exist yet")
    ap.add_argument("--window", type=int, default=5,
                    help="runs per rolling-median window")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="fail when window median > threshold x baseline")
    ap.add_argument("--min-runs", type=int, default=None,
                    help="runs required before the gate can fail "
                    "(default: 2*window — warn-only for the first window)")
    ap.add_argument("--update", action="store_true",
                    help="append this run's entries to the history")
    ap.add_argument("--gate", action="store_true",
                    help="exit non-zero on sustained regression")
    a = ap.parse_args(argv)
    return run_check(
        a.artifact, a.history, seed_path=a.seed, window=a.window,
        threshold=a.threshold, min_runs=a.min_runs, update=a.update,
        gate=a.gate,
    )


if __name__ == "__main__":
    raise SystemExit(main())
