"""Retrieval-layer microbench (framework feature built on the paper's
index): kNN-LM datastore scan throughput — flat vs forest-pruned vs int8
quantized — over a synthetic embedding datastore."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit
from repro.api import Config, IndexConfig, OverlapIndex
from repro.data.synthetic import embedding_datastore
from repro.kernels import ops as kops


def run(full: bool = False, out: dict | None = None) -> None:
    n = 200_000 if full else 30_000
    dim, k, n_q = 256, 8, 64
    keys, values = embedding_datastore(n, dim)
    g = np.random.default_rng(3)
    q = keys[g.choice(n, n_q)] + 0.1 * g.normal(size=(n_q, dim)).astype(np.float32)
    qj = jnp.asarray(q)
    kj = jnp.asarray(keys)

    # flat fused scan
    kops.knn_topk(qj[:2], kj, k=k)  # warm
    with Timer() as t:
        d_flat, i_flat = kops.knn_topk(qj, kj, k=k)
        d_flat.block_until_ready()
    emit("retrieval/flat", t.s * 1e6 / n_q, f"n={n};dim={dim};k={k}")

    # int8 quantized scan
    xq, scale = kops.quantize_datastore(kj)
    kops.pairwise_sq_l2_int8(qj[:2], xq, scale)
    with Timer() as t:
        d2 = kops.pairwise_sq_l2_int8(qj, xq, scale)
        dq, iq = jnp.sort(d2, axis=1)[:, :k], jnp.argsort(d2, axis=1)[:, :k]
        dq.block_until_ready()
    agree = float(np.mean([
        len(set(np.asarray(iq)[i].tolist()) & set(np.asarray(i_flat)[i].tolist())) / k
        for i in range(n_q)]))
    emit("retrieval/int8", t.s * 1e6 / n_q,
         f"n={n};dim={dim};k={k};agree_vs_f32={agree:.3f};bytes_ratio=0.25")

    # paper's forest index (pruned scan) through the facade
    cfg = Config(index=IndexConfig(
        method="vbm", eps=3.5, min_pts=8, xi_min=0.4, xi_max=0.8,
        dbscan_block=2048,
    ))
    ix = OverlapIndex.build(keys, cfg)
    ix.search(q, k=k, mode="forest")  # warm the plan
    with Timer() as t:
        res = ix.search(q, k=k, mode="forest")
    recall = float(np.mean([
        len(set(res.ids[i].tolist()) & set(np.asarray(i_flat)[i].tolist())) / k
        for i in range(n_q)]))
    frac = float(res.stats["distances"].mean()) / n
    emit("retrieval/forest-vbm", t.s * 1e6 / n_q,
         f"n={n};k={k};indexes={ix.build_report.n_indexes};"
         f"dist_frac={frac:.4f};recall_vs_exact={recall:.3f}")
    if out is not None:
        out["forest_dist_frac"] = frac
        out["forest_recall"] = recall


if __name__ == "__main__":
    run()
