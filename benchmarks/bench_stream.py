"""Streaming subsystem benchmark: ingest throughput, delta-search overhead,
and maintenance/rebuild cost for the ingest → monitor → rebuild lifecycle
(src/repro/stream/).

Measured per dataset:
  * ingest      — device routing+append throughput (points/s), steady state;
  * search      — ms/query over forest+delta at increasing delta fill, vs
                  the empty-delta baseline (the degradation the fixed
                  capacity bounds);
  * maintain    — drift-monitor evaluation cost and, when triggered, the
                  host rebuild + hot-swap wall time;
  * exactness   — mode='all' over forest+delta vs brute force over every
                  object ingested so far (hard gate, not a statistic).

``--smoke`` shrinks sizes for CI (runs in well under a minute on CPU and
exercises every code path including at least one rebuild swap).

Artifacts: CSV lines on stdout (benchmarks/common.emit) and a
machine-readable BENCH_stream.json (common.write_artifact).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit, record, write_artifact
from repro.api import Config, IndexConfig, OverlapIndex, StreamConfig
from repro.core import knn_exact

K = 10
N_QUERIES = 64


def _queries(x: np.ndarray, n: int, seed: int = 7) -> np.ndarray:
    g = np.random.default_rng(seed)
    idx = g.choice(len(x), min(n, len(x)), replace=False)
    return (x[idx] + 0.05 * x.std() * g.normal(size=(len(idx), x.shape[1]))).astype(
        np.float32
    )


def _drifting_batches(
    n_total: int, batch: int, dim: int, seed: int
) -> list[np.ndarray]:
    """IoT-style arrival: clustered points whose centers wander over time,
    plus a slowly growing bridge between regions (the overlap-drift driver)."""
    g = np.random.default_rng(seed)
    centers = g.normal(size=(6, dim)) * 12.0
    drift = g.normal(size=(6, dim))
    drift /= np.linalg.norm(drift, axis=1, keepdims=True)
    out = []
    t = 0.0
    remaining = n_total
    while remaining > 0:
        m = min(batch, remaining)
        lab = g.integers(0, 6, m)
        pts = centers[lab] + t * drift[lab] * 2.0 + g.normal(size=(m, dim))
        out.append(pts.astype(np.float32))
        remaining -= m
        t += 1.0
    return out


def _search_ms(sf: OverlapIndex, q: np.ndarray, *, mode: str) -> float:
    sf.search(q, k=K, mode=mode)  # warm: plan + shape specialization
    t0 = time.perf_counter()
    d, i, s = sf.search(q, k=K, mode=mode)  # SearchResult unpacks (host sync)
    return (time.perf_counter() - t0) * 1e3 / len(q)


def run(smoke: bool = False) -> None:
    if smoke:
        n_seed, n_stream, batch, dim, capacity = 1_500, 1_500, 256, 8, 256
    else:
        n_seed, n_stream, batch, dim, capacity = 20_000, 40_000, 1_024, 12, 2_048

    batches = _drifting_batches(n_stream, batch, dim, seed=11)
    x0 = np.concatenate(_drifting_batches(n_seed, n_seed, dim, seed=3))

    with Timer() as t_build:
        sf = OverlapIndex.build(x0, Config(
            index=IndexConfig(method="vbm", eps=2.5, min_pts=8),
            stream=StreamConfig(
                capacity=capacity, monitor_method="dbm",
                xi_rebuild=0.6, fill_rebuild=0.7,
            ),
        ))
    emit("stream/build", t_build.s * 1e6,
         f"n={n_seed};indexes={sf.forest.n_indexes};buckets={sf.forest.n_buckets}")
    record("stream", "build", n_seed=n_seed, indexes=sf.forest.n_indexes,
           buckets=sf.forest.n_buckets, wall_s=t_build.s)

    sf.check()  # allocate the (empty) delta so the baseline includes its scan
    q = _queries(x0, N_QUERIES)
    base_ms = _search_ms(sf, q, mode="forest")
    emit("stream/search_empty_delta", base_ms * 1e3, f"k={K};delta_fill=0")
    record("stream", "search_empty_delta", ms_per_query=base_ms, fill=0.0)

    # --- streaming loop ----------------------------------------------------
    ingest_s = 0.0
    maint_s = 0.0
    n_rebuilds0 = len(sf.rebuild_log)
    for bi, xb in enumerate(batches):
        t0 = time.perf_counter()
        sf.ingest(xb)
        jnp.asarray(sf.delta.count).block_until_ready()
        ingest_s += time.perf_counter() - t0

        t0 = time.perf_counter()
        report = sf.maintain()
        maint_s += time.perf_counter() - t0
        if report.triggers:
            emit("stream/rebuild", sf.rebuild_log[-1]["wall_time_s"] * 1e6,
                 f"batch={bi};triggers={len(report.triggers)};"
                 f"reasons={sorted(set(r for v in report.reasons.values() for r in v))}")
            record("stream", "rebuild", batch=bi,
                   triggers=len(report.triggers),
                   absorbed=sf.rebuild_log[-1]["n_absorbed"],
                   wall_s=sf.rebuild_log[-1]["wall_time_s"])
        if bi == len(batches) // 2:
            fill = float(np.asarray(sf.delta.count).sum()) / (
                sf.capacity * sf.forest.n_indexes)
            mid_ms = _search_ms(sf, q, mode="forest")
            emit("stream/search_mid_stream", mid_ms * 1e3,
                 f"k={K};delta_fill={fill:.3f};overhead={mid_ms / base_ms:.2f}x")
            record("stream", "search_mid_stream", ms_per_query=mid_ms, fill=fill)

    pts_per_s = n_stream / max(ingest_s, 1e-9)
    emit("stream/ingest", ingest_s * 1e6 / n_stream,
         f"n={n_stream};points_per_s={pts_per_s:.0f}")
    record("stream", "ingest", n=n_stream, points_per_s=pts_per_s,
           wall_s=ingest_s)
    emit("stream/maintain", maint_s * 1e6 / len(batches),
         f"checks={len(batches)};rebuilds={len(sf.rebuild_log) - n_rebuilds0}")
    record("stream", "maintain", checks=len(batches),
           rebuilds=len(sf.rebuild_log) - n_rebuilds0, wall_s=maint_s)

    # --- hard exactness gate ----------------------------------------------
    x_all = sf.x_all
    qf = _queries(x_all, N_QUERIES, seed=13)
    d, ids, stats = sf.search(qf, k=K, mode="all")
    de, _ = knn_exact(jnp.asarray(x_all), jnp.asarray(qf), k=K)
    # f32 ||q||^2+||x||^2-2qx expansion: ~1e-3 at these coordinate scales
    np.testing.assert_allclose(
        np.asarray(d), np.asarray(de), rtol=1e-3, atol=1e-3
    )
    end_ms = _search_ms(sf, qf, mode="forest")
    emit("stream/search_end", end_ms * 1e3,
         f"k={K};n_total={sf.n_total};exact=1;overhead={end_ms / base_ms:.2f}x")
    record("stream", "search_end", ms_per_query=end_ms, n_total=sf.n_total,
           exact=True)
    write_artifact("stream", meta=dict(
        smoke=smoke, n_seed=n_seed, n_stream=n_stream, batch=batch,
        capacity=capacity, rebuilds=len(sf.rebuild_log),
    ))
    print(f"stream bench OK: {n_stream} ingested at {pts_per_s:.0f} pts/s, "
          f"{len(sf.rebuild_log)} rebuilds, final search exact")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    run(smoke=ap.parse_args().smoke)
