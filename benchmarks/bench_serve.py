"""Open-loop saturation bench for the serving front (serve/engine.py).

Drives offered-QPS sweeps through ``ServeEngine`` the way a network front
would: arrivals are a seeded Poisson process scheduled on the wall clock,
INDEPENDENT of completions (open loop — offered load does not back off
when the engine falls behind, which is exactly what exposes the
saturation knee).  Each operating point runs twice:

  * ``noshed`` — no deadlines: every request is admitted and served, so
    past the knee the queue (and the p99 of everything in it) grows with
    offered load;
  * ``shed``   — every request carries the same ``deadline_s`` budget;
    admission control rejects on submit when the projected wait exceeds
    it, queued requests expire before prefill, and mid-flight requests
    are evicted — so the p99 of ADMITTED requests stays bounded near the
    deadline while the shed rate absorbs the overload.

Offered QPS points are calibrated to the measured engine capacity
(``num_slots / (max_new_tokens * decode_step_s)``), so the same ratios
(0.5x .. 5x capacity) land on both a laptop and a CI runner.

Everything reported comes straight out of ``ServeEngine.metrics()`` (the
repro.obs registry): request-latency percentiles are the engine's own
``serve.request_latency_s`` histogram (completed requests only — shed
waits live in ``serve.shed_wait_s``), shed counts are the
``serve.shed{reason=...}`` counters, and mean slot occupancy is
``serve.tokens / (serve.steps * num_slots)``.  With ``$REPRO_OBS_EVENTS``
set, a fraction of requests is trace-sampled and the slowest completed
sampled request of the heaviest shed point is reconstructed and printed —
*where* a tail request spent its time (queue wait vs prefill vs decode).

Hard gates (exit non-zero), the acceptance criteria of the serving front:
  * with shedding, p99 of admitted requests stays bounded
    (<= deadline + a small service allowance) at EVERY offered-QPS point,
    including far past the knee;
  * at the heaviest point the no-shedding p99 exceeds the shedding p99
    (the unbounded queue is visible) and the shed rate is non-zero;
  * per point, ``submitted == completed + shed`` once drained.

Artifacts: CSV lines on stdout (benchmarks/common.emit) and
BENCH_serve.json (common.write_artifact) with one record per (ratio,
mode).  Sub-capacity points additionally carry ``us_per_query`` (p50
request latency) so benchmarks/check_regress.py gates them as rolling-
median series — overloaded points are queue-dominated by design and stay
out of the regression gate.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from benchmarks.common import emit, record, write_artifact

# the sweep: offered QPS as multiples of measured capacity; >= SHED_BOUND
# ratios are past the knee, where the two modes must diverge
RATIOS = (0.5, 1.0, 2.0, 5.0)
# only the sub-capacity point feeds the regression-gate history: at >= 1x
# capacity the latency is queue-dominated and a critically-loaded queue's
# wait is inherently high-variance run to run
GATED_RATIOS = (0.5,)
TRACE_SAMPLE = 0.25


def _requests(cfg, n: int, *, tokens: int, deadline: float | None, seed: int):
    from repro.serve.engine import Request

    g = np.random.default_rng(seed)
    return [
        Request(
            rid=i, prompt=g.integers(0, cfg.vocab_size, 6).astype(np.int32),
            max_new_tokens=tokens, deadline_s=deadline,
        )
        for i in range(n)
    ]


def _arrivals(n: int, qps: float, seed: int) -> np.ndarray:
    """Poisson-process arrival offsets (seconds from sweep start)."""
    g = np.random.default_rng(seed)
    return np.cumsum(g.exponential(1.0 / qps, size=n))


def drive(engine, reqs, arrivals) -> list:
    """Open-loop driver: submit each request at its scheduled arrival time,
    interleaved with ``engine.step()`` service; arrivals never wait for
    completions.  Returns every terminal request (completed + shed,
    including submit-time rejections, which run()/step() do not return)."""
    finished = []
    t0 = time.perf_counter()
    i = 0
    while i < len(reqs) or engine.busy:
        now = time.perf_counter() - t0
        while i < len(reqs) and arrivals[i] <= now:
            r = reqs[i]
            i += 1
            if not engine.submit(r):
                finished.append(r)  # rejected on submit
        if engine.busy:
            finished.extend(engine.step())
        elif i < len(reqs):
            time.sleep(min(max(arrivals[i] - now, 0.0), 0.01))
    return finished


def _point(engine, cfg, *, n, qps, tokens, deadline, seed, events):
    """One operating point on a fresh registry; returns the metrics the
    sweep records."""
    from repro.obs import EventLog, Registry
    from repro.serve.engine import (
        SHED_EARLY,
        SHED_EXPIRED_FLIGHT,
        SHED_EXPIRED_QUEUE,
        SHED_REJECTED,
    )

    reg = engine.reset_metrics(
        Registry(events=EventLog(events)) if events else None
    )
    reqs = _requests(cfg, n, tokens=tokens, deadline=deadline, seed=seed)
    finished = drive(engine, reqs, _arrivals(n, qps, seed + 1))
    assert len(finished) == n, "driver lost a request"

    snap = reg.snapshot()
    lat = snap["histograms"].get("serve.request_latency_s", {})
    shed = {
        r: reg.value("serve.shed", reason=r)
        for r in (SHED_REJECTED, SHED_EXPIRED_QUEUE, SHED_EXPIRED_FLIGHT,
                  SHED_EARLY)
    }
    submitted = reg.value("serve.submitted")
    completed = reg.value("serve.completed")
    steps = reg.value("serve.steps")
    occupancy = (
        reg.value("serve.tokens") / (steps * engine.num_slots) if steps else 0.0
    )
    assert submitted == n
    assert completed + sum(shed.values()) == n, "shed accounting leak"
    return {
        "n": n,
        "completed": completed,
        "shed": shed,
        "shed_rate": sum(shed.values()) / n,
        "p50_s": lat.get("p50", float("nan")),
        "p95_s": lat.get("p95", float("nan")),
        "p99_s": lat.get("p99", float("nan")),
        "queue_wait_p99_s": snap["histograms"]
        .get("serve.queue_wait", {})
        .get("p99", 0.0),
        "occupancy": occupancy,
        "steps": steps,
        "finished": finished,
    }


def _slowest_sampled_trace(finished, events: str) -> str | None:
    """Reconstruct + render the slowest completed trace-sampled request's
    span tree (the PR 8 path): the bench's tail-latency explanation."""
    from repro.obs import Trace

    done = [r for r in finished if getattr(r, "done", False) and r.trace]
    done = [r for r in done if r.trace.sampled]
    if not done:
        return None
    worst = max(done, key=lambda r: r.latency_s)
    try:
        return Trace.reconstruct(events, worst.trace.trace_id).render()
    except (KeyError, ValueError, OSError):  # sampled but log rotated/unset
        return None


def run(smoke: bool = False, events: str | None = None) -> int:
    import jax

    from repro.configs import get_smoke_config
    from repro.models.model import Model
    from repro.serve.engine import ServeEngine

    # n_per_point must be large enough that the backlog a >=2x overload
    # builds up (~ n/capacity * (1 - 1/ratio) of queue wait by the last
    # arrival) comfortably exceeds the deadline — otherwise the whole burst
    # drains inside every budget and the knee never shows
    if smoke:
        n_per_point, tokens, num_slots, max_len = 150, 8, 4, 32
    else:
        n_per_point, tokens, num_slots, max_len = 500, 16, 8, 64

    cfg = get_smoke_config("smollm-135m")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(
        model, params, num_slots=num_slots, max_len=max_len,
        trace_sample=TRACE_SAMPLE if events else 0.0,
    )

    # warm twice: the first run pays prefill+decode compilation; the second
    # measures the steady state the capacity estimate and deadline hang off.
    # Capacity is MEASURED closed-loop throughput (requests / wall), which
    # prices everything the engine pays per request — refill, decode steps,
    # scheduler overhead — not just the decode-step arithmetic.
    service_p50 = capacity_qps = 0.0
    for w in range(2):
        engine.reset_metrics()
        for r in _requests(cfg, 4 * num_slots, tokens=tokens, deadline=None,
                           seed=90 + w):
            engine.submit(r)
        t0 = time.perf_counter()
        engine.run()
        capacity_qps = 4 * num_slots / (time.perf_counter() - t0)
        service_p50 = engine.metrics()["histograms"][
            "serve.request_latency_s"]["p50"]

    step_s = engine.step_time_s()
    # budget: a few end-to-end service times — comfortably met below the
    # knee, decisively violated by unbounded queueing above it
    deadline = 3.0 * service_p50
    bound = deadline + 3.0 * service_p50  # admitted-p99 ceiling (gate)
    print(f"# capacity ~{capacity_qps:.1f} qps (step {step_s*1e3:.2f} ms, "
          f"service p50 {service_p50*1e3:.1f} ms); deadline {deadline*1e3:.1f} ms")

    results: dict[tuple[float, str], dict] = {}
    for ratio in RATIOS:
        qps = ratio * capacity_qps
        for mode, dl in (("noshed", None), ("shed", deadline)):
            m = _point(
                engine, cfg, n=n_per_point, qps=qps, tokens=tokens,
                deadline=dl, seed=int(ratio * 100), events=events,
            )
            results[(ratio, mode)] = m
            emit(f"serve/{mode}_q{ratio:g}x", m["p50_s"] * 1e6,
                 f"qps={qps:.1f};p99_ms={m['p99_s']*1e3:.1f};"
                 f"shed_rate={m['shed_rate']:.2f};occ={m['occupancy']:.2f}")
            rec = dict(
                dataset="ServeSmoke" if smoke else "Serve",
                method=f"{mode}-q{ratio:g}x",
                offered_qps=qps, ratio=ratio, mode=mode,
                deadline_s=dl, n=m["n"], completed=m["completed"],
                shed_rejected=m["shed"]["rejected"],
                shed_expired_queue=m["shed"]["expired_queue"],
                shed_expired_flight=m["shed"]["expired_flight"],
                shed_early=m["shed"]["early"],
                shed_rate=m["shed_rate"],
                p50_s=m["p50_s"], p95_s=m["p95_s"], p99_s=m["p99_s"],
                queue_wait_p99_s=m["queue_wait_p99_s"],
                slot_occupancy=m["occupancy"], steps=m["steps"],
            )
            if ratio in GATED_RATIOS:
                # regression-gate series: stable (not queue-dominated) points
                rec["us_per_query"] = m["p50_s"] * 1e6
            record("serve", f"{mode}_q{ratio:g}x", **rec)

    # tail-latency explanation via the trace path (heaviest shed point)
    if events:
        tree = _slowest_sampled_trace(
            results[(RATIOS[-1], "shed")]["finished"], events
        )
        if tree:
            print("# slowest sampled admitted request at "
                  f"{RATIOS[-1]:g}x (shed mode):")
            for line in tree.splitlines():
                print(f"#   {line}")

    write_artifact("serve", meta=dict(
        smoke=smoke, n_per_point=n_per_point, tokens=tokens,
        num_slots=num_slots, capacity_qps=capacity_qps,
        decode_step_s=step_s, service_p50_s=service_p50,
        deadline_s=deadline, p99_bound_s=bound,
    ))

    # --- hard gates --------------------------------------------------------
    failures = []
    for ratio in RATIOS:
        p99 = results[(ratio, "shed")]["p99_s"]
        if not (np.isnan(p99) or p99 <= bound):
            failures.append(
                f"shed p99 unbounded at {ratio:g}x: {p99*1e3:.1f} ms "
                f"> bound {bound*1e3:.1f} ms"
            )
    top = RATIOS[-1]
    m_shed, m_raw = results[(top, "shed")], results[(top, "noshed")]
    if m_shed["shed_rate"] <= 0.0:
        failures.append(f"no shedding at {top:g}x capacity — knee not reached")
    if not m_raw["p99_s"] > m_shed["p99_s"]:
        failures.append(
            f"no-shedding p99 ({m_raw['p99_s']*1e3:.1f} ms) does not exceed "
            f"shedding p99 ({m_shed['p99_s']*1e3:.1f} ms) at {top:g}x"
        )
    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    print(f"serve bench OK: {len(RATIOS)} qps points x 2 modes; at {top:g}x "
          f"capacity shed p99 {m_shed['p99_s']*1e3:.1f} ms (bounded) vs "
          f"noshed {m_raw['p99_s']*1e3:.1f} ms, "
          f"shed rate {m_shed['shed_rate']:.0%}")
    return 0


if __name__ == "__main__":
    import argparse
    import os

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--events", default=os.environ.get("REPRO_OBS_EVENTS"),
                    help="JSONL span log for trace-sampled requests "
                    "(default: $REPRO_OBS_EVENTS)")
    a = ap.parse_args()
    raise SystemExit(run(smoke=a.smoke, events=a.events or None))
