"""Construction cost (paper Fig. 20): distance computations and comparisons
during index construction, per heuristic vs the BCCF-tree baseline.

The paper's Fig. 20 counts the TREE construction phase (its reported
36.6M/11.7M magnitudes exclude DBSCAN preprocessing, which would dominate);
we report the same tree-phase counters plus the preprocessing/overlap
counters separately for transparency.
"""
from __future__ import annotations

import time

from benchmarks.common import (
    METHODS,
    baseline_config,
    emit,
    facade_config,
    load_datasets,
)
from repro.api import OverlapIndex


def run(full: bool = False, out: dict | None = None) -> None:
    for ds in load_datasets(full):
        for method in METHODS:
            t0 = time.perf_counter()
            rep = OverlapIndex.build(ds.x, facade_config(ds, method)).build_report
            dt = time.perf_counter() - t0
            derived = (
                f"dataset={ds.name};method={method};"
                f"tree_dist={rep.tree_distances};tree_cmp={rep.tree_comparisons};"
                f"dbscan_dist={rep.dbscan_distances};overlap_dist={rep.overlap_distances};"
                f"indexes={rep.n_indexes}"
            )
            emit(f"construction/{ds.name}/{method}", dt * 1e6, derived)
            if out is not None:
                out[f"{ds.name}/{method}"] = rep.__dict__ | {"detail": None}
        t0 = time.perf_counter()
        brep = OverlapIndex.baseline(ds.x, baseline_config(ds)).build_report
        dt = time.perf_counter() - t0
        emit(
            f"construction/{ds.name}/bccf-baseline", dt * 1e6,
            f"dataset={ds.name};method=bccf;tree_dist={brep.tree_distances};"
            f"tree_cmp={brep.tree_comparisons};indexes=1",
        )
        if out is not None:
            out[f"{ds.name}/bccf"] = {"tree_distances": brep.tree_distances,
                                      "tree_comparisons": brep.tree_comparisons}


if __name__ == "__main__":
    run()
