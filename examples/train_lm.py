"""End-to-end training driver: train a ~135M-param LM (smollm-135m, the
assigned small-dense arch) for a few hundred steps on the synthetic token
pipeline, with checkpointing, restart-resume, and straggler logging.

    PYTHONPATH=src python examples/train_lm.py --steps 300 [--arch smollm-135m]

Defaults are sized to finish on CPU (reduced batch/seq); pass --prod-shapes
to use the assigned train_4k cell shape on real hardware.
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import Model
from repro.optim.optimizer import get_optimizer
from repro.optim.schedule import cosine_with_warmup
from repro.train.train_step import init_train_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-size-model", action="store_true",
                    help="use the full 135M config (default: smoke-scale)")
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    args = ap.parse_args()

    cfg = (get_config(args.arch) if args.full_size_model
           else get_smoke_config(args.arch))
    cfg = cfg.replace(grad_accum=1)
    model = Model(cfg)
    opt = get_optimizer(cfg.optimizer)
    lr = cosine_with_warmup(3e-4, warmup=20, total=args.steps)
    step_fn = jax.jit(make_train_step(model, opt, lr), donate_argnums=(0,))

    pipeline = TokenPipeline(
        DataConfig(seq_len=args.seq, global_batch=args.batch,
                   vocab_size=cfg.vocab_size))
    state = init_train_state(model, opt, jax.random.key(0))
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M steps={args.steps}")

    trainer = Trainer(step_fn, pipeline, TrainerConfig(
        total_steps=args.steps, ckpt_every=max(args.steps // 4, 10),
        ckpt_dir=args.ckpt_dir, log_every=10))
    state, report = trainer.run(state)
    losses = report.losses
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(first/last), wall {report.wall_time_s:.1f}s, "
          f"stragglers={len(report.straggler_events)}, "
          f"resumed_from={report.resumed_from}")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
