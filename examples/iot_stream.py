"""End-to-end IoT streaming demo: continuous ingest + overlap-driven online
index maintenance, through the ``repro.api.OverlapIndex`` facade
(src/repro/stream/ is the engine room underneath).

A 10k-object forest is built once (the paper's static pipeline), then an
IoT-style stream arrives in batches — in-distribution sensor readings plus a
drifting corridor of readings between two regions, the classic failure mode
for a frozen partition layout.  While ingesting, the demo keeps issuing kNN
queries and at every checkpoint PROVES the serving invariant:

    search over frozen-forest + delta-buckets == brute force over every
    object ever ingested (up to f32 distance-expansion rounding),

including immediately before and immediately after each maintenance rebuild
swap — i.e. the hot swap has no search-correctness gap.  The corridor drift
pushes the monitored DBM overlap rate past the rebuild threshold ξ, so at
least one rebuild is *overlap*-triggered (the paper's own heuristic acting
as the online repartitioning signal), not merely buffer-fill-triggered.

    PYTHONPATH=src python examples/iot_stream.py
"""
import sys

sys.path.insert(0, "src")

import time

import jax.numpy as jnp
import numpy as np

from repro.api import Config, IndexConfig, OverlapIndex, StreamConfig
from repro.core import knn_exact

N_SEED = 10_000
N_STREAM = 10_240
BATCH = 512
DIM = 8
K = 10


def seed_data(g: np.random.Generator) -> np.ndarray:
    centers = g.normal(size=(8, DIM)) * 10.0
    lab = g.integers(0, 8, N_SEED)
    return (centers[lab] + g.normal(size=(N_SEED, DIM))).astype(np.float32), centers


def stream_batches(g: np.random.Generator, centers: np.ndarray) -> list[np.ndarray]:
    """Half in-distribution arrivals, half corridor drift between the two
    closest regions — the overlap-rate driver."""
    d = ((centers[:, None] - centers[None, :]) ** 2).sum(-1)
    np.fill_diagonal(d, np.inf)
    a, b = np.unravel_index(np.argmin(d), d.shape)
    batches = []
    for t in range(N_STREAM // BATCH):
        lab = g.integers(0, len(centers), BATCH // 2)
        in_dist = centers[lab] + g.normal(size=(BATCH // 2, DIM))
        frac = g.uniform(0.25, 0.75, size=(BATCH // 2, 1))
        corridor = centers[a] * (1 - frac) + centers[b] * frac + g.normal(
            size=(BATCH // 2, DIM)) * (1.0 + 0.25 * t)
        batches.append(np.concatenate([in_dist, corridor]).astype(np.float32))
    return batches


def check_exact(sf: OverlapIndex, g: np.random.Generator, tag: str) -> None:
    x_all = sf.x_all
    qi = g.choice(sf.n_total, 32, replace=False)
    q = (x_all[qi] + 0.05 * g.normal(size=(32, DIM))).astype(np.float32)
    res = sf.search(q, k=K, mode="all")
    de, _ = knn_exact(jnp.asarray(x_all), jnp.asarray(q), k=K)
    # Both paths use the f32 ||q||^2+||x||^2-2qx expansion but reassociate
    # differently (bucketed vs flat scan): ~5e-3 at these coordinate scales.
    np.testing.assert_allclose(
        res.dists, np.asarray(de), rtol=5e-3, atol=5e-3)
    print(f"  [{tag}] exact over {sf.n_total} objects "
          f"(mean buckets visited {res.stats['buckets_visited'].mean():.1f})")


def main() -> None:
    g = np.random.default_rng(42)
    x0, centers = seed_data(g)
    t0 = time.perf_counter()
    sf = OverlapIndex.build(x0, Config(
        index=IndexConfig(method="vbm", eps=2.5, min_pts=8),
        stream=StreamConfig(
            capacity=1024, monitor_method="dbm",
            xi_rebuild=0.55, fill_rebuild=0.8,
        ),
    ))
    print(f"seed forest: {sf.forest.n_indexes} indexes, {sf.forest.n_buckets} "
          f"buckets over {N_SEED} objects ({time.perf_counter() - t0:.1f}s build)")

    overlap_rebuilds = 0
    for bi, xb in enumerate(stream_batches(g, centers)):
        sf.ingest(xb)
        # queries keep flowing against forest+delta between maintenance
        q = (xb[:16] + 0.05 * g.normal(size=(16, DIM))).astype(np.float32)
        res = sf.search(q, k=K, mode="forest")
        assert (res.ids[:, 0] >= 0).all()

        report = sf.check()
        if report.should_rebuild:
            check_exact(sf, g, f"batch {bi:2d} pre-swap ")  # before the swap...
            sf.maintain()
            check_exact(sf, g, f"batch {bi:2d} post-swap")  # ...and right after
            reasons = sorted({r for v in sf.rebuild_log[-1]["reasons"].values()
                              for r in v})
            overlap_rebuilds += int("overlap" in reasons)
            print(f"  batch {bi:2d}: rebuilt {len(report.triggers)} indexes "
                  f"({'+'.join(reasons)}); worst rate "
                  f"{report.rates.max():.2f} -> "
                  f"{sf.monitor.rates_baseline.max():.2f}")
        elif bi % 4 == 3:
            check_exact(sf, g, f"batch {bi:2d} checkpoint")

    check_exact(sf, g, "final")
    s = sf.structure()
    print(f"ingested {sf.n_total - N_SEED} objects in {N_STREAM // BATCH} batches; "
          f"{s['rebuilds']} index rebuilds ({overlap_rebuilds} overlap-triggered), "
          f"{s['total_leaves']} buckets, delta fill {sum(s['delta_fill'])}")
    assert sf.n_total - N_SEED >= 10_000, "demo must stream >= 10k objects"
    assert overlap_rebuilds >= 1, "an overlap-triggered rebuild must fire"
    print("streaming ingest + online maintenance OK")


if __name__ == "__main__":
    main()
