"""Serving example: a production front over kNN-LM retrieval from the
paper's overlap-optimized datastore — continuous batching, per-request
deadlines, admission control, and load shedding, with the traffic
accounting read straight off the engine's metrics registry.

Two phases:

1. comfortable load — every request completes, books balance;
2. deliberate overload with deadlines — the engine sheds what cannot
   meet its budget (reject at submit / expire in queue / evict
   mid-flight) and the p99 of ADMITTED requests stays near the deadline.

    PYTHONPATH=src python examples/knn_serving.py
"""
import sys

sys.path.insert(0, "src")

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import RetrievalConfig
from repro.data.synthetic import embedding_datastore
from repro.models.model import Model
from repro.serve.engine import (
    SHED_EXPIRED_FLIGHT,
    SHED_EXPIRED_QUEUE,
    SHED_REJECTED,
    Request,
    ServeEngine,
)
from repro.serve.retrieval import build_flat_datastore


def make_requests(cfg, n, *, seed, deadline_s=None, rid0=0):
    g = np.random.default_rng(seed)
    return [
        Request(
            rid=rid0 + i,
            prompt=g.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32),
            max_new_tokens=12,
            deadline_s=deadline_s,
        )
        for i in range(n)
    ]


def main() -> None:
    cfg = get_smoke_config("qwen2-0.5b").replace(
        retrieval=RetrievalConfig(enabled=True, k=8, lam=0.3, datastore_size=4096))
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    # datastore keyed on hidden states (synthetic stand-in with token values)
    keys, values = embedding_datastore(4096, cfg.d_model, seed=1)
    values = values % cfg.vocab_size
    ds = build_flat_datastore(keys, values)

    engine = ServeEngine(model, params, num_slots=4, max_len=64, datastore=ds)

    # ---- phase 1: comfortable load, no deadlines -------------------------
    t0 = time.perf_counter()
    for r in make_requests(cfg, 10, seed=0):
        engine.submit(r)
    finished = engine.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in finished)
    print(f"served {len(finished)} requests, {tokens} tokens, "
          f"{engine.steps} batched decode steps, {dt:.1f}s wall "
          f"({tokens/dt:.1f} tok/s incl. compile)")
    for r in finished[:3]:
        print(f"  req {r.rid}: prompt {r.prompt[:4].tolist()}... -> "
              f"{r.out_tokens[:8]}... latency {r.latency_s*1e3:.0f}ms")
    assert all(len(r.out_tokens) >= r.max_new_tokens for r in finished)

    # ---- phase 2: overload with deadlines --------------------------------
    # Phase 1 taught the engine its decode-step time (a median over
    # measured steps — the same estimate admission control projects with).
    # Budget each request ~30 steps of latency, then offer 120 steps of
    # work at once: the engine must shed the excess instead of letting
    # every request's latency grow with the queue.
    deadline_s = 30.0 * engine.step_time_s()
    engine.reset_metrics()  # phase-2 books stand alone (drops compile noise)
    reqs = make_requests(cfg, 40, seed=1, deadline_s=deadline_s, rid0=100)
    admitted = [r for r in reqs if engine.submit(r)]
    finished2 = engine.run()
    done = [r for r in finished2 if r.done]

    m = engine.metrics()
    shed = {
        reason: engine.obs.value("serve.shed", reason=reason)
        for reason in (SHED_REJECTED, SHED_EXPIRED_QUEUE, SHED_EXPIRED_FLIGHT)
    }
    lat = m["histograms"]["serve.request_latency_s"]
    print(f"overload: {len(reqs)} offered with deadline "
          f"{deadline_s*1e3:.0f}ms, {len(admitted)} admitted, "
          f"{len(done)} completed, shed by reason: {shed}")
    print(f"  admitted-request latency p50/p99: "
          f"{lat['p50']*1e3:.0f}/{lat['p99']*1e3:.0f}ms "
          f"(completed requests only; shed waits tracked separately)")

    # the traffic books balance: nothing was silently dropped
    total_shed = sum(shed.values())
    assert engine.obs.value("serve.submitted") == (
        engine.obs.value("serve.completed") + total_shed)
    assert total_shed > 0, "overload phase should shed"
    print("deadline-aware serving OK")


if __name__ == "__main__":
    main()
