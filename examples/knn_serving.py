"""Serving example: batched requests against a small LM with kNN-LM
retrieval from the paper's overlap-optimized datastore fused into every
decode step (the paper's technique as a serving feature).

    PYTHONPATH=src python examples/knn_serving.py
"""
import sys

sys.path.insert(0, "src")

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import RetrievalConfig
from repro.data.synthetic import embedding_datastore
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine
from repro.serve.retrieval import build_flat_datastore


def main() -> None:
    cfg = get_smoke_config("qwen2-0.5b").replace(
        retrieval=RetrievalConfig(enabled=True, k=8, lam=0.3, datastore_size=4096))
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    # datastore keyed on hidden states (synthetic stand-in with token values)
    keys, values = embedding_datastore(4096, cfg.d_model, seed=1)
    values = values % cfg.vocab_size
    ds = build_flat_datastore(keys, values)

    engine = ServeEngine(model, params, num_slots=4, max_len=64, datastore=ds)
    g = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(10):
        engine.submit(Request(
            rid=rid,
            prompt=g.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32),
            max_new_tokens=12,
        ))
    finished = engine.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in finished)
    print(f"served {len(finished)} requests, {tokens} tokens, "
          f"{engine.steps} batched decode steps, {dt:.1f}s wall "
          f"({tokens/dt:.1f} tok/s incl. compile)")
    for r in finished[:3]:
        print(f"  req {r.rid}: prompt {r.prompt[:4].tolist()}... -> "
              f"{r.out_tokens[:8]}... latency {r.latency_s:.2f}s")
    assert all(len(r.out_tokens) >= r.max_new_tokens for r in finished)
    print("retrieval-augmented serving OK")


if __name__ == "__main__":
    main()
