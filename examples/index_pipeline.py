"""The paper's three-stage pipeline, step by step, with the overlap matrices
printed — the 'explainer' example.

    PYTHONPATH=src python examples/index_pipeline.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import dbscan, decide, overlap_matrix, partitions_from_labels
from repro.core.forest import build_forest
from repro.data.synthetic import ward_like


def main() -> None:
    x = ward_like(6_000)
    print(f"(i) preprocessing: DBSCAN over {len(x)} x {x.shape[1]} objects")
    res = dbscan(x, eps=2.0, min_pts=23)
    print(f"    {res.n_clusters} clusters, {(res.labels < 0).sum()} noise pts, "
          f"{res.n_iterations} propagation sweeps")
    pivots, radii, assign = partitions_from_labels(x, res.labels, res.n_clusters)

    print("(ii) overlap estimation (paper Defs. 7-11):")
    for method in ("vbm", "dbm", "obm"):
        rates = np.asarray(overlap_matrix(
            method, jnp.asarray(pivots), jnp.asarray(radii),
            x=jnp.asarray(x), assign=jnp.asarray(assign)))
        iu = np.triu_indices_from(rates, 1)
        print(f"    {method}: mean={rates[iu].mean():.4f} max={rates[iu].max():.4f} "
              f"pairs>0: {(rates[iu] > 0).sum()}/{len(iu[0])}")

    print("(iii) decision-making (xi_min=0.4, xi_max=0.8), VBM:")
    groups, stats = decide(x, pivots, radii, assign,
                           method="vbm", xi_min=0.4, xi_max=0.8)
    print(f"    merged pairs: {stats.n_merged_pairs}, overlap indexes: "
          f"{stats.n_overlap_indexes}, low-overlap moves: {stats.n_low_moves}")
    print(f"    final groups: {stats.n_final}")

    forest = build_forest(x, groups, c_max=int(np.sqrt(len(x))), pivot_method="gh")
    s = forest.aggregate_structure()
    print(f"    forest: {s['n_trees']} trees, {s['total_leaves']} buckets, "
          f"height {s['max_height']}, mean bucket fill {s['bucket_fill_mean']:.1f}")
    for i, g in enumerate(groups):
        tag = " (overlap index)" if g.is_overlap_index else ""
        print(f"      index {i}: {len(g.members)} objects, r={g.radius:.2f}, "
              f"neighbors={g.neighbors}{tag}")


if __name__ == "__main__":
    main()
