"""Quickstart: build the paper's overlap-optimized index over synthetic IoT
data through the ``OverlapIndex`` facade, run kNN queries with all three
heuristics, compare against the BCCF baseline and exact brute force, and
round-trip the index through save/load.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import os
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.api import Config, IndexConfig, OverlapIndex
from repro.core import knn_exact
from repro.data.synthetic import tracking_like


def main() -> None:
    x = tracking_like(8_000)
    print(f"dataset: {x.shape[0]} objects, {x.shape[1]} dims (Tracking-like IoVT)")

    g = np.random.default_rng(0)
    q = x[g.choice(len(x), 32)] + 0.05 * g.normal(size=(32, x.shape[1])).astype(np.float32)
    d_exact, i_exact = knn_exact(jnp.asarray(x), jnp.asarray(q), k=10)
    i_exact = np.asarray(i_exact)

    ix = None
    for method in ("vbm", "dbm", "obm"):
        cfg = Config(index=IndexConfig(
            method=method, eps=6.0, min_pts=16, xi_min=0.4, xi_max=0.8))
        ix = OverlapIndex.build(x, cfg)
        res = ix.search(q, k=10)
        recall = np.mean([
            len(set(res.ids[i].tolist()) & set(i_exact[i].tolist())) / 10
            for i in range(len(q))
        ])
        rep = ix.build_report
        print(
            f"{method.upper()}: {rep.n_indexes} indexes "
            f"({rep.n_overlap_indexes} overlap), build dists "
            f"{rep.tree_distances:,}, search dists/query "
            f"{res.stats['distances'].mean():.0f}, recall@10 {recall:.3f}"
        )

    baseline = OverlapIndex.baseline(x)  # documented BCCF 2-means baseline
    res = baseline.search(q, k=10, mode="all")
    print(
        f"BCCF baseline: build dists {baseline.build_report.tree_distances:,}, "
        f"search dists/query {res.stats['distances'].mean():.0f}, recall@10 1.000"
    )

    # persistence: a loaded index answers bitwise-identically, no rebuild
    want = ix.search(q, k=10)
    with tempfile.TemporaryDirectory() as tmp:
        path = ix.save(os.path.join(tmp, "index.npz"))
        got = OverlapIndex.load(path).search(q, k=10)
    assert np.array_equal(want.dists, got.dists)
    assert np.array_equal(want.ids, got.ids)
    print(f"save/load round-trip: bitwise-identical search after restart ({ix})")


if __name__ == "__main__":
    main()
