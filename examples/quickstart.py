"""Quickstart: build the paper's overlap-optimized index over synthetic IoT
data, run kNN queries with all three heuristics, compare against the BCCF
baseline and exact brute force.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import (
    IndexConfig,
    build_baseline,
    build_index,
    knn_exact,
    knn_search_host,
)
from repro.data.synthetic import tracking_like


def main() -> None:
    x = tracking_like(8_000)
    print(f"dataset: {x.shape[0]} objects, {x.shape[1]} dims (Tracking-like IoVT)")

    g = np.random.default_rng(0)
    q = x[g.choice(len(x), 32)] + 0.05 * g.normal(size=(32, x.shape[1])).astype(np.float32)
    d_exact, i_exact = knn_exact(jnp.asarray(x), jnp.asarray(q), k=10)
    i_exact = np.asarray(i_exact)

    for method in ("vbm", "dbm", "obm"):
        cfg = IndexConfig(method=method, eps=6.0, min_pts=16, xi_min=0.4, xi_max=0.8)
        forest, report = build_index(x, cfg)
        d, ids, stats = knn_search_host(forest, q, k=10)
        recall = np.mean([
            len(set(ids[i].tolist()) & set(i_exact[i].tolist())) / 10
            for i in range(len(q))
        ])
        print(
            f"{method.upper()}: {report.n_indexes} indexes "
            f"({report.n_overlap_indexes} overlap), build dists "
            f"{report.tree_distances:,}, search dists/query "
            f"{stats['distances'].mean():.0f}, recall@10 {recall:.3f}"
        )

    baseline, brep = build_baseline(x)
    d, ids, stats = knn_search_host(baseline, q, k=10, mode="all")
    print(
        f"BCCF baseline: build dists {brep.tree_distances:,}, "
        f"search dists/query {stats['distances'].mean():.0f}, recall@10 1.000"
    )


if __name__ == "__main__":
    main()
