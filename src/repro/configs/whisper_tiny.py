"""whisper-tiny [audio; arXiv:2212.04356]: enc-dec, 4+4 layers, d=384, 6H,
d_ff=1536, vocab 51865.  Conv frontend is a STUB: input_specs() provides
precomputed (B, 1500, 384) frame embeddings (per assignment)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,          # decoder layers
    encoder_layers=4,
    encoder_seq=1500,
    frontend="audio_stub",
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    qkv_bias=True,
    tie_embeddings=True,
    attn_tp=False,         # 6 heads don't divide 16-way TP; DP/FSDP + mlp TP
    param_dtype="float32",
    optimizer="adamw",
    remat="full",
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, encoder_layers=2, encoder_seq=16, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=256, remat="none",
)
