"""qwen2-0.5b [dense; arXiv:2407.10671]: 24L, d=896, 14H GQA kv=2,
d_ff=4864, vocab 151936, QKV bias, tied embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    attn_tp=False,  # 14 heads don't divide 16-way TP
    param_dtype="float32",
    optimizer="adamw",
    remat="full",
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256, remat="none",
)
