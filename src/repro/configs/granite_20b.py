"""granite-20b [dense; arXiv:2405.04324]: code model, 52L, d=6144, 48H,
MQA (kv=1), d_ff=24576, vocab 49152."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    param_dtype="bfloat16",
    optimizer="adafactor",
    remat="full",
    seq_shard_activations=True,
    grad_accum=4,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=256,
    param_dtype="float32", remat="none", grad_accum=1, seq_shard_activations=False,
)
