"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full published configuration;
``get_smoke_config(arch_id)`` returns a reduced same-family configuration
for CPU smoke tests (small widths/layers/experts, identical code paths).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (
    SHAPES,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RetrievalConfig,
    RWKVConfig,
    ShapeConfig,
    SSMConfig,
    shape_applicable,
)

ARCH_IDS = [
    "whisper-tiny",
    "pixtral-12b",
    "jamba-1.5-large-398b",
    "smollm-135m",
    "granite-20b",
    "qwen2-0.5b",
    "deepseek-67b",
    "rwkv6-3b",
    "deepseek-v2-236b",
    "qwen3-moe-235b-a22b",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch]).SMOKE_CONFIG


__all__ = [
    "ARCH_IDS", "get_config", "get_smoke_config", "SHAPES", "ShapeConfig",
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "RWKVConfig",
    "RetrievalConfig", "shape_applicable",
]
