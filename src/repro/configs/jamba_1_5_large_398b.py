"""jamba-1.5-large-398b [hybrid; arXiv:2403.19887]: 72L, d=8192, 64H GQA
kv=8, d_ff=24576, MoE 16 experts top-2.  Mamba:attention 7:1 interleave
(one attention layer per 8-layer group, offset 4, as in the Jamba paper);
MoE on every other layer (period 2, first layer dense)."""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    attn_period=8,
    attn_offset=4,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576, moe_period=2, first_dense=1),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    param_dtype="bfloat16",
    optimizer="adafactor",
    remat="full",
    seq_shard_activations=True,
    grad_accum=8,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=2.0, d_ff_expert=64, moe_period=2, first_dense=1),
    ssm=SSMConfig(d_state=4, d_conv=4, expand=2),
    param_dtype="float32", remat="none", grad_accum=1, seq_shard_activations=False,
)
