"""deepseek-67b [dense; arXiv:2401.02954]: llama-arch, 95L, d=8192, 64H GQA
kv=8, d_ff=22016, vocab 102400."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102400,
    param_dtype="bfloat16",
    optimizer="adafactor",
    remat="full",
    seq_shard_activations=True,
    grad_accum=8,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    param_dtype="float32", remat="none", grad_accum=1, seq_shard_activations=False,
)
