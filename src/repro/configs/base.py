"""Model / run configuration schema for the architecture zoo."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0          # shared (always-on) experts, deepseek-v2 style
    capacity_factor: float = 1.25
    moe_period: int = 1          # every `moe_period`-th layer is MoE
    first_dense: int = 0         # first k layers use dense FFN
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:  # Mamba-1 (Jamba's mixer)
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class RWKVConfig:  # RWKV-6 "Finch"
    head_dim: int = 64
    decay_lora: int = 64
    gate_lora: int = 64


@dataclass(frozen=True)
class RetrievalConfig:
    """kNN-LM datastore retrieval (the paper's technique at the LM head)."""

    enabled: bool = False
    k: int = 8
    lam: float = 0.25           # p = lam * p_knn + (1 - lam) * p_lm
    temperature: float = 10.0
    datastore_size: int = 65536  # per model shard
    key_dim: int = 0             # 0 -> d_model
    quantized: bool = False      # int8 datastore (beyond-paper)
    kernel: bool = True          # route distances through kernels/ops dispatch


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    rwkv: RWKVConfig | None = None
    attn_period: int = 1         # hybrid: one attention layer per attn_period
    attn_offset: int = 0         # position of the attention layer in the group
    encoder_layers: int = 0      # enc-dec only
    encoder_seq: int = 1500      # stub frontend sequence length
    frontend: str | None = None  # audio_stub | vision_stub
    num_stub_patches: int = 256  # vlm stub patches replacing leading tokens
    tie_embeddings: bool = False
    # --- numerics / memory policy ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"          # full | dots | none
    scan_layers: bool = True
    # --- sharding profile ---
    attn_tp: bool = True         # shard attention heads over 'tensor'
    mlp_tp: bool = True
    seq_shard_activations: bool = False  # sequence-shard residual stream
    constrain_sublayer_outputs: bool = False  # force RS (not AR) after TP ops
    moe_a2a: bool = False        # all-to-all EP dispatch (vs psum combine)
    grad_accum: int = 1
    optimizer: str = "adamw"     # adamw | adafactor
    retrieval: RetrievalConfig = field(default_factory=RetrievalConfig)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab_size // 128) * 128

    def is_attn_layer(self, i: int) -> bool:
        """Hybrid interleave: which decoder layers are attention."""
        if self.family != "hybrid":
            return self.family != "ssm"
        return i % self.attn_period == self.attn_offset

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None or i < self.moe.first_dense:
            return False
        return (i - self.moe.first_dense) % self.moe.moe_period == 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# long_500k needs sub-quadratic attention: SSM / hybrid only (DESIGN.md §5).
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return model.family in LONG_CONTEXT_FAMILIES
    return True
