"""smollm-135m [dense; hf:HuggingFaceTB/SmolLM-135M]: llama-arch small,
30L, d=576, 9H GQA kv=3, d_ff=1536, vocab 49152."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
    attn_tp=False,  # 9 heads don't divide 16-way TP
    param_dtype="float32",
    optimizer="adamw",
    remat="full",
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=48, num_heads=3, num_kv_heads=3, d_ff=96,
    vocab_size=256, remat="none",
)
