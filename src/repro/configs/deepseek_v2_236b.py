"""deepseek-v2-236b [moe; arXiv:2405.04434]: 60L, d=5120, 128H MLA
(kv_lora=512, rope 64, nope 128, v 128), MoE 160 routed top-6 + 2 shared
(expert d_ff 1536), first layer dense (d_ff 12288), vocab 102400."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,   # MLA: per-head KV decompressed from the latent
    d_ff=12288,         # the single dense layer's FFN width
    vocab_size=102400,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, d_ff_expert=1536, num_shared=2,
                  first_dense=1),
    param_dtype="bfloat16",
    optimizer="adafactor",
    remat="full",
    seq_shard_activations=True,
    grad_accum=8,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=256,
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=8,
                  nope_head_dim=16, v_head_dim=16),
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=4.0, d_ff_expert=32, num_shared=2,
                  first_dense=1),
    param_dtype="float32", remat="none", grad_accum=1, seq_shard_activations=False,
)
