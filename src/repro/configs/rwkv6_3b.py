"""rwkv6-3b "Finch" [ssm; arXiv:2404.05892]: attention-free, 32L, d=2560,
data-dependent per-channel decay, d_ff=8960, vocab 65536."""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,        # d_model / rwkv.head_dim
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, gate_lora=64),
    attn_tp=False,       # per-head state ops stay local; channel-mix has TP
    param_dtype="float32",
    optimizer="adamw",
    remat="full",
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=256, rwkv=RWKVConfig(head_dim=16, decay_lora=8, gate_lora=8),
    remat="none",
)
