"""pixtral-12b [vlm; hf:mistralai/Pixtral-12B-2409]: 40L, d=5120, 32H GQA
kv=8, d_ff=14336, vocab 131072.  Pixtral-ViT frontend is a STUB: input
patch embeddings are provided precomputed (per assignment)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    frontend="vision_stub",
    num_stub_patches=256,
    param_dtype="bfloat16",
    optimizer="adafactor",
    remat="full",
    seq_shard_activations=True,
    grad_accum=4,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, num_stub_patches=4,
    param_dtype="float32", remat="none", grad_accum=1, seq_shard_activations=False,
)
