"""qwen3-moe-235b-a22b [moe; hf:Qwen/Qwen3-30B-A3B family]: 94L, d=4096,
64H GQA kv=4 (head_dim 128), 128 experts top-8 (expert d_ff 1536),
vocab 151936."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536),
    param_dtype="bfloat16",
    optimizer="adafactor",
    remat="full",
    seq_shard_activations=True,
    grad_accum=8,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=256,
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=4.0, d_ff_expert=32),
    param_dtype="float32", remat="none", grad_accum=1, seq_shard_activations=False,
)
