"""Metrics export surface: Prometheus text rendering + the events CLI.

``render_prometheus(snapshot)`` turns a ``Registry.snapshot()`` dict into
the Prometheus text exposition format — counters and gauges verbatim,
histograms as summaries (``{quantile="0.5"}`` samples plus ``_sum`` /
``_count`` / ``_min`` / ``_max``).  Metric names sanitize dots and span
slashes to underscores (``search/plan_lookup`` -> ``search_plan_lookup``);
label values are quoted and escaped.  ``parse_prometheus`` is the inverse
reader the ``--check`` gate round-trips through — rendering that does not
parse is a bug worth failing CI over.

The CLI summarizes runs:

    python -m repro.obs.export --events obs.jsonl            # span table
    python -m repro.obs.export --events obs.jsonl --format prometheus
    python -m repro.obs.export --events obs.jsonl --check    # CI gate
    python -m repro.obs.export --events obs.jsonl --traces   # list ids
    python -m repro.obs.export --events obs.jsonl --trace ID # one tree
    python -m repro.obs.export --snapshot metrics.json --format prometheus

``--events`` reads a span JSONL (rotations included), aggregates every
span path into a latency histogram, and prints a per-span table
(count / total / mean / p50 / p95 / p99 / max).  ``--snapshot`` renders a
saved ``Registry.snapshot()`` (or an ``OverlapIndex.metrics()`` dump — its
``registry`` section is detected) without needing the live process.
"""
from __future__ import annotations

import argparse
import json
import math
import re
import sys
from typing import Any, Iterable

from repro.obs.events import EventLog, events_path_from_env

__all__ = [
    "render_prometheus",
    "parse_prometheus",
    "span_table",
    "render_span_table",
    "main",
]

_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")
_SAMPLE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^{}]*)\})?"
    r"\s+(?P<value>[^\s]+)$"
)
_LABEL = re.compile(r'^(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>(?:[^"\\]|\\.)*)"$')

_QUANTILES = (("0.5", "p50"), ("0.95", "p95"), ("0.99", "p99"))


def _sanitize(name: str) -> str:
    name = _NAME_OK.sub("_", name)
    if not name or not re.match(r"[a-zA-Z_:]", name[0]):
        name = "_" + name
    return name


def _split_key(key: str) -> tuple[str, dict[str, str]]:
    """Invert ``metrics._fmt``: ``name{k=v,...}`` -> (name, labels)."""
    if "{" not in key:
        return key, {}
    name, _, rest = key.partition("{")
    labels: dict[str, str] = {}
    for part in rest.rstrip("}").split(","):
        if part:
            k, _, v = part.partition("=")
            labels[k] = v
    return name, labels


def _esc(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"')


def _fmt_labels(labels: dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{_sanitize(k)}="{_esc(str(v))}"' for k, v in labels.items())
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if isinstance(v, float) and math.isnan(v):
        return "NaN"
    if isinstance(v, float) and math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v)) if isinstance(v, float) else str(v)


def render_prometheus(snapshot: dict[str, Any]) -> str:
    """``Registry.snapshot()`` -> Prometheus text format (see module doc)."""
    lines: list[str] = []
    for key, val in snapshot.get("counters", {}).items():
        name, labels = _split_key(key)
        pname = _sanitize(name)
        lines.append(f"# TYPE {pname} counter")
        lines.append(f"{pname}{_fmt_labels(labels)} {_fmt_value(val)}")
    for key, val in snapshot.get("gauges", {}).items():
        name, labels = _split_key(key)
        pname = _sanitize(name)
        lines.append(f"# TYPE {pname} gauge")
        lines.append(f"{pname}{_fmt_labels(labels)} {_fmt_value(val)}")
    for key, h in snapshot.get("histograms", {}).items():
        name, labels = _split_key(key)
        pname = _sanitize(name)
        lines.append(f"# TYPE {pname} summary")
        for q, field in _QUANTILES:
            qlabels = {**labels, "quantile": q}
            lines.append(
                f"{pname}{_fmt_labels(qlabels)} {_fmt_value(h[field])}"
            )
        lines.append(f"{pname}_sum{_fmt_labels(labels)} {_fmt_value(h['sum'])}")
        lines.append(
            f"{pname}_count{_fmt_labels(labels)} {_fmt_value(h['count'])}"
        )
        lines.append(f"{pname}_min{_fmt_labels(labels)} {_fmt_value(h['min'])}")
        lines.append(f"{pname}_max{_fmt_labels(labels)} {_fmt_value(h['max'])}")
    return "\n".join(lines) + ("\n" if lines else "")


def parse_prometheus(text: str) -> list[dict[str, Any]]:
    """Parse text-format samples back into ``{name, labels, value}`` dicts.

    Raises ``ValueError`` naming the offending line on anything malformed —
    this is the ``--check`` gate's teeth, not a lenient scraper."""
    samples: list[dict[str, Any]] = []
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: not a metric sample: {line!r}")
        labels: dict[str, str] = {}
        raw = m.group("labels")
        if raw:
            for part in _split_label_pairs(raw, lineno):
                lm = _LABEL.match(part)
                if lm is None:
                    raise ValueError(
                        f"line {lineno}: bad label pair {part!r} in {line!r}"
                    )
                labels[lm.group("k")] = lm.group("v")
        try:
            value = float(m.group("value"))
        except ValueError as e:
            raise ValueError(
                f"line {lineno}: bad value {m.group('value')!r}"
            ) from e
        samples.append(
            {"name": m.group("name"), "labels": labels, "value": value}
        )
    return samples


def _split_label_pairs(raw: str, lineno: int) -> Iterable[str]:
    """Split ``k1="v1",k2="v2"`` at commas outside quotes."""
    out, buf, in_q, esc = [], [], False, False
    for ch in raw:
        if esc:
            buf.append(ch)
            esc = False
        elif ch == "\\":
            buf.append(ch)
            esc = True
        elif ch == '"':
            buf.append(ch)
            in_q = not in_q
        elif ch == "," and not in_q:
            out.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    if in_q:
        raise ValueError(f"line {lineno}: unterminated label value in {raw!r}")
    if buf:
        out.append("".join(buf))
    return out


# ---------------------------------------------------------------------------
# events JSONL -> per-span latency table
# ---------------------------------------------------------------------------


def span_table(records: list[dict[str, Any]]) -> dict[str, dict[str, float]]:
    """Aggregate span events into per-path latency summaries (sorted by
    total time descending — where the run went)."""
    from repro.obs.metrics import Histogram

    hists: dict[str, Histogram] = {}
    for r in records:
        if r.get("event") != "span":
            continue
        h = hists.get(r["span"])
        if h is None:
            h = hists[r["span"]] = Histogram()
        h.observe(float(r.get("dur_s", 0.0)))
    table = {name: h.snapshot() for name, h in hists.items()}
    return dict(
        sorted(table.items(), key=lambda kv: kv[1]["sum"], reverse=True)
    )


def render_span_table(table: dict[str, dict[str, float]]) -> str:
    if not table:
        return "(no span events)"
    width = max(len(n) for n in table)
    head = (f"{'span':<{width}}  {'count':>7}  {'total_s':>9}  {'mean_ms':>9}  "
            f"{'p50_ms':>9}  {'p95_ms':>9}  {'p99_ms':>9}  {'max_ms':>9}")
    lines = [head, "-" * len(head)]
    for name, s in table.items():
        lines.append(
            f"{name:<{width}}  {s['count']:>7d}  {s['sum']:>9.4f}  "
            f"{s['mean'] * 1e3:>9.3f}  {s['p50'] * 1e3:>9.3f}  "
            f"{s['p95'] * 1e3:>9.3f}  {s['p99'] * 1e3:>9.3f}  "
            f"{s['max'] * 1e3:>9.3f}"
        )
    return "\n".join(lines)


def _snapshot_from_events(records: list[dict[str, Any]]) -> dict[str, Any]:
    """A synthetic registry snapshot aggregated from span events, so
    ``--events --format prometheus`` works without the live registry."""
    return {
        "enabled": True,
        "counters": {},
        "gauges": {},
        "histograms": span_table(records),
    }


def _load_snapshot(path: str) -> dict[str, Any]:
    with open(path) as f:
        d = json.load(f)
    if "registry" in d and isinstance(d["registry"], dict):
        d = d["registry"]  # an OverlapIndex.metrics() dump
    if "histograms" not in d and "counters" not in d:
        raise ValueError(
            f"{path} is not a Registry.snapshot() (or metrics()) JSON dump"
        )
    return d


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.export",
        description="Summarize/export repro.obs telemetry "
        "(span tables, Prometheus text format, trace trees).",
    )
    ap.add_argument(
        "--events",
        help="span/event JSONL (rotations included); defaults to "
        "$REPRO_OBS_EVENTS when set — the same variable the writers honor, "
        "so CI can gate the log it just produced without re-plumbing paths",
    )
    ap.add_argument(
        "--snapshot", help="Registry.snapshot() or OverlapIndex.metrics() JSON"
    )
    ap.add_argument(
        "--format", choices=("table", "prometheus", "json"), default="table"
    )
    ap.add_argument(
        "--check", action="store_true",
        help="render Prometheus output and round-trip it through the "
        "parser; exit non-zero on any malformed sample (CI gate)",
    )
    ap.add_argument("--traces", action="store_true", help="list trace ids")
    ap.add_argument("--trace", help="render one reconstructed trace tree")
    args = ap.parse_args(argv)

    if not args.events:
        args.events = events_path_from_env()
    if not args.events and not args.snapshot:
        ap.error("need --events and/or --snapshot")
    if (args.traces or args.trace) and not args.events:
        ap.error("--traces/--trace need --events")

    records: list[dict[str, Any]] = []
    if args.events:
        records = EventLog.read(args.events)

    if args.traces:
        from repro.obs.trace import Trace

        for tid in Trace.trace_ids(args.events):
            print(tid)
        return 0
    if args.trace:
        from repro.obs.trace import Trace

        t = Trace.reconstruct(args.events, args.trace)
        if not t.records:
            print(f"trace {args.trace!r} not found in {args.events}",
                  file=sys.stderr)
            return 1
        print(t.render())
        return 0

    snap = (
        _load_snapshot(args.snapshot)
        if args.snapshot
        else _snapshot_from_events(records)
    )

    if args.check:
        text = render_prometheus(snap)
        try:
            samples = parse_prometheus(text)
        except ValueError as e:
            print(f"prometheus rendering FAILED to parse: {e}", file=sys.stderr)
            return 1
        print(f"prometheus render OK ({len(samples)} samples"
              f"{f', {len(records)} events' if args.events else ''})")
        if args.events:
            print(render_span_table(span_table(records)))
        return 0

    if args.format == "prometheus":
        sys.stdout.write(render_prometheus(snap))
    elif args.format == "json":
        print(json.dumps(snap, indent=2, sort_keys=True))
    else:
        if args.events:
            print(render_span_table(span_table(records)))
        else:
            print(json.dumps(snap, indent=2, sort_keys=True))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
