"""Unified telemetry layer: metrics registry, phase spans, JSONL events.

    from repro.obs import Registry

    reg = Registry()
    with reg.span("search"):
        with reg.span("device_execute"):
            ...                       # -> histogram "search/device_execute"
    reg.counter("search.queries").inc(64)
    reg.gauge("serve.queue_depth").set(3)
    reg.snapshot()                    # one nested, JSON-serializable dict

Consumed by ``repro.api.OverlapIndex`` (per-phase search/ingest/maintain
spans + per-island node-access counters, exposed via ``.metrics()``) and
``repro.serve.ServeEngine`` (latency histograms + queue/slot gauges).
See README.md in this directory for metric names and overhead notes.

Adjacent modules: ``repro.obs.trace`` (per-request trace propagation +
``Trace.reconstruct`` over the JSONL events), ``repro.obs.attribution``
(contributing/wasted visit classification behind ``OverlapIndex.explain``),
``repro.obs.export`` (Prometheus text rendering + the
``python -m repro.obs.export`` CLI).
"""
from repro.obs.events import EventLog, events_path_from_env
from repro.obs.metrics import Counter, Gauge, Histogram, Registry
from repro.obs.trace import (
    SpanNode,
    Trace,
    TraceContext,
    TraceSampler,
    current_trace,
    new_trace,
    use_trace,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "EventLog",
    "events_path_from_env",
    "SpanNode",
    "Trace",
    "TraceContext",
    "TraceSampler",
    "current_trace",
    "new_trace",
    "use_trace",
]
