"""Overlap attribution: classify every bucket visit of a search as
*contributing* or *wasted*, and charge the waste to partition pairs.

The paper's argument is causal — partition overlap drives node accesses,
node accesses drive search time — but fleet counters can't say WHICH
overlapping pair cost WHICH queries what.  This post-pass closes that gap
from evidence the executor already computed (``core.knn.VisitRows``: the
sorted visit orders + per-phase visit counts; see its docstring for the
prefix-decode invariant):

  contributing visit — at least one member of the visited bucket survived
      into the query's final top-k.  The visit was necessary under the
      scan's ordering: it supplied an answer.
  wasted visit — the bucket was scanned (its lower bound beat the running
      kth-best at visit time) but no member survived.  These are exactly
      the accesses overlap optimization exists to remove.

Every visit is one or the other, so per query

    contributing + wasted == SearchStats.buckets_visited      (gated in-suite)

Wasted visits are then attributed to the (visited_index, home_index) pair
— home is the index the query routes to — and weighted against the
registered VBM/DBM/OBM overlap-rate matrix: a pair with high waste AND a
high overlap score is the decision stage's merge/extract candidate; high
waste with a LOW score means the heuristic under-prices that pair (the
learned-overlap ROADMAP item's training signal).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import numpy as np

__all__ = ["ExplainReport", "attribute_visits"]


@dataclass
class ExplainReport:
    """One ``OverlapIndex.explain`` call's attribution (host numpy).

    ``visited_pair[j, i]`` / ``wasted_pair[j, i]`` count visits of buckets
    owned by index ``j`` on behalf of queries homed at index ``i`` (the
    diagonal is intra-index work; off-diagonal is overlap-induced).
    """

    contributing: np.ndarray  # (Q,) i64 contributing visits per query
    wasted: np.ndarray  # (Q,) i64 wasted visits per query
    home: np.ndarray  # (Q,) i64 routed home index per query
    visited_pair: np.ndarray  # (I, I) i64 visits by (visited, home)
    wasted_pair: np.ndarray  # (I, I) i64 wasted visits by (visited, home)
    rates: np.ndarray | None  # (I, I) overlap-rate matrix, or None
    method: str = ""  # overlap method the rates came from
    result: Any = None  # the run's SearchResult (facade attaches it)

    @property
    def queries(self) -> int:
        return len(self.contributing)

    @property
    def total_visits(self) -> int:
        return int(self.contributing.sum() + self.wasted.sum())

    @property
    def wasted_fraction(self) -> float:
        tot = self.total_visits
        return float(self.wasted.sum()) / tot if tot else 0.0

    def top_pairs(self, n: int = 10) -> list[dict[str, Any]]:
        """The worst (visited, home) pairs by wasted visits, each with its
        overlap-rate score — the decision stage's work list."""
        j, i = np.unravel_index(
            np.argsort(self.wasted_pair, axis=None)[::-1], self.wasted_pair.shape
        )
        out = []
        for jj, ii in zip(j[:n], i[:n]):
            w = int(self.wasted_pair[jj, ii])
            if w == 0:
                break
            out.append({
                "visited": int(jj),
                "home": int(ii),
                "wasted": w,
                "visits": int(self.visited_pair[jj, ii]),
                "rate": (
                    None if self.rates is None else float(self.rates[jj, ii])
                ),
            })
        return out

    def to_dict(self) -> dict[str, Any]:
        """JSON-serializable rollup (the ``metrics()['overlap_health']``
        shape, minus the lifetime accumulation)."""
        return {
            "queries": self.queries,
            "contributing": int(self.contributing.sum()),
            "wasted": int(self.wasted.sum()),
            "wasted_fraction": self.wasted_fraction,
            "method": self.method,
            "top_pairs": self.top_pairs(),
        }


def _id_locations(
    n_ids: int,
    bucket_ids: np.ndarray,
    bucket_mask: np.ndarray,
    delta_ids: np.ndarray | None,
    delta_count: np.ndarray | None,
) -> tuple[np.ndarray, np.ndarray]:
    """Object id -> (main bucket row | -1, delta index row | -1).

    Bucket/delta membership is a strict partition of the live objects, so
    each id appears in exactly one of the two maps."""
    id_main = np.full(n_ids, -1, np.int64)
    m = np.asarray(bucket_mask, bool)
    ids = np.asarray(bucket_ids)
    rows = np.repeat(np.arange(ids.shape[0], dtype=np.int64), m.sum(axis=1))
    id_main[ids[m].astype(np.int64)] = rows
    id_delta = np.full(n_ids, -1, np.int64)
    if delta_ids is not None:
        d_ids = np.asarray(delta_ids)
        d_cnt = np.asarray(delta_count)
        for i in range(d_ids.shape[0]):
            c = int(d_cnt[i])
            if c:
                id_delta[d_ids[i, :c].astype(np.int64)] = i
    return id_main, id_delta


def attribute_visits(
    *,
    order: np.ndarray,
    visits: np.ndarray,
    dorder: np.ndarray | None,
    dvisits: np.ndarray | None,
    result_ids: np.ndarray,
    home: np.ndarray,
    n_indexes: int,
    bucket_index: np.ndarray,
    bucket_ids: np.ndarray,
    bucket_mask: np.ndarray,
    main_rows_per_shard: int,
    delta_rows_per_shard: int = 0,
    delta_ids: np.ndarray | None = None,
    delta_count: np.ndarray | None = None,
    rates: np.ndarray | None = None,
    method: str = "",
) -> ExplainReport:
    """Decode ``VisitRows`` (host numpy) and attribute every visit.

    ``order``/``dorder`` are the col-stacked per-shard-local sorted visit
    orders, ``visits``/``dvisits`` the (S, Q) per-phase visit counts (see
    ``core.knn.VisitRows``).  ``main_rows_per_shard`` is the PADDED bucket
    row count per shard (global row = local + shard * that);
    ``delta_rows_per_shard`` likewise for the delta phase.  ``home`` is
    each query's routed index; ``result_ids`` the final top-k (−1 pad).

    A query whose eligible buckets hold fewer than k members keeps scanning
    past the +inf lower bounds (inf <= inf), so decoded visits CAN land on
    ineligible rows and — under the sharded layout — on shard-alignment
    padding rows (owner = sentinel index I).  Padding rows hold no members,
    so such visits are always wasted; they stay in the per-query wasted
    counts (conservation against ``buckets_visited`` holds) but out of the
    (visited, home) pair matrices, since no real index owns them.
    """
    order = np.asarray(order)
    visits = np.asarray(visits)
    S, Q = visits.shape
    W = order.shape[1] // S
    Wd = 0
    if dorder is not None:
        dorder = np.asarray(dorder)
        dvisits = np.asarray(dvisits)
        Wd = dorder.shape[1] // S
    result_ids = np.asarray(result_ids)
    home = np.asarray(home, np.int64)
    bucket_index = np.asarray(bucket_index, np.int64)

    n_ids = max(
        int(np.asarray(bucket_ids).max(initial=-1)) + 1,
        int(result_ids.max(initial=-1)) + 1,
        (0 if delta_ids is None
         else int(np.asarray(delta_ids).max(initial=-1)) + 1),
        1,
    )
    id_main, id_delta = _id_locations(
        n_ids, bucket_ids, bucket_mask, delta_ids, delta_count
    )

    contributing = np.zeros(Q, np.int64)
    wasted = np.zeros(Q, np.int64)
    visited_pair = np.zeros((n_indexes, n_indexes), np.int64)
    wasted_pair = np.zeros((n_indexes, n_indexes), np.int64)

    for q in range(Q):
        surv = result_ids[q]
        surv = surv[surv >= 0].astype(np.int64)
        surv_main = set(id_main[surv][id_main[surv] >= 0].tolist())
        surv_delta = set(id_delta[surv][id_delta[surv] >= 0].tolist())
        h = int(home[q])
        for s in range(S):
            v = int(visits[s, q])
            if v:
                rows = (
                    order[q, s * W: s * W + v].astype(np.int64)
                    + s * main_rows_per_shard
                )
                real = rows < len(bucket_index)  # pad rows: sentinel owner
                owners = np.where(real, bucket_index[np.minimum(
                    rows, len(bucket_index) - 1)], n_indexes)
                hit = np.fromiter(
                    (r in surv_main for r in rows.tolist()), bool, len(rows)
                )
                contributing[q] += int(hit.sum())
                wasted[q] += int((~hit).sum())
                attr = owners < n_indexes  # no real index owns a pad row
                np.add.at(visited_pair, (owners[attr], h), 1)
                np.add.at(wasted_pair, (owners[~hit & attr], h), 1)
            if dorder is None:
                continue
            dv = int(dvisits[s, q])
            if dv:
                drows = (
                    dorder[q, s * Wd: s * Wd + dv].astype(np.int64)
                    + s * delta_rows_per_shard
                )
                # a delta row IS its owning index (one tail bucket per index;
                # rows >= n_indexes are shard-alignment padding)
                hit = np.fromiter(
                    (r in surv_delta for r in drows.tolist()), bool, len(drows)
                )
                contributing[q] += int(hit.sum())
                wasted[q] += int((~hit).sum())
                attr = drows < n_indexes
                np.add.at(visited_pair, (drows[attr], h), 1)
                np.add.at(wasted_pair, (drows[~hit & attr], h), 1)

    return ExplainReport(
        contributing=contributing,
        wasted=wasted,
        home=home,
        visited_pair=visited_pair,
        wasted_pair=wasted_pair,
        rates=None if rates is None else np.asarray(rates),
        method=method,
    )
