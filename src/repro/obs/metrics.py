"""Dependency-free metrics primitives: counters, gauges, streaming
histograms, and phase-span timers, behind one ``Registry``.

The paper's whole argument is measured in observability terms — overlap
reduction is proven by node-access counts and search time — but until this
layer the repo's instrumentation was scattered shards (``SearchStats`` in
core, ``ingest_stats()`` on the facade, ``PlanCache.stats()``, raw
``perf_counter`` calls in serve).  Everything now registers into one
``Registry`` per owner object (``OverlapIndex``, ``ServeEngine``), and one
``snapshot()`` shows the coherent picture.

Design constraints, in order:

  * zero hot-path cost when disabled — a disabled registry hands out
    shared null metric objects whose methods are no-ops, and ``span()``
    short-circuits before touching the clock;
  * no effect on computation — every metric is HOST-side bookkeeping; the
    jitted executors are untouched, so a metrics-enabled search returns
    bitwise-identical results to a metrics-off search (tested);
  * exact percentiles where it matters — ``Histogram`` keeps a windowed
    reservoir of the last ``window`` observations and computes p50/p95/p99
    with numpy's linear interpolation rule over that window (exact, and
    testable against ``np.percentile``, whenever fewer than ``window``
    values were seen); count/sum/min/max are lifetime-exact regardless.

Spans nest: ``with reg.span("search"): with reg.span("plan_lookup"): ...``
records a duration histogram under the path ``"search/plan_lookup"`` — the
nesting stack is per-thread, so concurrent engines don't interleave paths.
"""
from __future__ import annotations

import math
import threading
import time
from contextlib import contextmanager
from typing import Any, Iterator

from repro.obs import trace as trace_mod

__all__ = ["Counter", "Gauge", "Histogram", "Registry"]

MetricKey = tuple[str, tuple[tuple[str, Any], ...]]


def _key(name: str, labels: dict[str, Any]) -> MetricKey:
    return (name, tuple(sorted(labels.items())))


def _fmt(key: MetricKey) -> str:
    name, labels = key
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic integer counter (calls, points, cache hits, node accesses)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-written value (queue depth, slot occupancy, fill fraction)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def add(self, d: float) -> None:
        self.value += float(d)


class Histogram:
    """Streaming duration/size distribution with windowed percentiles.

    Lifetime ``count``/``sum``/``min``/``max`` plus a ring buffer of the
    last ``window`` observations; ``percentile(q)`` sorts the window and
    interpolates linearly between ranks (numpy's default rule), so while
    ``count <= window`` the reported percentiles are EXACTLY
    ``np.percentile(observed, q)``.  Past that, percentiles describe the
    most recent ``window`` observations — the serving-relevant tail, not a
    lifetime average that staleness can't move.
    """

    __slots__ = ("window", "count", "total", "vmin", "vmax", "_buf", "_pos")

    def __init__(self, window: int = 2048) -> None:
        if window < 1:
            raise ValueError(f"Histogram window={window} must be >= 1")
        self.window = window
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._buf: list[float] = []
        self._pos = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if len(self._buf) < self.window:
            self._buf.append(v)
        else:
            self._buf[self._pos] = v
            self._pos = (self._pos + 1) % self.window

    def percentile(self, q: float) -> float:
        """q in [0, 100] over the retained window; NaN when empty."""
        if not self._buf:
            return math.nan
        s = sorted(self._buf)
        rank = (len(s) - 1) * (q / 100.0)
        lo = math.floor(rank)
        hi = math.ceil(rank)
        frac = rank - lo
        return s[lo] * (1.0 - frac) + s[hi] * frac

    def snapshot(self) -> dict[str, float | int]:
        n = self.count
        return {
            "count": n,
            "sum": self.total,
            "min": self.vmin if n else math.nan,
            "max": self.vmax if n else math.nan,
            "mean": self.total / n if n else math.nan,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
            "window": min(len(self._buf), self.window),
        }


class _NullCounter(Counter):
    __slots__ = ()

    def inc(self, n: int = 1) -> None:  # noqa: ARG002 — intentionally inert
        pass


class _NullGauge(Gauge):
    __slots__ = ()

    def set(self, v: float) -> None:
        pass

    def add(self, d: float) -> None:
        pass


class _NullHistogram(Histogram):
    __slots__ = ()

    def observe(self, v: float) -> None:
        pass


# shared inert instances a disabled Registry hands out — callers keep their
# unconditional `reg.counter(...).inc()` style at ~one dict-free call of cost
_NULL_COUNTER = _NullCounter()
_NULL_GAUGE = _NullGauge()
_NULL_HISTOGRAM = _NullHistogram()


class Registry:
    """One namespace of metrics + the span stack + an optional event log.

    ``enabled=False`` turns every accessor into a shared no-op object and
    ``span()`` into a clock-free passthrough; flipping a config toggles the
    entire layer without touching any instrumented call site.

    ``events`` is an ``obs.events.EventLog`` (or anything with ``emit``);
    when set, every span exit emits one JSONL record.
    """

    def __init__(
        self,
        *,
        enabled: bool = True,
        window: int = 2048,
        events: Any | None = None,
    ) -> None:
        self.enabled = bool(enabled)
        self.window = int(window)
        self.events = events
        self._counters: dict[MetricKey, Counter] = {}
        self._gauges: dict[MetricKey, Gauge] = {}
        self._hists: dict[MetricKey, Histogram] = {}
        self._local = threading.local()

    # -- accessors (get-or-create) ------------------------------------------
    def counter(self, name: str, **labels) -> Counter:
        if not self.enabled:
            return _NULL_COUNTER
        k = _key(name, labels)
        got = self._counters.get(k)
        if got is None:
            got = self._counters[k] = Counter()
        return got

    def gauge(self, name: str, **labels) -> Gauge:
        if not self.enabled:
            return _NULL_GAUGE
        k = _key(name, labels)
        got = self._gauges.get(k)
        if got is None:
            got = self._gauges[k] = Gauge()
        return got

    def histogram(self, name: str, **labels) -> Histogram:
        if not self.enabled:
            return _NULL_HISTOGRAM
        k = _key(name, labels)
        got = self._hists.get(k)
        if got is None:
            got = self._hists[k] = Histogram(self.window)
        return got

    # -- spans ---------------------------------------------------------------
    def _stack(self) -> list[str]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    @contextmanager
    def span(self, name: str, **labels) -> Iterator[str | None]:
        """Time a phase; nested spans record under ``outer/inner`` paths.

        Yields the full path (or ``None`` when disabled).  The duration is
        observed into ``histogram(path)`` in SECONDS, and — when an event
        log is attached — emitted as one ``{"event": "span", ...}`` line.
        Exceptions propagate; the stack still unwinds and the (partial)
        duration is still recorded, so a failing phase stays visible.
        """
        if not self.enabled:
            yield None
            return
        stack = self._stack()
        stack.append(name)
        path = "/".join(stack)
        # trace linkage: when an ambient sampled TraceContext is installed
        # (obs/trace.use_trace) AND events are attached, this span joins the
        # request's tree — parentage comes from the context's own stack, so
        # linkage survives across owner objects (engine registry -> index
        # registry) as long as the context flows
        ctx = trace_mod.current_trace() if self.events is not None else None
        sid = parent = None
        if ctx is not None:
            sid, parent = ctx.push()
        t0 = time.perf_counter()
        try:
            yield path
        finally:
            dur = time.perf_counter() - t0
            stack.pop()
            self.histogram(path, **labels).observe(dur)
            if ctx is not None:
                ctx.pop()
            if self.events is not None:
                rec = {"event": "span", "span": path, "dur_s": dur}
                if labels:
                    rec["labels"] = dict(labels)
                if ctx is not None:
                    rec["trace_id"] = ctx.trace_id
                    rec["span_id"] = sid
                    rec["parent_id"] = parent
                self.events.emit(rec)

    def record_span(self, name: str, dur_s: float, **labels) -> None:
        """Record a span whose duration was measured externally (e.g. queue
        wait = admission time minus submit time): observes the histogram
        under ``name`` and — with events attached — emits a span event with
        the same trace linkage a ``span()`` exit would carry."""
        if not self.enabled:
            return
        self.histogram(name, **labels).observe(float(dur_s))
        if self.events is None:
            return
        rec = {"event": "span", "span": name, "dur_s": float(dur_s)}
        if labels:
            rec["labels"] = dict(labels)
        ctx = trace_mod.current_trace()
        if ctx is not None:
            sid, parent = ctx.link()
            rec["trace_id"] = ctx.trace_id
            rec["span_id"] = sid
            rec["parent_id"] = parent
        self.events.emit(rec)

    def emit_trace_root(self, ctx, name: str, dur_s: float, **labels) -> None:
        """Emit a trace's ROOT span record (parent ``None``) with an
        externally-measured duration — the owner (``ServeEngine``) calls
        this once per sampled request at completion, closing the tree every
        nested span already parented to ``ctx.root_id``."""
        if not self.enabled:
            return
        self.histogram(name, **labels).observe(float(dur_s))
        if self.events is None or ctx is None or not ctx.sampled:
            return
        rec = {
            "event": "span",
            "span": name,
            "dur_s": float(dur_s),
            "trace_id": ctx.trace_id,
            "span_id": ctx.root_id,
            "parent_id": None,
        }
        if labels:
            rec["labels"] = dict(labels)
        self.events.emit(rec)

    def emit_event(self, event: dict[str, Any], *, traced_only: bool = False) -> None:
        """Emit a structured point event, stamped with trace linkage when a
        sampled ambient trace is active (parented at the current span,
        nothing pushed).  ``traced_only=True`` drops the event entirely
        outside a sampled trace — for per-request annotations (island
        counters, plan identity) that would otherwise bloat steady-state
        logs."""
        if self.events is None or not self.enabled:
            return
        ctx = trace_mod.current_trace()
        if ctx is None:
            if not traced_only:
                self.events.emit(event)
            return
        sid, parent = ctx.link()
        self.events.emit(
            {**event, "trace_id": ctx.trace_id, "span_id": sid,
             "parent_id": parent}
        )

    # -- reads ---------------------------------------------------------------
    def counters(self) -> dict[MetricKey, int]:
        """Raw (name, labels) -> value view, for structured consumers
        (``OverlapIndex.metrics`` groups per-island counters out of this)."""
        return {k: c.value for k, c in self._counters.items()}

    def value(self, name: str, **labels) -> int:
        """One counter's value; 0 when it was never touched (or disabled)."""
        got = self._counters.get(_key(name, labels))
        return 0 if got is None else got.value

    def snapshot(self) -> dict[str, Any]:
        """The whole registry as plain nested dicts (JSON-serializable).

        Labeled metrics format as ``name{k=v,...}`` keys; histograms expand
        to their ``{count,sum,min,max,mean,p50,p95,p99,window}`` dicts.
        """
        return {
            "enabled": self.enabled,
            "counters": {_fmt(k): c.value for k, c in self._counters.items()},
            "gauges": {_fmt(k): g.value for k, g in self._gauges.items()},
            "histograms": {
                _fmt(k): h.snapshot() for k, h in self._hists.items()
            },
        }

    def to_prometheus(self) -> str:
        """The whole registry in Prometheus text exposition format —
        counters, gauges, and histograms-as-summaries (quantile labels +
        ``_sum``/``_count``).  See ``obs/export.py`` for the renderer and
        the ``python -m repro.obs.export`` CLI around it."""
        from repro.obs.export import render_prometheus  # lazy: export is CLI-adjacent

        return render_prometheus(self.snapshot())
