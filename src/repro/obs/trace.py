"""Per-request trace propagation + reconstruction over the JSONL events.

A ``TraceContext`` is one request's identity: a ``trace_id``, a span-id
allocator, and the sampling decision.  It flows *ambiently* — ``use_trace``
installs it in a thread-local and every ``Registry.span`` exit inside the
``with`` block stamps its event record with ``trace_id`` / ``span_id`` /
``parent_id`` — so the instrumented layers (``ServeEngine`` request ->
``OverlapIndex.search`` -> ``SearchPlan`` -> executor islands) need no
signature changes to participate: whoever holds the context wraps the call.

Parentage is a per-thread stack inside the context: a span entered while
another trace span is open parents to it; a span entered at the top level
parents to the context's ``root_id`` (the "request" span the owner emits
explicitly, with its externally-measured duration, when the request
completes).  Events are written at span *exit*, so children precede their
parent in the file — ``Trace.reconstruct`` links by id, not by order.

Sampling is deterministic and systematic (error-diffusion accumulator, no
RNG): ``TraceSampler(rate)`` admits exactly ``floor`-or-`ceil(n * rate)``
of the first n requests in a fixed, reproducible pattern — rate 1.0 traces
everything, rate 0 nothing.  An unsampled request gets no context at all,
so the untraced hot path stays bitwise-identical and pays nothing beyond
one attribute read.
"""
from __future__ import annotations

import threading
import uuid
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Iterator

from repro.obs.events import EventLog

__all__ = [
    "TraceContext",
    "TraceSampler",
    "Trace",
    "SpanNode",
    "current_trace",
    "new_trace",
    "use_trace",
]

_ambient = threading.local()


class TraceContext:
    """One request's tracing identity: id allocation + the parent stack.

    ``sampled=False`` contexts exist so callers can hold a request-scoped
    object unconditionally; the registry only emits linkage for sampled
    ones.  Span ids are ``<trace_id>.<n>`` — unique within the trace,
    allocation is thread-safe (``root_id`` is always ``.1``).
    """

    __slots__ = ("trace_id", "sampled", "root_id", "_n", "_lock", "_local")

    def __init__(self, trace_id: str | None = None, *, sampled: bool = True):
        self.trace_id = trace_id or uuid.uuid4().hex[:16]
        self.sampled = bool(sampled)
        self._n = 0
        self._lock = threading.Lock()
        self._local = threading.local()
        self.root_id = self.alloc()

    def alloc(self) -> str:
        with self._lock:
            self._n += 1
            return f"{self.trace_id}.{self._n}"

    def _stack(self) -> list[str]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def push(self) -> tuple[str, str]:
        """Enter a span: returns (span_id, parent_id) and makes the new
        span the parent of whatever nests inside it."""
        sid = self.alloc()
        st = self._stack()
        parent = st[-1] if st else self.root_id
        st.append(sid)
        return sid, parent

    def pop(self) -> None:
        self._stack().pop()

    def link(self) -> tuple[str, str]:
        """Allocate an id parented at the current position WITHOUT pushing
        — for point events (island counters, plan annotations)."""
        sid = self.alloc()
        st = self._stack()
        return sid, (st[-1] if st else self.root_id)

    def __repr__(self) -> str:
        return (f"TraceContext({self.trace_id!r}, sampled={self.sampled}, "
                f"spans={self._n})")


def new_trace(*, sampled: bool = True) -> TraceContext:
    return TraceContext(sampled=sampled)


def current_trace() -> TraceContext | None:
    """The ambient context installed by ``use_trace``, if any (and only if
    sampled — unsampled contexts are never installed)."""
    return getattr(_ambient, "ctx", None)


@contextmanager
def use_trace(ctx: TraceContext | None) -> Iterator[TraceContext | None]:
    """Install ``ctx`` as the ambient trace for the block.  ``None`` (or an
    unsampled context) is a true no-op: whatever was ambient stays ambient,
    so call sites wrap unconditionally."""
    if ctx is None or not ctx.sampled:
        yield ctx
        return
    prev = getattr(_ambient, "ctx", None)
    _ambient.ctx = ctx
    try:
        yield ctx
    finally:
        _ambient.ctx = prev


class TraceSampler:
    """Deterministic systematic sampler (error-diffusion, no RNG).

    ``sample()`` accumulates ``rate`` per call and fires each time the
    accumulator crosses 1 — e.g. rate 0.25 admits request 4, 8, 12, ... —
    so runs are reproducible and the admitted fraction is exact in the
    long run.  Not thread-safe by design: each owner (engine, index) holds
    its own.
    """

    __slots__ = ("rate", "_acc")

    def __init__(self, rate: float):
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"trace sample rate {rate} must lie in [0, 1]")
        self.rate = float(rate)
        self._acc = 0.0

    def sample(self) -> bool:
        if self.rate <= 0.0:
            return False
        self._acc += self.rate
        if self._acc >= 1.0:
            self._acc -= 1.0
            return True
        return False

    def maybe_trace(self) -> TraceContext | None:
        return new_trace() if self.sample() else None


# ---------------------------------------------------------------------------
# reconstruction
# ---------------------------------------------------------------------------


@dataclass
class SpanNode:
    """One reconstructed span: its event record + child spans (file order)."""

    record: dict[str, Any]
    children: list["SpanNode"] = field(default_factory=list)

    @property
    def name(self) -> str:
        return str(self.record.get("span", self.record.get("event", "?")))

    @property
    def dur_s(self) -> float:
        return float(self.record.get("dur_s", 0.0))


@dataclass
class Trace:
    """One request's span tree, reassembled from an events JSONL.

    ``roots`` are the spans whose parent is absent from the file — normally
    exactly one, the owner-emitted ``request`` root.  ``records`` keeps
    every raw event of the trace (including point events) in file order.
    """

    trace_id: str
    roots: list[SpanNode]
    records: list[dict[str, Any]]

    @staticmethod
    def reconstruct(path: str, trace_id: str) -> "Trace":
        """Reassemble one trace from ``path`` (rotated files included —
        ``EventLog.read`` spans rotations oldest-first)."""
        recs = [
            r for r in EventLog.read(path) if r.get("trace_id") == trace_id
        ]
        nodes: dict[str, SpanNode] = {
            r["span_id"]: SpanNode(r) for r in recs if "span_id" in r
        }
        roots: list[SpanNode] = []
        for r in recs:
            sid = r.get("span_id")
            if sid is None:
                continue
            parent = r.get("parent_id")
            if parent is not None and parent in nodes and parent != sid:
                nodes[parent].children.append(nodes[sid])
            else:
                roots.append(nodes[sid])
        return Trace(trace_id=trace_id, roots=roots, records=recs)

    @staticmethod
    def trace_ids(path: str) -> list[str]:
        """Every trace id present in the log, in first-seen order."""
        seen: dict[str, None] = {}
        for r in EventLog.read(path):
            tid = r.get("trace_id")
            if tid is not None and tid not in seen:
                seen[tid] = None
        return list(seen)

    def span_names(self) -> set[str]:
        out: set[str] = set()

        def walk(n: SpanNode) -> None:
            out.add(n.name)
            for c in n.children:
                walk(c)

        for r in self.roots:
            walk(r)
        return out

    def render(self) -> str:
        """Human-readable tree (the export CLI's ``--trace`` output)."""
        lines = [f"trace {self.trace_id}"]

        def walk(n: SpanNode, depth: int) -> None:
            lines.append(f"{'  ' * depth}- {n.name}  {n.dur_s * 1e3:.3f} ms")
            for c in n.children:
                walk(c, depth + 1)

        for r in self.roots:
            walk(r, 1)
        return "\n".join(lines)
