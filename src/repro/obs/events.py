"""Toggleable JSONL event emitter — the registry's wire format.

One ``EventLog`` appends one JSON object per line to a file; ``Registry``
span exits (obs/metrics.py) and any caller with something structured to
say (``emit`` takes an arbitrary JSON-serializable dict) share it.  Lines
are self-contained — each carries a wall-clock ``ts`` — so logs from
several processes concatenate and sort cleanly.

Off by default: nothing opens a file unless an ``events_path`` is
configured (``ObsConfig.events_path`` or the ``REPRO_OBS_EVENTS``
environment variable), so the metrics layer stays filesystem-free in the
common case.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any

__all__ = ["EventLog", "events_path_from_env"]

ENV_VAR = "REPRO_OBS_EVENTS"


def events_path_from_env() -> str | None:
    """The ambient JSONL destination, if any (empty string means off)."""
    return os.environ.get(ENV_VAR) or None


class EventLog:
    """Append-only JSONL writer with line-level durability.

    ``emit`` stamps ``ts`` (unix seconds) and writes exactly one line per
    event, flushing by default so a crash mid-run loses at most the event
    being written — these logs exist to debug exactly such runs.
    """

    def __init__(self, path: str, *, flush: bool = True) -> None:
        self.path = str(path)
        self._flush = flush
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(self.path, "a")

    def emit(self, event: dict[str, Any]) -> None:
        rec = {"ts": time.time(), **event}
        self._f.write(json.dumps(rec, sort_keys=True) + "\n")
        if self._flush:
            self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "EventLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self) -> None:  # best-effort: drop the fd with the object
        try:
            self.close()
        except Exception:
            pass

    @staticmethod
    def read(path: str) -> list[dict[str, Any]]:
        """Parse a JSONL event file back into dicts (round-trip of ``emit``).

        Skips blank lines; raises on malformed JSON — a corrupt event log
        should fail loudly in tooling, not silently truncate."""
        out: list[dict[str, Any]] = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(json.loads(line))
        return out
