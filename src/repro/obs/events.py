"""Toggleable JSONL event emitter — the registry's wire format.

One ``EventLog`` appends one JSON object per line to a file; ``Registry``
span exits (obs/metrics.py) and any caller with something structured to
say (``emit`` takes an arbitrary JSON-serializable dict) share it.  Lines
are self-contained — each carries a wall-clock ``ts`` — so logs from
several processes concatenate and sort cleanly.

Off by default: nothing opens a file unless an ``events_path`` is
configured (``ObsConfig.events_path`` or the ``REPRO_OBS_EVENTS``
environment variable), so the metrics layer stays filesystem-free in the
common case.

Rotation: append mode means restarts accumulate — which is the point for
debugging, and a disk-filling liability for a long-lived server.  With
``max_bytes`` set, an emit that would push the current file past the limit
first shifts ``path -> path.1 -> path.2 -> ... -> path.N`` (``backups``
deep; the oldest falls off) and starts a fresh file, logrotate-style.
``EventLog.read`` transparently spans the rotation set oldest-first, so
readers (``Trace.reconstruct``, the export CLI) see one continuous stream.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any

__all__ = ["EventLog", "events_path_from_env"]

ENV_VAR = "REPRO_OBS_EVENTS"


def events_path_from_env() -> str | None:
    """The ambient JSONL destination, if any (empty string means off)."""
    return os.environ.get(ENV_VAR) or None


class EventLog:
    """Append-only JSONL writer with line-level durability.

    ``emit`` stamps ``ts`` (unix seconds) and writes exactly one line per
    event, flushing by default so a crash mid-run loses at most the event
    being written — these logs exist to debug exactly such runs.

    ``max_bytes=None`` (default) never rotates; otherwise a file is capped
    near ``max_bytes`` (a single event always lands whole in one file, so
    the cap is exceeded only by the final line's length) and up to
    ``backups`` rotated predecessors are kept as ``path.1 .. path.N``.
    """

    def __init__(
        self,
        path: str,
        *,
        flush: bool = True,
        max_bytes: int | None = None,
        backups: int = 3,
    ) -> None:
        if max_bytes is not None and max_bytes < 1:
            raise ValueError(f"EventLog max_bytes={max_bytes} must be >= 1")
        if backups < 0:
            raise ValueError(f"EventLog backups={backups} must be >= 0")
        self.path = str(path)
        self._flush = flush
        self.max_bytes = max_bytes
        self.backups = backups
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(self.path, "a")
        self._size = os.path.getsize(self.path)

    def emit(self, event: dict[str, Any]) -> None:
        rec = {"ts": time.time(), **event}
        line = json.dumps(rec, sort_keys=True) + "\n"
        if (
            self.max_bytes is not None
            and self._size > 0
            and self._size + len(line) > self.max_bytes
        ):
            self._rotate()
        self._f.write(line)
        self._size += len(line)
        if self._flush:
            self._f.flush()

    def _rotate(self) -> None:
        """Shift the rotation chain and start a fresh current file."""
        self._f.close()
        if self.backups == 0:
            # no history requested: truncate in place
            self._f = open(self.path, "w")
            self._size = 0
            return
        oldest = f"{self.path}.{self.backups}"
        if os.path.exists(oldest):
            os.remove(oldest)
        for n in range(self.backups - 1, 0, -1):
            src = f"{self.path}.{n}"
            if os.path.exists(src):
                os.replace(src, f"{self.path}.{n + 1}")
        os.replace(self.path, f"{self.path}.1")
        self._f = open(self.path, "a")
        self._size = 0

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "EventLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self) -> None:  # best-effort: drop the fd with the object
        try:
            self.close()
        except Exception:
            pass

    @staticmethod
    def rotated_paths(path: str) -> list[str]:
        """Existing files of the rotation set, OLDEST first (``path.N`` down
        to ``path.1``, then ``path`` itself)."""
        out: list[str] = []
        n = 1
        while os.path.exists(f"{path}.{n}"):
            n += 1
        for i in range(n - 1, 0, -1):
            out.append(f"{path}.{i}")
        if os.path.exists(path):
            out.append(path)
        return out

    @staticmethod
    def read(path: str) -> list[dict[str, Any]]:
        """Parse a JSONL event stream back into dicts — spanning the whole
        rotation set (``path.N .. path.1`` then ``path``), oldest first, so
        a rotated log reads as one continuous stream.

        Skips blank lines; raises on malformed JSON — a corrupt event log
        should fail loudly in tooling, not silently truncate."""
        out: list[dict[str, Any]] = []
        files = EventLog.rotated_paths(path) or [path]
        for fp in files:
            with open(fp) as f:
                for line in f:
                    line = line.strip()
                    if line:
                        out.append(json.loads(line))
        return out
