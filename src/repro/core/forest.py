"""Forest of BCCF indexes — the device-facing flattened structure.

The decision stage (§4.3) emits groups with neighbor links; each group is
indexed by one BCCF tree.  This module packs the whole forest into fixed-shape
SoA arrays that the jittable search (core/knn.py) and the Pallas kernels
consume directly:

  index_centers  (I, D)        group pivot (Alg. 2 step-1 routing)
  index_radii    (I,)
  neighbors      (I, MAXNBR)   i32, -1 padded (overlap-index links)
  bucket_x       (NB, C, D)    bucket member coordinates, zero padded
  bucket_ids     (NB, C)       i32 global object ids, -1 padded
  bucket_mask    (NB, C)       bool
  bucket_pivot   (NB, D)       bucket centroid (lower-bound reference point)
  bucket_radius  (NB,)         max distance member -> pivot
  bucket_index   (NB,)         i32 owning index id

Per-tree node arrays are kept (host side) for structure benchmarks and for
the tree-descent r_q estimator.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core.bccf import BuildCounters, FlatTree, TreeStructure, build_tree
from repro.core.decision import Partition


@dataclass
class ForestArrays:
    index_centers: np.ndarray
    index_radii: np.ndarray
    neighbors: np.ndarray
    is_overlap_index: np.ndarray  # (I,) bool
    bucket_x: np.ndarray
    bucket_ids: np.ndarray
    bucket_mask: np.ndarray
    bucket_pivot: np.ndarray
    bucket_radius: np.ndarray
    bucket_index: np.ndarray
    c_max: int
    trees: list[FlatTree] = field(default_factory=list, repr=False)
    build_stats: dict[str, Any] = field(default_factory=dict)

    @property
    def n_indexes(self) -> int:
        return int(self.index_centers.shape[0])

    @property
    def n_buckets(self) -> int:
        return int(self.bucket_x.shape[0])

    def aggregate_structure(self) -> dict[str, Any]:
        """Structure-evaluation rollup (paper Figs. 6-19).

        Derived from the per-tree host copies in ``self.trees`` — rebuild
        swaps (stream/maintenance.py) go through ``swap_trees``, which
        refreshes those copies and re-flattens the device arrays together,
        so this rollup can never describe a stale structure.  The rollup
        cross-checks itself against the flattened arrays and refuses to
        report numbers that disagree with what search actually scans.
        """
        total_leaves = sum(t.structure.n_leaves for t in self.trees)
        if self.trees and total_leaves != self.n_buckets:
            raise RuntimeError(
                f"stale forest structure: trees report {total_leaves} leaves "
                f"but the flattened arrays hold {self.n_buckets} buckets — "
                "tree swaps must go through forest.swap_trees"
            )
        per_tree = []
        for t in self.trees:
            s = t.structure
            per_tree.append(
                dict(
                    n_internal=s.n_internal,
                    n_leaves=s.n_leaves,
                    height=s.height,
                    bucket_sizes=list(s.bucket_sizes),
                    nodes_per_level=dict(s.nodes_per_level),
                )
            )
        all_buckets = [b for t in per_tree for b in t["bucket_sizes"]]
        return dict(
            n_trees=len(per_tree),
            trees=per_tree,
            total_internal=sum(t["n_internal"] for t in per_tree),
            total_leaves=sum(t["n_leaves"] for t in per_tree),
            max_height=max((t["height"] for t in per_tree), default=0),
            bucket_fill_mean=float(np.mean(all_buckets)) if all_buckets else 0.0,
            bucket_fill_median=float(np.median(all_buckets)) if all_buckets else 0.0,
        )


def _flatten_trees(
    x: np.ndarray, trees: list[FlatTree], *, c_max: int
) -> dict[str, np.ndarray]:
    """Flatten per-tree buckets into the fixed-shape SoA device layout.

    Shared by the initial build and by rebuild swaps (``swap_trees``) so the
    two paths can never drift apart on padding/pivot/radius conventions.
    """
    dim = x.shape[1]
    bucket_rows: list[np.ndarray] = []
    bucket_idrows: list[np.ndarray] = []
    bucket_owner: list[int] = []
    for gi, tree in enumerate(trees):
        for members in tree.bucket_members:
            bucket_rows.append(x[members])
            bucket_idrows.append(np.asarray(members, np.int64))
            bucket_owner.append(gi)

    nb = len(bucket_rows)
    cap = max(c_max, max((len(b) for b in bucket_rows), default=1))
    bucket_x = np.zeros((nb, cap, dim), np.float32)
    bucket_ids = np.full((nb, cap), -1, np.int32)
    bucket_mask = np.zeros((nb, cap), bool)
    bucket_pivot = np.zeros((nb, dim), np.float32)
    bucket_radius = np.zeros((nb,), np.float32)
    for i, (pts, bids) in enumerate(zip(bucket_rows, bucket_idrows)):
        m = len(pts)
        bucket_x[i, :m] = pts
        bucket_ids[i, :m] = bids
        bucket_mask[i, :m] = True
        piv = pts.mean(axis=0)
        bucket_pivot[i] = piv
        bucket_radius[i] = np.sqrt(((pts - piv) ** 2).sum(-1)).max() if m else 0.0
    return dict(
        bucket_x=bucket_x,
        bucket_ids=bucket_ids,
        bucket_mask=bucket_mask,
        bucket_pivot=bucket_pivot,
        bucket_radius=bucket_radius,
        bucket_index=np.array(bucket_owner, np.int32),
        c_max=int(cap),
    )


def build_forest(
    x: np.ndarray,
    groups: list[Partition],
    *,
    c_max: int,
    pivot_method: str = "gh",
    seed: int = 0,
) -> ForestArrays:
    """Build one BCCF tree per decision group and flatten into a forest."""
    x = np.asarray(x, np.float32)
    trees: list[FlatTree] = []
    counters = BuildCounters()
    for gi, g in enumerate(groups):
        tree = build_tree(
            x[g.members], g.members, c_max=c_max, pivot_method=pivot_method, seed=seed + gi
        )
        trees.append(tree)
        counters.distances += tree.counters.distances
        counters.comparisons += tree.counters.comparisons

    flat = _flatten_trees(x, trees, c_max=c_max)
    max_nbr = max((len(g.neighbors) for g in groups), default=0)
    neighbors = np.full((len(groups), max(max_nbr, 1)), -1, np.int32)
    for i, g in enumerate(groups):
        neighbors[i, : len(g.neighbors)] = np.asarray(g.neighbors, np.int32)

    return ForestArrays(
        index_centers=np.stack([g.pivot for g in groups]).astype(np.float32),
        index_radii=np.array([g.radius for g in groups], np.float32),
        neighbors=neighbors,
        is_overlap_index=np.array([g.is_overlap_index for g in groups], bool),
        trees=trees,
        build_stats=dict(
            tree_distances=counters.distances,
            tree_comparisons=counters.comparisons,
            rebuilds=0,
        ),
        **flat,
    )


def swap_trees(
    forest: ForestArrays,
    x: np.ndarray,
    replacements: dict[int, FlatTree],
    *,
    index_centers: np.ndarray | None = None,
    index_radii: np.ndarray | None = None,
) -> ForestArrays:
    """Swap freshly rebuilt per-index trees into a forest (hot rebuild path).

    Returns a NEW ForestArrays (the old one keeps serving until the caller
    swaps the device upload) with:

    * the flattened bucket arrays re-derived from the updated tree set via
      the same ``_flatten_trees`` the initial build uses,
    * the host-side ``trees`` list refreshed — ``aggregate_structure`` stays
      truthful after the swap instead of describing dead trees,
    * ``build_stats`` counters ACCUMULATED (initial build + every rebuild so
      far + this one), because the paper's construction-cost metric must
      include maintenance work, plus a ``rebuilds`` tally,
    * optionally updated index geometry (post-ingest centroids/radii from
      the maintenance monitor).

    ``x`` must cover every global object id referenced by any tree
    (the streaming caller passes its full accumulated dataset).
    """
    x = np.asarray(x, np.float32)
    trees = list(forest.trees)
    add = BuildCounters()
    for gi, tree in replacements.items():
        if not (0 <= gi < len(trees)):
            raise ValueError(f"replacement for unknown index {gi}")
        trees[gi] = tree
        add.distances += tree.counters.distances
        add.comparisons += tree.counters.comparisons

    flat = _flatten_trees(x, trees, c_max=forest.c_max)
    centers = forest.index_centers.copy() if index_centers is None else (
        np.asarray(index_centers, np.float32)
    )
    radii = forest.index_radii.copy() if index_radii is None else (
        np.asarray(index_radii, np.float32)
    )
    stats = dict(forest.build_stats)
    stats["tree_distances"] = stats.get("tree_distances", 0) + add.distances
    stats["tree_comparisons"] = stats.get("tree_comparisons", 0) + add.comparisons
    stats["rebuilds"] = stats.get("rebuilds", 0) + len(replacements)
    return ForestArrays(
        index_centers=centers,
        index_radii=radii,
        neighbors=forest.neighbors.copy(),
        is_overlap_index=forest.is_overlap_index.copy(),
        trees=trees,
        build_stats=stats,
        **flat,
    )
