"""BCCF-tree construction (paper Def. 12; baseline of [5]).

Internal node: two pivots (p1, p2) with covering radii (r1, r2) taken over
*all* objects of the subtree; children partition objects by the GH rule
(d(o,p1) <= d(o,p2)).  Leaves are buckets of capacity c_max = sqrt(n).

Two pivot-selection strategies:
* ``kmeans`` — the BCCF baseline: recursive 2-means (pivots = objects nearest
  to the converged centroids).  Expensive: ~2m distances per iteration.
* ``gh``     — the paper's proposed refinement (§4.3): cheap GH pivots
  (random p1, farthest-point p2), single assignment pass.

Construction is host-orchestrated (numpy recursion, the build path of every
production vector store); the emitted structure is a flattened SoA the
jittable search consumes.  Every distance evaluation and comparison is
counted, because those counters ARE the paper's construction-cost metric
(Fig. 20).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class BuildCounters:
    distances: int = 0
    comparisons: int = 0


@dataclass
class TreeStructure:
    """Structure-evaluation metrics (paper Figs. 6-19)."""

    n_internal: int = 0
    n_leaves: int = 0
    height: int = 0
    bucket_sizes: list[int] = field(default_factory=list)
    nodes_per_level: dict[int, int] = field(default_factory=dict)


@dataclass
class FlatTree:
    """Flattened BCCF tree. ``node_children`` entries: >= 0 -> internal node
    id; < 0 -> bucket id encoded as -(local_bucket_id + 1); for single-bucket
    trees ``node_pivots`` is empty and the only bucket is bucket 0."""

    node_pivots: np.ndarray  # (M, 2, D) f32
    node_radii: np.ndarray  # (M, 2) f32
    node_children: np.ndarray  # (M, 2) i32
    bucket_members: list[np.ndarray]  # local bucket id -> global object ids
    structure: TreeStructure
    counters: BuildCounters


def _dists(a: np.ndarray, b: np.ndarray, counters: BuildCounters) -> np.ndarray:
    """Row-wise distances from points ``a`` (m, D) to single point ``b``."""
    counters.distances += len(a)
    return np.sqrt(np.maximum(((a - b) ** 2).sum(-1), 0.0))


def _pivots_gh(pts: np.ndarray, rng: np.random.Generator, c: BuildCounters):
    i1 = int(rng.integers(len(pts)))
    d1 = _dists(pts, pts[i1], c)
    c.comparisons += len(pts)
    i2 = int(d1.argmax())
    if i2 == i1:  # all points identical
        i2 = (i1 + 1) % len(pts)
    return i1, i2, d1


def _pivots_kmeans(
    pts: np.ndarray, rng: np.random.Generator, c: BuildCounters, max_iter: int = 10
):
    """2-means; returns indices of the objects closest to the centroids."""
    i1, i2, _ = _pivots_gh(pts, rng, c)  # far-pair init
    cent = np.stack([pts[i1], pts[i2]]).astype(np.float64)
    prev = None
    for _ in range(max_iter):
        d0 = _dists(pts, cent[0], c)
        d1 = _dists(pts, cent[1], c)
        c.comparisons += len(pts)
        assign = (d1 < d0).astype(np.int32)
        if prev is not None and np.array_equal(assign, prev):
            break
        prev = assign
        for k in (0, 1):
            sel = pts[assign == k]
            if len(sel):
                cent[k] = sel.mean(axis=0)
    j1 = int(_dists(pts, cent[0], c).argmin())
    j2 = int(_dists(pts, cent[1], c).argmin())
    c.comparisons += 2 * len(pts)
    if j1 == j2:
        j2 = (j1 + 1) % len(pts)
    return j1, j2


def build_tree(
    x: np.ndarray,
    ids: np.ndarray,
    *,
    c_max: int,
    pivot_method: str = "gh",
    seed: int = 0,
) -> FlatTree:
    """Build a flattened BCCF tree over ``x`` (m, D) with object ids ``ids``."""
    x = np.asarray(x, np.float32)
    ids = np.asarray(ids)
    rng = np.random.default_rng(seed)
    counters = BuildCounters()
    structure = TreeStructure()

    node_pivots: list[np.ndarray] = []
    node_radii: list[np.ndarray] = []
    node_children: list[list[int]] = []
    buckets: list[np.ndarray] = []

    def make_leaf(sub_ids: np.ndarray, level: int) -> int:
        bucket_id = len(buckets)
        buckets.append(sub_ids)
        structure.n_leaves += 1
        structure.bucket_sizes.append(len(sub_ids))
        structure.height = max(structure.height, level)
        structure.nodes_per_level[level] = structure.nodes_per_level.get(level, 0) + 1
        return -(bucket_id + 1)

    def rec(sub: np.ndarray, sub_ids: np.ndarray, level: int) -> int:
        if len(sub_ids) <= c_max:
            return make_leaf(sub_ids, level)
        if pivot_method == "kmeans":
            i1, i2 = _pivots_kmeans(sub, rng, counters)
            d1 = _dists(sub, sub[i1], counters)
            d2 = _dists(sub, sub[i2], counters)
        elif pivot_method == "gh":
            i1, i2, d1 = _pivots_gh(sub, rng, counters)
            d2 = _dists(sub, sub[i2], counters)
        else:
            raise ValueError(f"pivot_method {pivot_method!r}")
        counters.comparisons += len(sub_ids)
        left = d1 <= d2
        # Degenerate split (duplicate-heavy nodes): balanced fallback.
        if left.all() or (~left).all():
            order = np.argsort(d1, kind="stable")
            left = np.zeros(len(sub_ids), bool)
            left[order[: len(sub_ids) // 2]] = True
        # Def. 12: radii are max distance over the WHOLE node per pivot.
        r1 = float(d1.max())
        r2 = float(d2.max())
        node_id = len(node_children)
        node_pivots.append(np.stack([sub[i1], sub[i2]]))
        node_radii.append(np.array([r1, r2], np.float32))
        node_children.append([0, 0])
        structure.n_internal += 1
        structure.nodes_per_level[level] = structure.nodes_per_level.get(level, 0) + 1
        cl = rec(sub[left], sub_ids[left], level + 1)
        cr = rec(sub[~left], sub_ids[~left], level + 1)
        node_children[node_id] = [cl, cr]
        return node_id

    if len(sub := ids) == 0:
        raise ValueError("cannot build a tree over zero objects")
    root = rec(x, ids, 0)
    if root < 0 and not node_children:
        # Whole tree is a single bucket: no internal nodes.
        pass
    d = x.shape[1]
    return FlatTree(
        node_pivots=(np.stack(node_pivots) if node_pivots else np.zeros((0, 2, d), np.float32)),
        node_radii=(np.stack(node_radii) if node_radii else np.zeros((0, 2), np.float32)),
        node_children=(np.array(node_children, np.int32) if node_children else np.zeros((0, 2), np.int32)),
        bucket_members=buckets,
        structure=structure,
        counters=counters,
    )
