"""DBSCAN preprocessing (paper §4.1, Algorithm 1) — TPU-native formulation.

The paper's sequential ExpandCluster recursion is replaced by a parallel
formulation with identical output semantics (DBSCAN's clustering is unique up
to border-point tie-breaking, which we resolve by nearest-core assignment):

1. *Core mask*: |N_eps(o)| >= MinPts, computed with blocked pairwise-distance
   tiles (never materializing the full N x N matrix).

Each phase streams its (block, N) distance tiles through the fused eps-graph
kernels in ``kernels/pairwise_l2.py`` (dispatch via ``kernels/ops``:
compiled Pallas on TPU, interpret under ``REPRO_FORCE_PALLAS=1``, pure-jnp
reference otherwise); ``kernel=False`` forces the in-place jnp formulation,
which tests/test_dbscan.py keeps as the oracle for the kernelized path.
2. *Core connectivity*: connected components of the eps-graph restricted to
   core points, via min-label propagation + pointer jumping inside a single
   jitted ``lax.while_loop`` (converges in O(graph diameter / 2^jumps) sweeps).
3. *Border points*: assigned to the cluster of their nearest core neighbor
   within eps; points with no core neighbor are NOISE.

Algorithm 1 lines 9-11 (partition extraction: pivot = cluster mean, radius =
max distance to pivot) are provided by ``partitions_from_labels``.  Noise is
assigned to the nearest pivot afterwards (production stores index everything;
documented deviation in DESIGN.md §3).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metric import _pairwise_sq_l2_jnp
from repro.kernels import ops

Array = jax.Array


@dataclass(frozen=True)
class DBSCANResult:
    labels: np.ndarray  # (N,) int32 contiguous cluster ids; -1 for noise
    n_clusters: int
    core_mask: np.ndarray  # (N,) bool
    n_iterations: int
    distance_computations: int  # total pairwise distances evaluated


def _pad_rows(x: Array, block: int) -> tuple[Array, int]:
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        # Far-away pad rows: never within eps of anything real.
        x = jnp.concatenate([x, jnp.full((pad, x.shape[1]), 1e30, x.dtype)], axis=0)
    return x, n + pad


@functools.partial(
    jax.jit, static_argnames=("block", "min_pts", "max_iter", "kernel")
)
def _dbscan_device(
    x: Array, eps: float, *, min_pts: int, block: int, max_iter: int,
    kernel: bool = True,
):
    n = x.shape[0]
    xp, n_pad = _pad_rows(x, block)
    nb = n_pad // block
    eps_sq = jnp.asarray(eps, jnp.float32) ** 2
    sentinel = jnp.int32(n)

    def _block_rows(ib):
        return jax.lax.dynamic_slice_in_dim(xp, ib * block, block)

    # -- 1. core mask ------------------------------------------------------
    def _count_body(_, ib):
        if kernel:
            return None, ops.eps_count(_block_rows(ib), x, eps_sq)
        d = _pairwise_sq_l2_jnp(_block_rows(ib), x)
        return None, jnp.sum(d <= eps_sq, axis=1)

    _, counts = jax.lax.scan(_count_body, None, jnp.arange(nb))
    counts = counts.reshape(-1)[:n]
    core = counts >= min_pts  # (N,)

    # -- 2. min-label propagation over core-core eps edges ------------------
    labels0 = jnp.where(core, jnp.arange(n, dtype=jnp.int32), sentinel)

    def _sweep(labels):
        def body(_, ib):
            if kernel:
                return None, ops.eps_min_label(
                    _block_rows(ib), x, labels, core, eps_sq
                )
            d = _pairwise_sq_l2_jnp(_block_rows(ib), x)
            adj = (d <= eps_sq) & core[None, :]
            cand = jnp.where(adj, labels[None, :], sentinel)
            return None, jnp.min(cand, axis=1)

        _, new = jax.lax.scan(body, None, jnp.arange(nb))
        new = jnp.minimum(new.reshape(-1)[:n], labels)
        new = jnp.where(core, new, labels)
        # pointer jumping (path halving), x3
        ext = jnp.concatenate([new, jnp.array([sentinel], jnp.int32)])
        for _ in range(3):
            jumped = ext[jnp.clip(new, 0, n)]
            new = jnp.where(core & (jumped < new), jumped, new)
            ext = jnp.concatenate([new, jnp.array([sentinel], jnp.int32)])
        return new

    def cond(state):
        labels, prev, it = state
        return (it < max_iter) & jnp.any(labels != prev)

    def step(state):
        labels, _, it = state
        return _sweep(labels), labels, it + 1

    labels, _, iters = jax.lax.while_loop(
        cond, step, (_sweep(labels0), labels0, jnp.int32(1))
    )

    # -- 3. border points: nearest core neighbor within eps -----------------
    def _border_body(_, ib):
        if kernel:
            dmin, lab = ops.eps_nearest_core(_block_rows(ib), x, labels, core)
            return None, jnp.where(dmin <= eps_sq, lab, sentinel)
        d = _pairwise_sq_l2_jnp(_block_rows(ib), x)
        d = jnp.where(core[None, :], d, jnp.inf)
        j = jnp.argmin(d, axis=1)
        dmin = jnp.take_along_axis(d, j[:, None], axis=1)[:, 0]
        lab = labels[j]
        return None, jnp.where(dmin <= eps_sq, lab, sentinel)

    _, border = jax.lax.scan(_border_body, None, jnp.arange(nb))
    border = border.reshape(-1)[:n]
    final = jnp.where(core, labels, border)
    return final, core, iters


def dbscan(
    x,
    eps: float,
    min_pts: int,
    *,
    block: int = 1024,
    max_iter: int = 64,
    kernel: bool = True,
) -> DBSCANResult:
    """Run DBSCAN; returns contiguous labels (-1 = noise) on host.

    ``kernel=True`` (default) streams each phase through the fused eps-graph
    kernels (kernels/ops dispatch); ``kernel=False`` keeps the in-place jnp
    formulation — the oracle the kernel path is tested against.
    """
    x = jnp.asarray(x, jnp.float32)
    n = int(x.shape[0])
    block = int(min(block, max(128, n)))
    labels, core, iters = _dbscan_device(
        x, float(eps), min_pts=int(min_pts), block=block, max_iter=max_iter,
        kernel=bool(kernel),
    )
    labels = np.asarray(labels)
    core = np.asarray(core)
    iters = int(iters)
    # renumber to contiguous ids; sentinel (== n) -> -1
    out = np.full(n, -1, np.int32)
    valid = labels < n
    uniq, inv = np.unique(labels[valid], return_inverse=True)
    out[valid] = inv.astype(np.int32)
    n_pad = n + ((-n) % block)
    # sweeps: core-count pass + (iters propagation) + border pass, each n_pad*n
    dist_count = (iters + 2) * n_pad * n
    return DBSCANResult(
        labels=out,
        n_clusters=int(uniq.size),
        core_mask=core,
        n_iterations=iters,
        distance_computations=int(dist_count),
    )


def partitions_from_labels(
    x, labels: np.ndarray, n_clusters: int, *, assign_noise: bool = True
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Algorithm 1, lines 9-11: pivots (cluster means), radii (max distance
    to pivot), and the final object->partition assignment.

    Noise points (label -1) are assigned to their nearest pivot (radii are
    re-expanded accordingly) when ``assign_noise``.
    """
    x = np.asarray(x, np.float32)
    labels = np.asarray(labels).copy()
    if n_clusters == 0:
        # Degenerate: everything is noise -> single partition.
        pivot = x.mean(axis=0, keepdims=True)
        radii = np.array([np.sqrt(((x - pivot) ** 2).sum(-1)).max()], np.float32)
        return pivot.astype(np.float32), radii, np.zeros(len(x), np.int32)
    pivots = np.zeros((n_clusters, x.shape[1]), np.float64)
    counts = np.zeros(n_clusters, np.int64)
    np.add.at(pivots, labels[labels >= 0], x[labels >= 0])
    np.add.at(counts, labels[labels >= 0], 1)
    pivots = (pivots / np.maximum(counts[:, None], 1)).astype(np.float32)
    if assign_noise and (labels < 0).any():
        noise = np.where(labels < 0)[0]
        d = ((x[noise, None, :] - pivots[None, :, :]) ** 2).sum(-1)
        labels[noise] = d.argmin(axis=1).astype(np.int32)
    radii = np.zeros(n_clusters, np.float32)
    d_all = np.sqrt(((x - pivots[labels]) ** 2).sum(-1))
    np.maximum.at(radii, labels, d_all.astype(np.float32))
    return pivots, radii, labels.astype(np.int32)
