"""k-NN search over the flattened forest (paper Algorithm 2) — jittable.

Paper Alg. 2:  STEP 1 route the query to the closest index center and append
that index's neighbor overlap-indexes; STEP 2 run the kNN-BCCF
branch-and-bound on every selected index in parallel; STEP 3 gather.

TPU-native realization (DESIGN.md §3): the per-index branch-and-bound descent
becomes a *sorted-lower-bound masked bucket scan* over the forest's flattened
buckets:

  1. route:   d(q, index_centers) -> closest + neighbors -> eligibility mask
              over buckets (STEP 1; identical selection semantics).
  2. bound:   lb_b = max(0, d(q, bucket_pivot_b) - bucket_radius_b) for all
              eligible buckets (one distance-matrix kernel), +inf elsewhere.
  3. scan:    visit buckets in ascending-lb order under a ``lax.while_loop``;
              each step evaluates the next ``beam`` buckets per query
              (distance block + top-k merge) and stops once
              lb > kth-best for every query (exact termination: lb is sorted
              and kth-best is non-increasing).

The scan visits a superset-free ordering of what best-first tree descent
visits, so the paper's cost metrics (distance computations, bucket/node
accesses, comparisons) are preserved and instrumented per query.  The first
visited bucket doubles as the paper's Estimated-r_q seed (kth distance of the
nearest leaf).

``mode='all'`` disables routing (every index selected) — used by tests to
prove the scan is EXACT against brute force, and by callers who want exact
global kNN at higher cost.

Streaming deltas (repro.stream): ``knn_search(..., delta=DeltaView)`` runs a
SECOND bounded scan phase over the per-index delta tail buckets (the
device-resident append buffers of stream/ingest.py), seeded with the main
phase's top-k carry.  The delta buckets behave exactly like forest buckets
(pivot/radius lower bounds, same fused kernel step); because lower bounds are
only ever pruning conditions, splitting the scan into two phases preserves
exactness — the main phase merely prunes against a k-th best that ignores
delta members (visits a superset), and the delta phase prunes against the
true running k-th best.

Under-filled selections: when the selected indexes hold fewer than k
objects, the k-th best distance stays +inf and the bounded scan naturally
SPILLS into the next-nearest non-selected buckets until k answers exist —
matching the paper's §4.3 intent ("particularly when the required number
of objects has not yet been reached").  The exact-within-selection
contract therefore applies when the selection holds >= k objects.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import ForestArrays
from repro.core.metric import pairwise
from repro.deprecation import warn_deprecated
from repro.kernels import ops as kops
from repro.kernels import ref as kref

Array = jax.Array


class DeviceForest(NamedTuple):
    index_centers: Array  # (I, D)
    index_radii: Array  # (I,)
    neighbors: Array  # (I, MAXNBR) i32, -1 pad
    bucket_x: Array  # (NB, C, D) f32, or int8 when quantized
    bucket_ids: Array  # (NB, C) i32, -1 pad
    bucket_mask: Array  # (NB, C) bool
    bucket_pivot: Array  # (NB, D) f32 (bounds stay full precision)
    bucket_radius: Array  # (NB,)
    bucket_index: Array  # (NB,) i32
    bucket_scale: Array | None = None  # (NB, C) f32 dequant scales (int8 mode)


class DeltaView(NamedTuple):
    """Search-facing view of the streaming delta buffers (repro.stream).

    One delta bucket per index: fixed-capacity tail arrays appended to by
    stream/ingest.ingest.  ``pivot`` is the reference point the running
    ``radius`` bound is maintained against (the owning index's center at
    buffer allocation), so ``max(0, d(q, pivot) - radius)`` is a valid lower
    bound on any member distance.  Unfilled slots carry id -1 (the same
    padding contract as ``DeviceForest.bucket_ids``)."""

    x: Array  # (I, CAPD, D) f32
    ids: Array  # (I, CAPD) i32, -1 pad
    mask: Array  # (I, CAPD) bool
    pivot: Array  # (I, D) f32
    radius: Array  # (I,) f32


class SearchStats(NamedTuple):
    buckets_visited: Array  # (Q,) i32
    distances: Array  # (Q,) i32  useful (unpadded) OBJECT distances
    bound_distances: Array  # (Q,) i32  routing (centers) + bucket-bound dists
    padded_distances: Array  # (Q,) i32  object distances incl. padding lanes
    comparisons: Array  # (Q,) i32  routing + bound + top-k comparisons
    steps: Array  # () i32  while-loop trip count


class IslandStats(NamedTuple):
    """Per-executor-island node-access counters (leading dim = islands).

    The paper's cost currency — bucket/node accesses and bound distance
    evaluations — broken down by WHICH executor island did the work: one
    row per shard under the sharded layout (each shard scans its local
    bucket rows, so the rows expose load balance), a single row on the
    single-device layout.  ``SearchStats`` stays the fleet total; this is
    the telemetry layer's per-island view (``OverlapIndex.metrics()``).
    """

    buckets_visited: Array  # (S, Q) i32 per-shard bucket visits
    distances: Array  # (S, Q) i32 per-shard useful object distances
    bound_distances: Array  # (S, Q) i32 per-shard routing + bound distances


def device_forest(f: ForestArrays, *, quantize: bool = False) -> DeviceForest:
    """Upload the flattened forest; ``quantize=True`` stores bucket members
    int8 with per-member scales (kernels/ops.quantize_datastore layout) —
    4x less HBM traffic on the member scan; bounds/pivots stay f32."""
    bucket_x = jnp.asarray(f.bucket_x)
    bucket_scale = None
    if quantize:
        nb, cap, dim = bucket_x.shape
        xq, scale = kops.quantize_datastore(bucket_x.reshape(nb * cap, dim))
        bucket_x = xq.reshape(nb, cap, dim)
        bucket_scale = scale.reshape(nb, cap)
    return DeviceForest(
        index_centers=jnp.asarray(f.index_centers),
        index_radii=jnp.asarray(f.index_radii),
        neighbors=jnp.asarray(f.neighbors),
        bucket_x=bucket_x,
        bucket_ids=jnp.asarray(f.bucket_ids),
        bucket_mask=jnp.asarray(f.bucket_mask),
        bucket_pivot=jnp.asarray(f.bucket_pivot),
        bucket_radius=jnp.asarray(f.bucket_radius),
        bucket_index=jnp.asarray(f.bucket_index),
        bucket_scale=bucket_scale,
    )


def route_points(centers: Array, q: Array, *, kernel: bool = True) -> tuple[Array, Array]:
    """Alg. 2 STEP 1 routing: distances to index centers + closest index.

    Shared by the query path (knn_search) and the streaming ingest router
    (stream/ingest.ingest) — both assign a point to its nearest index center.
    Returns (d_idx (Q, I) squared distances, closest (Q,) i32).
    """
    d_idx = pairwise(q, centers, metric="sq_l2", use_kernel=kernel)  # (Q, I)
    return d_idx, jnp.argmin(d_idx, axis=1).astype(jnp.int32)


def route_eligibility(closest: Array, neighbors: Array) -> Array:
    """(Q, I) bool: closest index + its overlap-index neighbors, per query.

    Scatter formulation via ``segment_max``: each query contributes
    1 + MAXNBR (query, index) pairs; one segment per (query, index) cell.
    Replaces the (Q, I, MAXNBR) one-hot mask product — the one-hot path
    materialized O(Q * I * MAXNBR) work for what is O(Q * MAXNBR) pairs,
    which matters for forests with many indexes (ROADMAP item).
    """
    n_idx = neighbors.shape[0]
    qn = closest.shape[0]
    nbrs = neighbors[closest]  # (Q, MAXNBR)
    cand = jnp.concatenate(
        [closest[:, None], jnp.where(nbrs >= 0, nbrs, 0)], axis=1
    )  # (Q, 1 + MAXNBR), invalid links parked on index 0 with value 0
    val = jnp.concatenate(
        [jnp.ones((qn, 1), jnp.int32), (nbrs >= 0).astype(jnp.int32)], axis=1
    )
    seg = (cand.astype(jnp.int32) + n_idx * jnp.arange(qn, dtype=jnp.int32)[:, None]).ravel()
    sel = jax.ops.segment_max(val.ravel(), seg, num_segments=qn * n_idx)
    return sel.reshape(qn, n_idx) > 0


class _Carry(NamedTuple):
    top_d: Array  # (Q, kk) ascending squared dists
    top_i: Array  # (Q, kk) ids
    t: Array
    visits: Array
    ndist: Array
    npad: Array


class ScanOut(NamedTuple):
    """One executor's bounded-scan result BEFORE the stats rollup: the top-k
    carry plus the raw per-query cost counters.  On the single-device path
    this is the whole search; on the sharded path each shard produces one
    and the island merges ``top_d``/``top_i`` (``merge_shard_topk``) and
    ``psum``s the counters before ``scan_stats`` builds ``SearchStats``."""

    top_d: Array  # (Q, kk) ascending SQUARED distances
    top_i: Array  # (Q, kk) global object ids, -1 pad
    visits: Array  # (Q,) i32
    ndist: Array  # (Q,) i32
    npad: Array  # (Q,) i32
    steps: Array  # () i32
    n_elig: Array  # (Q,) i32 eligible main buckets
    n_elig_d: Array  # (Q,) i32 eligible delta buckets
    # main-phase-only visit counts (visits - visits_main = delta visits);
    # the attribution layer decodes visited rows from this + the sorted
    # visit order.  Appended with a default so positional/keyword
    # constructions that predate it stay valid; dead on the normal search
    # path (DCE'd out of compiled executors that don't return it).
    visits_main: Array | None = None


def _sorted_bounds(lb: Array, beam: int) -> tuple[Array, Array, Array]:
    """Ascending visit order + sorted bounds, padded to a beam multiple."""
    nb = lb.shape[1]
    order = jnp.argsort(lb, axis=1)
    lb_sorted = jnp.take_along_axis(lb, order, axis=1)
    n_steps = -(-nb // beam)  # ceil
    pad = n_steps * beam - nb
    if pad:
        order = jnp.pad(order, ((0, 0), (0, pad)))
        lb_sorted = jnp.pad(lb_sorted, ((0, 0), (0, pad)), constant_values=jnp.inf)
    return order, lb_sorted, jnp.int32(n_steps)


def _scan_phase(
    carry: _Carry,
    q: Array,
    order: Array,
    lb_sorted: Array,
    n_steps: Array,
    beam: int,
    scan_step,
    scan_x: Array,
    scan_ids: Array,
    scan_scale: Array | None,
    bucket_count: Array,
    cap: int,
    qmask: Array | None = None,
) -> _Carry:
    """One bounded best-first scan phase (main buckets or delta buckets).

    Visits buckets in ascending-lb order until lb > kth-best for every query
    (exact termination: lb is sorted and kth-best is non-increasing).  The
    carry's top-k streams THROUGH phases: the delta phase starts from the
    main phase's result and keeps merging into the same (Q, kk) state.

    ``qmask`` (Q,) bool — optional per-query kill switch: a False query
    visits NOTHING in this phase (not even the +inf-bound spill that an
    empty carry would otherwise trigger).  The routed layout uses it to
    turn a pruned (query, host) pair into genuine zero work on that host;
    ``None`` (every other caller) compiles to the unmasked predicate.
    """

    def active_mask(c: _Carry) -> Array:
        kth = jnp.sqrt(c.top_d[:, -1])  # inf until kk found
        cur_lb = jax.lax.dynamic_slice_in_dim(lb_sorted, c.t * beam, beam, axis=1)
        act = cur_lb <= kth[:, None]  # (Q, beam)
        if qmask is not None:
            act = act & qmask[:, None]
        return act

    def cond(c: _Carry) -> Array:
        return (c.t < n_steps) & jnp.any(active_mask(c))

    def body(c: _Carry) -> _Carry:
        act = active_mask(c)  # (Q, beam)
        bsel = jax.lax.dynamic_slice_in_dim(order, c.t * beam, beam, axis=1)
        # fused gather -> squared-L2 -> running top-k merge (one kernel step;
        # the (Q, beam, C, D) gather never materializes on the kernel path)
        new_d, new_i = scan_step(
            q, scan_x, scan_ids, bsel, act, c.top_d, c.top_i, scan_scale
        )
        n_members = jnp.where(act, bucket_count[bsel], 0)  # (Q, beam)
        return _Carry(
            top_d=new_d,
            top_i=new_i,
            t=c.t + 1,
            visits=c.visits + jnp.sum(act, axis=1, dtype=jnp.int32),
            ndist=c.ndist + jnp.sum(n_members, axis=1, dtype=jnp.int32),
            npad=c.npad + jnp.sum(act, axis=1, dtype=jnp.int32) * cap,
        )

    return jax.lax.while_loop(cond, body, carry)


def route_select(
    forest: DeviceForest, q: Array, *, mode: str = "forest", kernel: bool = True
) -> tuple[Array, Array, Array]:
    """Alg. 2 STEP 1: per-query index selection + the routing cost counters.

    Returns (sel (Q, I) bool, route_dists (Q,) i32, route_cmps (Q,) i32).
    Touches only the REPLICATED forest leaves (centers, neighbors), so the
    sharded island runs it unchanged on every shard — identical selection
    everywhere is what makes the per-shard scans exact.
    """
    qn = q.shape[0]
    n_idx = forest.index_centers.shape[0]
    if mode == "forest":
        _, closest = route_points(forest.index_centers, q, kernel=kernel)
        sel = route_eligibility(closest, forest.neighbors)  # (Q, I)
        route_dists = jnp.full((qn,), n_idx, jnp.int32)
        route_cmps = jnp.full((qn,), n_idx, jnp.int32)
    elif mode == "all":
        sel = jnp.ones((qn, n_idx), jnp.bool_)
        route_dists = jnp.zeros((qn,), jnp.int32)
        route_cmps = jnp.zeros((qn,), jnp.int32)
    else:
        raise ValueError(f"mode {mode!r}")
    return sel, route_dists, route_cmps


class PhaseBounds(NamedTuple):
    """STEP 2a output for one scan phase: the ascending visit order, the
    sorted lower bounds (ineligible rows at +inf, padded to a beam multiple)
    and the per-query eligible-row count for the cost instrumentation."""

    order: Array  # (Q, n_steps*beam) int
    lb_sorted: Array  # (Q, n_steps*beam) f32, ascending, +inf tail
    n_elig: Array  # (Q,) i32


def bucket_bounds(
    forest: DeviceForest,
    q: Array,
    bucket_sel: Array,
    *,
    beam: int = 1,
    kernel: bool = True,
) -> PhaseBounds:
    """STEP 2a over the main bucket rows: eligibility -> pivot lower bounds
    -> sorted visit order.

    Split from the scan body because the SORT must not share a program
    region with the scan's ``while_loop`` under ``shard_map``+``jit`` (the
    SPMD partitioner miscompiles sort-feeds-while on manually sharded
    operands; see ``distributed/knn_island.sharded_search``).  The
    single-device path simply calls both stages back to back — identical
    ops, identical results.
    """
    elig = bucket_sel[:, forest.bucket_index]  # (Q, NB) -> sel[q, owner(b)]
    # Bounds are only *used* for eligible buckets (ineligible ones are masked
    # to +inf below), so the paper's Fig. 21 cost metric charges exactly the
    # eligible count per query — not all NB rows of the distance matrix.
    n_elig = jnp.sum(elig, axis=1, dtype=jnp.int32)  # (Q,)
    d_piv = pairwise(q, forest.bucket_pivot, metric="l2", use_kernel=kernel)  # (Q, NB)
    lb = jnp.maximum(d_piv - forest.bucket_radius[None, :], 0.0)
    lb = jnp.where(elig, lb, jnp.inf)
    order, lb_sorted, _ = _sorted_bounds(lb, beam)
    return PhaseBounds(order=order, lb_sorted=lb_sorted, n_elig=n_elig)


def delta_bounds(
    delta: DeltaView,
    q: Array,
    delta_sel: Array,
    *,
    beam: int = 1,
    kernel: bool = True,
) -> PhaseBounds:
    """STEP 2a over the delta rows (one streaming bucket per index; empty
    buffers are never eligible)."""
    dcount = jnp.sum(delta.mask, axis=1, dtype=jnp.int32)  # (I_d,)
    elig_d = delta_sel & (dcount[None, :] > 0)  # (Q, I_d)
    n_elig_d = jnp.sum(elig_d, axis=1, dtype=jnp.int32)
    d_piv_d = pairwise(q, delta.pivot, metric="l2", use_kernel=kernel)
    lb_d = jnp.maximum(d_piv_d - delta.radius[None, :], 0.0)
    lb_d = jnp.where(elig_d, lb_d, jnp.inf)
    order_d, lb_d_sorted, _ = _sorted_bounds(lb_d, beam)
    return PhaseBounds(order=order_d, lb_sorted=lb_d_sorted, n_elig=n_elig_d)


def scan_sorted(
    forest: DeviceForest,
    q: Array,
    bounds: PhaseBounds,
    *,
    kk: int,
    beam: int = 1,
    kernel: bool = True,
    delta: DeltaView | None = None,
    dbounds: PhaseBounds | None = None,
    qmask: Array | None = None,
) -> ScanOut:
    """STEP 2b/2c executor body: bounded best-first scan over the bucket
    rows (and delta rows) it is given, visiting in the precomputed
    ``PhaseBounds`` order.  Contains the ``while_loop`` but NO sort — see
    ``bucket_bounds`` for why the stages are split.  ``qmask`` (Q,) bool
    suppresses both phases per query (see ``_scan_phase``; the routing
    tier's host pruning)."""
    qn = q.shape[0]
    _, cap, _ = forest.bucket_x.shape

    init = _Carry(
        top_d=jnp.full((qn, kk), jnp.inf),
        top_i=jnp.full((qn, kk), -1, jnp.int32),
        t=jnp.int32(0),
        visits=jnp.zeros((qn,), jnp.int32),
        ndist=jnp.zeros((qn,), jnp.int32),
        npad=jnp.zeros((qn,), jnp.int32),
    )

    # real (unpadded) member count per bucket, for the cost instrumentation
    bucket_count = jnp.sum(forest.bucket_mask, axis=1, dtype=jnp.int32)  # (NB,)
    if kernel:
        # tile-align the datastore-sized operands ONCE, outside the loop —
        # the kernel wrapper's defensive per-call pads become no-ops
        scan_x, scan_ids, scan_scale = kops.bucket_scan_prepad(
            forest.bucket_x, forest.bucket_ids, forest.bucket_scale
        )
        scan_step = kops.bucket_scan_topk
    else:
        scan_x, scan_ids, scan_scale = (
            forest.bucket_x, forest.bucket_ids, forest.bucket_scale,
        )
        scan_step = kref.bucket_scan_topk_ref

    # order/lb_sorted are padded to exactly n_steps*beam (``_sorted_bounds``)
    n_steps = jnp.int32(bounds.order.shape[1] // beam)
    out = _scan_phase(
        init, q, bounds.order, bounds.lb_sorted, n_steps, beam,
        scan_step, scan_x, scan_ids, scan_scale, bucket_count, cap,
        qmask=qmask,
    )
    total_steps = out.t
    visits_main = out.visits

    n_elig_d = jnp.zeros((qn,), jnp.int32)
    if delta is not None:
        dcap = delta.x.shape[1]
        dcount = jnp.sum(delta.mask, axis=1, dtype=jnp.int32)  # (I_d,)
        if kernel:
            dx, dids, _ = kops.bucket_scan_prepad(delta.x, delta.ids, None)
            dstep = kops.delta_scan_topk
        else:
            dx, dids, dstep = delta.x, delta.ids, kref.bucket_scan_topk_ref
        n_steps_d = jnp.int32(dbounds.order.shape[1] // beam)
        out = _scan_phase(
            out._replace(t=jnp.int32(0)), q, dbounds.order, dbounds.lb_sorted,
            n_steps_d, beam, dstep, dx, dids, None, dcount, dcap,
            qmask=qmask,
        )
        total_steps = total_steps + out.t
        n_elig_d = dbounds.n_elig

    return ScanOut(
        top_d=out.top_d,
        top_i=out.top_i,
        visits=out.visits,
        ndist=out.ndist,
        npad=out.npad,
        steps=total_steps,
        n_elig=bounds.n_elig,
        n_elig_d=n_elig_d,
        visits_main=visits_main,
    )


def local_scan(
    forest: DeviceForest,
    q: Array,
    bucket_sel: Array,
    *,
    kk: int,
    beam: int = 1,
    kernel: bool = True,
    delta: DeltaView | None = None,
    delta_sel: Array | None = None,
) -> ScanOut:
    """STEP 2 executor body over the bucket rows AND delta rows it is given.

    The single-device path passes the whole forest; the sharded island calls
    the split stages (``bucket_bounds``/``delta_bounds`` in one island,
    ``scan_sorted`` in another) per shard on the LOCAL bucket/delta rows —
    the scan itself never knows which.  ``bucket_sel`` (Q, I') is the
    selection table indexed by ``forest.bucket_index``; I' may exceed the
    true index count so that padded shard-alignment buckets can point at an
    always-False sentinel column.  ``delta_sel`` (Q, I_d) selects per delta
    row (defaults to ``bucket_sel``).

    Returns the raw ``ScanOut``: top-kk carry (squared distances) + cost
    counters, ready for ``merge_shard_topk`` / ``scan_stats``.
    """
    bounds = bucket_bounds(forest, q, bucket_sel, beam=beam, kernel=kernel)
    dbounds = None
    if delta is not None:
        if delta_sel is None:
            delta_sel = bucket_sel
        dbounds = delta_bounds(delta, q, delta_sel, beam=beam, kernel=kernel)
    return scan_sorted(
        forest, q, bounds, kk=kk, beam=beam, kernel=kernel,
        delta=delta, dbounds=dbounds,
    )


def merge_shard_topk(
    top_d: Array, top_i: Array, *, k: int, axis_name: str
) -> tuple[Array, Array]:
    """Cross-shard top-k merge: gather k candidates per shard, keep the
    global k.  Exactly the flat-datastore merge ``serve/retrieval.knn_logits``
    runs — collective volume is k * 2 scalars per query per shard, never the
    datastore.  k-per-shard guarantees exactness: the global top-k is a
    subset of the union of per-shard top-ks.
    """
    d_all = jax.lax.all_gather(top_d, axis_name, axis=1, tiled=True)  # (Q, S*k)
    i_all = jax.lax.all_gather(top_i, axis_name, axis=1, tiled=True)
    neg, pos = jax.lax.top_k(-d_all, k)
    return -neg, jnp.take_along_axis(i_all, pos, axis=1)


def scan_stats(
    route_dists: Array, route_cmps: Array, out: ScanOut, *, kk: int
) -> SearchStats:
    """Roll a (possibly merged) ``ScanOut`` + routing counters into the
    paper's ``SearchStats``.  Shared by both executors so the instrumented
    cost model cannot drift between layouts."""
    return SearchStats(
        buckets_visited=out.visits,
        distances=out.ndist,
        bound_distances=route_dists + out.n_elig + out.n_elig_d,
        padded_distances=out.npad,
        comparisons=route_cmps
        + out.n_elig + out.n_elig_d  # bound comparisons (eligible buckets)
        # top-k merge comparisons over every padded lane actually scanned
        # (npad carries each phase's own bucket capacity)
        + out.npad * jnp.int32(int(np.ceil(np.log2(max(kk, 2))))),
        steps=out.steps,
    )


def knn_search_impl(
    forest: DeviceForest,
    q: Array,
    *,
    k: int,
    mode: str = "forest",
    beam: int = 1,
    kernel: bool = True,
    delta: DeltaView | None = None,
) -> tuple[Array, Array, SearchStats]:
    """Batched kNN over the forest. Returns (dists (Q,k), ids (Q,k), stats).

    This is the EXECUTOR: a pure, un-jitted function.  The facade's planner
    (``repro.api.plan.SearchPlan``) closes a ``jax.jit`` over it once per
    static-option tuple ``(k, mode, beam, kernel, quantize, delta shape)``
    and caches the compiled executable so repeated searches with stable
    shapes never re-trace.  ``knn_search`` below is the legacy jitted entry,
    kept as a deprecation shim.

    dists are true L2 distances; ids are global object ids (-1 if fewer than
    k objects were reachable).

    ``kernel=True`` (default) routes every distance — STEP 1 routing, STEP 2a
    bucket bounds, and the STEP 2b fused gather+distance+top-k bucket scan —
    through the ``repro.kernels.ops`` dispatch layer (compiled Pallas on TPU,
    interpret under REPRO_FORCE_PALLAS=1, jnp reference elsewhere).
    ``kernel=False`` forces the pure-jnp reference path end to end.

    ``delta`` (a DeltaView) adds the streaming delta buckets as a second scan
    phase: the same bounded best-first scan, seeded with the main phase's
    top-k carry, over the per-index append buffers.  Results are then exact
    over main forest + delta members (within the mode's selection semantics).
    """
    n_idx = forest.index_centers.shape[0]
    nb, cap, _ = forest.bucket_x.shape
    n_cap = nb * cap
    if delta is not None:
        n_cap += n_idx * delta.x.shape[1]
    kk = min(k, n_cap)

    sel, route_dists, route_cmps = route_select(forest, q, mode=mode, kernel=kernel)
    out = local_scan(
        forest, q, sel, kk=kk, beam=beam, kernel=kernel,
        delta=delta, delta_sel=sel,
    )
    stats = scan_stats(route_dists, route_cmps, out, kk=kk)
    return jnp.sqrt(out.top_d), out.top_i, stats


class VisitRows(NamedTuple):
    """Per-query visited-row evidence for the attribution layer
    (``obs/attribution.py``) — one uniform layout across device layouts.

    Exactness of the decode rests on a scan invariant: within one executor
    (one shard, one phase) the visited buckets are EXACTLY the prefix of
    the ascending-lower-bound visit order of length ``visits[s, q]`` — the
    scan walks ``order`` front to back and the termination predicate
    (``lb_sorted <= kth_best``) can only flip from visit to skip, never
    back, because ``lb_sorted`` ascends while kth-best is non-increasing.
    So (order, per-phase visit counts) reconstructs the visited set
    host-side without re-running anything.

    ``order`` concatenates the S per-shard LOCAL sorted orders along axis 1
    (block s spans columns ``[s*W, (s+1)*W)`` with ``W = order.shape[1] //
    S``; entries are SHARD-LOCAL row ids — global row = local + s *
    rows_per_shard).  The single layout is the S=1 special case where
    local == global.  ``dorder``/``dvisits`` are the delta phase's twin
    (``None`` when no delta phase was compiled in).
    """

    order: Array  # (Q, S*W) per-shard-local sorted visit orders, col-stacked
    visits: Array  # (S, Q) i32 MAIN-phase visited counts per shard
    dorder: Array | None  # (Q, S*Wd) delta visit orders
    dvisits: Array | None  # (S, Q) i32 delta-phase visited counts per shard


def knn_search_explain_impl(
    forest: DeviceForest,
    q: Array,
    *,
    k: int,
    mode: str = "forest",
    beam: int = 1,
    kernel: bool = True,
    delta: DeltaView | None = None,
) -> tuple[Array, Array, SearchStats, VisitRows]:
    """``knn_search_impl`` + the visited-row evidence (``VisitRows``).

    Runs the IDENTICAL op sequence as the normal executor — same routing,
    same bounds, same scan bodies with the same operands — and additionally
    returns the sorted visit orders and per-phase visit counts that were
    already computed along the way.  Results are therefore bitwise-identical
    to ``knn_search_impl`` (gated in-suite); the extra outputs are arrays
    the normal path computes and discards, not extra device work.
    """
    n_idx = forest.index_centers.shape[0]
    nb, cap, _ = forest.bucket_x.shape
    n_cap = nb * cap
    if delta is not None:
        n_cap += n_idx * delta.x.shape[1]
    kk = min(k, n_cap)

    sel, route_dists, route_cmps = route_select(forest, q, mode=mode, kernel=kernel)
    bounds = bucket_bounds(forest, q, sel, beam=beam, kernel=kernel)
    dbounds = None
    if delta is not None:
        dbounds = delta_bounds(delta, q, sel, beam=beam, kernel=kernel)
    out = scan_sorted(
        forest, q, bounds, kk=kk, beam=beam, kernel=kernel,
        delta=delta, dbounds=dbounds,
    )
    stats = scan_stats(route_dists, route_cmps, out, kk=kk)
    rows = VisitRows(
        order=bounds.order,
        visits=out.visits_main[None],
        dorder=None if dbounds is None else dbounds.order,
        dvisits=None if delta is None else (out.visits - out.visits_main)[None],
    )
    return jnp.sqrt(out.top_d), out.top_i, stats, rows


# Jitted executor shared by the legacy entry points below.  The facade does
# NOT use this cache — it owns one executor per SearchPlan (repro.api.plan).
knn_search_jit = functools.partial(
    jax.jit, static_argnames=("k", "mode", "beam", "kernel")
)(knn_search_impl)


def knn_search(
    forest: DeviceForest,
    q: Array,
    *,
    k: int,
    mode: str = "forest",
    beam: int = 1,
    kernel: bool = True,
    delta: DeltaView | None = None,
) -> tuple[Array, Array, SearchStats]:
    """Deprecated jitted entry — use ``repro.api.OverlapIndex.search``.

    Behaviour is unchanged (same executor, same jit cache); only the entry
    point moved: the facade plans/caches executors per static-option tuple
    and returns a structured ``SearchResult``.
    """
    warn_deprecated("repro.core.knn.knn_search", "repro.api.OverlapIndex.search")
    return knn_search_jit(
        forest, q, k=k, mode=mode, beam=beam, kernel=kernel, delta=delta
    )


# legacy escape hatch used by kernel tests to force re-dispatch after
# flipping REPRO_FORCE_PALLAS (the flag is read at trace time)
knn_search.clear_cache = knn_search_jit.clear_cache


@functools.partial(jax.jit, static_argnames=("k", "kernel"))
def knn_exact(x: Array, q: Array, *, k: int, kernel: bool = True) -> tuple[Array, Array]:
    """Brute-force oracle: exact kNN of q (Q, D) in x (N, D)."""
    d2 = pairwise(q, x, metric="sq_l2", use_kernel=kernel)
    neg, idx = jax.lax.top_k(-d2, min(k, x.shape[0]))
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), idx


def knn_search_host(
    forest: ForestArrays,
    q,
    *,
    k: int,
    mode: str = "forest",
    beam: int = 1,
    kernel: bool = True,
    quantize: bool = False,
    delta: DeltaView | None = None,
):
    """Deprecated host wrapper — use ``repro.api.OverlapIndex.search``
    (numpy results + python-int stats, plus plan caching and persistence).

    ``kernel`` selects the kernels/ops dispatch path (see knn_search_impl);
    ``quantize`` stores bucket members int8 on device (device_forest);
    ``delta`` scans the streaming delta buckets as a second phase.
    """
    warn_deprecated(
        "repro.core.knn.knn_search_host", "repro.api.OverlapIndex.search"
    )
    df = device_forest(forest, quantize=quantize)
    d, i, s = knn_search_jit(
        df, jnp.asarray(q, jnp.float32), k=k, mode=mode, beam=beam, kernel=kernel,
        delta=delta,
    )
    # Def. 4: |X| <= k  =>  answer set is the whole dataset.  (Same
    # truncation as OverlapIndex.search: bucket/delta membership is a
    # strict partition of the objects, so this count equals its n_total.)
    n_real = int(forest.bucket_mask.sum())
    if delta is not None:
        n_real += int(jnp.sum(delta.mask))
    if d.shape[1] > min(k, n_real):
        d = d[:, : min(k, n_real)]
        i = i[:, : min(k, n_real)]
    from repro.api.plan import stats_to_host  # lazy: api sits above core

    return np.asarray(d), np.asarray(i), stats_to_host(s)
