"""Metric-space primitives (paper §2, Definitions 1-5).

All distance computations are batched, jittable, and dispatch to the Pallas
pairwise kernel (``repro.kernels.ops``) above a size threshold; below it they
use the pure-jnp path (identical math, cheaper dispatch).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array

# ---------------------------------------------------------------------------
# Distance functions d : S x S -> R+  (p1-p4 of Definition 1)
# ---------------------------------------------------------------------------


def sq_l2(x: Array, y: Array) -> Array:
    """Squared euclidean distance between single objects (D,) x (D,)."""
    d = x - y
    return jnp.sum(d * d)


def l2(x: Array, y: Array) -> Array:
    return jnp.sqrt(jnp.maximum(sq_l2(x, y), 0.0))


def l1(x: Array, y: Array) -> Array:
    return jnp.sum(jnp.abs(x - y))


def cosine(x: Array, y: Array) -> Array:
    """Cosine *distance* (1 - cosine similarity). Not a metric (fails p4 in
    general) but commonly used for embedding datastores; exposed for the
    retrieval layer, never for the tree-bound math (which assumes p4)."""
    nx = jnp.linalg.norm(x) + 1e-12
    ny = jnp.linalg.norm(y) + 1e-12
    return 1.0 - jnp.dot(x, y) / (nx * ny)


METRICS: dict[str, Callable[[Array, Array], Array]] = {
    "l2": l2,
    "sq_l2": sq_l2,
    "l1": l1,
    "cosine": cosine,
}


# ---------------------------------------------------------------------------
# Batched pairwise distances
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("metric", "use_kernel"))
def pairwise(q: Array, x: Array, *, metric: str = "l2", use_kernel: bool = True) -> Array:
    """Pairwise distance matrix (Q, N) between rows of q (Q, D) and x (N, D).

    ``use_kernel`` routes the L2 family through the Pallas tiled kernel when
    shapes are MXU-friendly; the fallback is the jnp expansion that the kernel
    is validated against (kernels/ref.py).
    """
    if metric in ("l2", "sq_l2"):
        if use_kernel:
            # Deferred import: kernels depend on core for oracle definitions.
            from repro.kernels import ops as kops

            sq = kops.pairwise_sq_l2(q, x)
        else:
            sq = _pairwise_sq_l2_jnp(q, x)
        return sq if metric == "sq_l2" else jnp.sqrt(jnp.maximum(sq, 0.0))
    if metric == "l1":
        return jnp.sum(jnp.abs(q[:, None, :] - x[None, :, :]), axis=-1)
    if metric == "cosine":
        qn = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-12)
        xn = x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-12)
        return 1.0 - qn @ xn.T
    raise ValueError(f"unknown metric {metric!r}")


def _pairwise_sq_l2_jnp(q: Array, x: Array) -> Array:
    """||q||^2 + ||x||^2 - 2 q.x — the expansion the MXU kernel implements."""
    qq = jnp.sum(q * q, axis=-1)[:, None]
    xx = jnp.sum(x * x, axis=-1)[None, :]
    cross = q @ x.T
    return jnp.maximum(qq + xx - 2.0 * cross, 0.0)


def distances_to_point(x: Array, p: Array, *, metric: str = "l2") -> Array:
    """Distances (N,) from every row of x (N, D) to a single point p (D,)."""
    return pairwise(p[None, :], x, metric=metric, use_kernel=False)[0]


def check_metric_axioms(d: Callable, pts: Array, atol: float = 1e-5) -> dict[str, bool]:
    """Empirically check p1-p4 on a point sample. Used by property tests."""
    n = pts.shape[0]
    dm = jax.vmap(lambda a: jax.vmap(lambda b: d(a, b))(pts))(pts)
    non_neg = bool(jnp.all(dm >= -atol))
    sym = bool(jnp.allclose(dm, dm.T, atol=atol))
    ident = bool(jnp.all(jnp.abs(jnp.diag(dm)) <= atol))
    # For all (i, j, k): d(i,j) + d(j,k) >= d(i,k).
    tri = bool(jnp.all(dm[:, :, None] + dm[None, :, :] >= dm[:, None, :] - atol))
    return {"non_negativity": non_neg, "symmetry": sym, "identity": ident, "triangle": tri}
