"""Overlap estimation heuristics — the paper's core contribution (§4.2).

Three heuristics score the overlap of two hyperball partitions
``P_i = (pivot p_i, radius r_i)`` with a rate in [0, 1]:

* VBM (Volume-Based, Defs. 7-9): exact n-ball intersection volume via
  hyperspherical-cap volumes.  The paper's cap integral
  ``(pi^((n-1)/2) r^n / Gamma((n+1)/2)) * int_0^theta sin^n(t) dt``
  is evaluated in closed form with the regularized incomplete beta function
  (Li, 2011):  ``V_cap = 1/2 V_ball(r) I_{sin^2 theta}((n+1)/2, 1/2)`` for
  ``theta <= pi/2`` and ``V_ball - 1/2 V_ball I_{sin^2 theta}`` otherwise.
  All volumes are kept in log space — at n = 20 dims, ``r^n`` overflows f32
  long before the *ratio* (which is all the rate needs) becomes ill-defined.

* DBM (Distance-Based, Def. 10): ``D = (h1 + h2) / d(p1, p2)`` where ``h_i``
  are the cap heights.  (In the partial-overlap case ``h1 + h2`` reduces to
  ``r1 + r2 - d``; we compute via the cap geometry for faithfulness.)

* OBM (Object-Based, Def. 11): ``A = |A| / (|P1| + |P2|)`` where ``A`` is the
  set of objects lying inside BOTH balls.  Denominator counts objects
  *assigned* to each partition (the partitions are sets of objects);
  numerator counts ball co-membership, matching the paper's Def. 11.

Degenerate cases shared by all three (Defs. 7/10/11):
  rate = 0  if d >= r1 + r2          (disjoint)
  rate = 1  if d <= |r1 - r2|        (containment)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.scipy.special import betainc, gammaln

Array = jax.Array

_EPS = 1e-12


# ---------------------------------------------------------------------------
# Hyperball geometry (Definitions 8 & 9)
# ---------------------------------------------------------------------------


def ball_log_volume(n_dim: int | Array, r: Array) -> Array:
    """log V of an n-ball of radius r (Def. 8), -inf for r == 0."""
    n = jnp.asarray(n_dim, jnp.float32)
    logr = jnp.log(jnp.maximum(r, _EPS))
    return 0.5 * n * jnp.log(jnp.pi) - gammaln(0.5 * n + 1.0) + n * logr


def cap_cos_theta(r_i: Array, r_j: Array, d: Array) -> Array:
    """cos(theta_i) of the cap cut into ball i by ball j (Def. 9, Eq. 12)."""
    denom = jnp.maximum(2.0 * r_i * d, _EPS)
    return jnp.clip((r_i**2 + d**2 - r_j**2) / denom, -1.0, 1.0)


def cap_height(r_i: Array, cos_theta_i: Array) -> Array:
    """h_i = r_i (1 - cos(theta_i))  (Def. 9, Eq. 11)."""
    return r_i * (1.0 - cos_theta_i)


def cap_log_volume(n_dim: int | Array, r: Array, cos_theta: Array) -> Array:
    """log volume of the hyperspherical cap with polar angle theta (Def. 9).

    Closed form of the paper's sin^n integral via the regularized incomplete
    beta function.  Handles theta > pi/2 (cap larger than a half-ball, which
    occurs when one center falls deep inside the other ball).
    """
    n = jnp.asarray(n_dim, jnp.float32)
    sin2 = jnp.clip(1.0 - cos_theta**2, 0.0, 1.0)
    # I_{sin^2 theta}((n+1)/2, 1/2) in [0, 1]
    reg = betainc(0.5 * (n + 1.0), 0.5, sin2)
    log_ball = ball_log_volume(n_dim, r)
    log_half_ball = log_ball + jnp.log(0.5)
    log_small = log_half_ball + jnp.log(jnp.maximum(reg, _EPS))
    # theta > pi/2  =>  V_cap = V_ball - V_cap(pi - theta).  Stay in log
    # space: exponentiating the raw volumes overflows f32 for n >~ 20 dims
    # (exactly what this module promises to avoid); the *ratio*
    # exp(log_small - log_ball) = reg/2 <= 1/2 is always representable.
    ratio = jnp.exp(jnp.minimum(log_small - log_ball, 0.0))
    log_big = log_ball + jnp.log1p(-jnp.minimum(ratio, 1.0 - _EPS))
    return jnp.where(cos_theta >= 0.0, log_small, log_big)


def intersection_log_volume(n_dim: int | Array, r1: Array, r2: Array, d: Array) -> Array:
    """log of the lens volume (Def. 7, Eq. 6), for the partial-overlap case."""
    c1 = cap_cos_theta(r1, r2, d)
    c2 = cap_cos_theta(r2, r1, d)
    lv1 = cap_log_volume(n_dim, r1, c1)
    lv2 = cap_log_volume(n_dim, r2, c2)
    return jnp.logaddexp(lv1, lv2)


# ---------------------------------------------------------------------------
# Rates (Defs. 7, 10, 11) — scalar-pair versions, then pairwise matrices
# ---------------------------------------------------------------------------


def _select_cases(d: Array, r1: Array, r2: Array, partial: Array) -> Array:
    disjoint = d >= (r1 + r2)
    contained = d <= jnp.abs(r1 - r2)
    return jnp.where(disjoint, 0.0, jnp.where(contained, 1.0, partial))


def vbm_rate(r1: Array, r2: Array, d: Array, n_dim: int) -> Array:
    """Volume rate V (Def. 7, Eq. 7): lens volume / (V1 + V2)."""
    log_lens = intersection_log_volume(n_dim, r1, r2, d)
    log_tot = jnp.logaddexp(ball_log_volume(n_dim, r1), ball_log_volume(n_dim, r2))
    partial = jnp.exp(jnp.clip(log_lens - log_tot, -80.0, 0.0))
    return _select_cases(d, r1, r2, partial)


def dbm_rate(r1: Array, r2: Array, d: Array) -> Array:
    """Distance rate D (Def. 10): (h1 + h2) / d."""
    h1 = cap_height(r1, cap_cos_theta(r1, r2, d))
    h2 = cap_height(r2, cap_cos_theta(r2, r1, d))
    partial = (h1 + h2) / jnp.maximum(d, _EPS)
    return jnp.clip(_select_cases(d, r1, r2, partial), 0.0, 1.0)


def obm_rate(n_shared: Array, n1: Array, n2: Array, r1: Array, r2: Array, d: Array) -> Array:
    """Object rate A (Def. 11): |A| / (|P1| + |P2|)."""
    partial = n_shared / jnp.maximum(n1 + n2, 1.0)
    return _select_cases(d, r1, r2, partial)


# ---------------------------------------------------------------------------
# Pairwise overlap matrices over a set of partitions
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_dim", "method"))
def overlap_matrix_geometric(
    pivots: Array, radii: Array, *, n_dim: int, method: str
) -> Array:
    """(C, C) overlap-rate matrix for VBM / DBM. Diagonal forced to 0."""
    from repro.core.metric import pairwise  # local import to avoid cycle

    d = pairwise(pivots, pivots, metric="l2", use_kernel=False)
    r1 = radii[:, None]
    r2 = radii[None, :]
    if method == "vbm":
        rates = vbm_rate(r1, r2, d, n_dim)
    elif method == "dbm":
        rates = dbm_rate(r1, r2, d)
    else:
        raise ValueError(f"geometric overlap method {method!r}")
    c = radii.shape[0]
    return rates * (1.0 - jnp.eye(c, dtype=rates.dtype))


@jax.jit
def ball_membership(x: Array, pivots: Array, radii: Array) -> Array:
    """(N, C) bool: object n lies inside ball c."""
    from repro.core.metric import pairwise

    d = pairwise(x, pivots, metric="l2", use_kernel=False)
    return d <= radii[None, :]


@jax.jit
def overlap_matrix_objects(
    x: Array, assign: Array, pivots: Array, radii: Array
) -> Array:
    """(C, C) OBM rate matrix (Def. 11) from data ``x`` and partition
    assignment ``assign`` (N,) int32."""
    from repro.core.metric import pairwise

    c = pivots.shape[0]
    member = ball_membership(x, pivots, radii).astype(jnp.float32)  # (N, C)
    shared = member.T @ member  # (C, C) co-membership counts
    counts = jnp.zeros((c,), jnp.float32).at[assign].add(1.0)
    d = pairwise(pivots, pivots, metric="l2", use_kernel=False)
    rates = obm_rate(shared, counts[:, None], counts[None, :], radii[:, None], radii[None, :], d)
    return rates * (1.0 - jnp.eye(c, dtype=rates.dtype))


@jax.jit
def max_neighbor_rate(rates: Array) -> Array:
    """(I,) worst off-diagonal overlap rate per partition.

    The scalar each partition is judged by — at build time against
    (xi_min, xi_max) by the decision stage, online against xi_rebuild by the
    streaming drift monitor (stream/maintenance.OverlapMonitor)."""
    c = rates.shape[0]
    return jnp.max(rates * (1.0 - jnp.eye(c, dtype=rates.dtype)), axis=1)


# ---------------------------------------------------------------------------
# Overlap-method registry — VBM/DBM/OBM are *entries*, not special cases
# ---------------------------------------------------------------------------
#
# Every consumer (decision.decide, stream.maintenance.OverlapMonitor, the
# OverlapIndex facade) resolves methods through this table, so a hybrid or
# learned heuristic registered at runtime flows through the whole pipeline
# without touching any dispatch site.


@dataclasses.dataclass(frozen=True)
class OverlapMethod:
    """One registered overlap heuristic.

    ``matrix_fn(pivots, radii, *, x=None, assign=None) -> (C, C)`` rate
    matrix in [0, 1] with a zero diagonal.  ``needs_objects`` marks methods
    defined over the objects themselves (like the paper's OBM, Def. 11) —
    callers must then supply the dataset ``x`` and partition ``assign``, and
    cost accounting charges the per-object membership pass.
    """

    name: str
    matrix_fn: Callable[..., Array]
    needs_objects: bool = False


_REGISTRY: dict[str, OverlapMethod] = {}


def register_overlap_method(
    name: str,
    matrix_fn: Callable[..., Array],
    *,
    needs_objects: bool = False,
    overwrite: bool = False,
) -> OverlapMethod:
    """Register an overlap heuristic under ``name`` (see OverlapMethod)."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(
            f"overlap method {name!r} is already registered; pass "
            "overwrite=True to replace it"
        )
    entry = OverlapMethod(name=name, matrix_fn=matrix_fn, needs_objects=needs_objects)
    _REGISTRY[name] = entry
    return entry


def unregister_overlap_method(name: str) -> None:
    _REGISTRY.pop(name, None)


def available_overlap_methods() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_overlap_method(name: str) -> OverlapMethod:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown overlap method {name!r}; registered methods: "
            f"{', '.join(available_overlap_methods())} "
            "(repro.core.overlap.register_overlap_method to add one)"
        ) from None


def _vbm_matrix(pivots: Array, radii: Array, *, x=None, assign=None) -> Array:
    return overlap_matrix_geometric(
        pivots, radii, n_dim=int(pivots.shape[-1]), method="vbm"
    )


def _dbm_matrix(pivots: Array, radii: Array, *, x=None, assign=None) -> Array:
    return overlap_matrix_geometric(
        pivots, radii, n_dim=int(pivots.shape[-1]), method="dbm"
    )


def _obm_matrix(pivots: Array, radii: Array, *, x=None, assign=None) -> Array:
    return overlap_matrix_objects(x, assign, pivots, radii)


register_overlap_method("vbm", _vbm_matrix)
register_overlap_method("dbm", _dbm_matrix)
register_overlap_method("obm", _obm_matrix, needs_objects=True)


def overlap_matrix(
    method: str,
    pivots: Array,
    radii: Array,
    *,
    x: Array | None = None,
    assign: Array | None = None,
) -> Array:
    """Resolve ``method`` through the registry -> (C, C) rate matrix."""
    entry = get_overlap_method(method)
    if entry.needs_objects and (x is None or assign is None):
        raise ValueError(
            f"overlap method {method!r} is object-based and needs the dataset "
            "and partition assignment (pass x= and assign=)"
        )
    return entry.matrix_fn(pivots, radii, x=x, assign=assign)
