"""The paper's primary contribution: overlap-optimized metric-tree indexing
(DBSCAN preprocessing -> VBM/DBM/OBM overlap estimation -> decision ->
forest of BCCF trees) with a jittable, TPU-native kNN search."""
from repro.core.dbscan import DBSCANResult, dbscan, partitions_from_labels
from repro.core.decision import DecisionStats, Partition, decide
from repro.core.forest import ForestArrays, build_forest, swap_trees
from repro.core.knn import (
    DeltaView,
    DeviceForest,
    SearchStats,
    device_forest,
    knn_exact,
    knn_search,
    knn_search_host,
    knn_search_impl,
    knn_search_jit,
    route_eligibility,
    route_points,
)
from repro.core.overlap import (
    OverlapMethod,
    available_overlap_methods,
    ball_log_volume,
    cap_log_volume,
    dbm_rate,
    get_overlap_method,
    intersection_log_volume,
    max_neighbor_rate,
    obm_rate,
    overlap_matrix,
    register_overlap_method,
    unregister_overlap_method,
    vbm_rate,
)
from repro.core.pipeline import (
    BuildReport,
    IndexConfig,
    build_baseline,
    build_baseline_core,
    build_index,
    build_index_core,
    default_c_max,
    default_delta_capacity,
)

__all__ = [
    "DBSCANResult", "dbscan", "partitions_from_labels",
    "DecisionStats", "Partition", "decide",
    "ForestArrays", "build_forest", "swap_trees",
    "DeltaView", "DeviceForest", "SearchStats", "device_forest",
    "knn_exact", "knn_search", "knn_search_host", "knn_search_impl",
    "knn_search_jit",
    "route_eligibility", "route_points",
    "OverlapMethod", "available_overlap_methods", "get_overlap_method",
    "register_overlap_method", "unregister_overlap_method",
    "ball_log_volume", "cap_log_volume", "dbm_rate", "intersection_log_volume",
    "max_neighbor_rate", "obm_rate", "overlap_matrix", "vbm_rate",
    "BuildReport", "IndexConfig", "build_baseline", "build_baseline_core",
    "build_index", "build_index_core",
    "default_c_max", "default_delta_capacity",
]
