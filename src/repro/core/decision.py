"""Decision-making stage (paper §4.3): classify pairwise overlap into
low / medium / high using thresholds (xi_min, xi_max) and restructure the
partition set accordingly:

* high   [xi_max, 1]   : merge the two partitions (union-find contraction).
* medium [xi_min, xi_max): extract the lens objects into a third *overlap
                           partition*, registered as a NEIGHBOR of both.
* low    (0, xi_min)   : move the lens objects of the smaller-cap partition
                           into the other partition.

Ordering (the paper specifies pairwise rules but not an order; documented in
DESIGN.md §3): merges are applied first via union-find on all high pairs,
pivots/radii are recomputed, the overlap matrix is re-estimated on the merged
groups, then medium pairs (descending rate; each object is extracted at most
once), then low pairs.  This is host-orchestrated (like any production vector
store's build path); all bulk math (distances, memberships, overlap rates)
runs in JAX.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import overlap as ovl
from repro.core.metric import pairwise


@dataclass
class Partition:
    """A partition group emitted by the decision stage."""

    members: np.ndarray  # (m,) int64 object ids into the dataset
    pivot: np.ndarray  # (D,)
    radius: float
    neighbors: list[int] = field(default_factory=list)  # group-level links
    is_overlap_index: bool = False


@dataclass
class DecisionStats:
    n_initial: int = 0
    n_merged_pairs: int = 0
    n_overlap_indexes: int = 0
    n_low_moves: int = 0
    n_final: int = 0
    distance_computations: int = 0


class _UnionFind:
    def __init__(self, n: int):
        self.parent = list(range(n))

    def find(self, a: int) -> int:
        while self.parent[a] != a:
            self.parent[a] = self.parent[self.parent[a]]
            a = self.parent[a]
        return a

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[max(ra, rb)] = min(ra, rb)


def _recompute(x: np.ndarray, members: np.ndarray) -> tuple[np.ndarray, float]:
    pts = x[members]
    pivot = pts.mean(axis=0)
    radius = float(np.sqrt(((pts - pivot) ** 2).sum(-1)).max()) if len(pts) else 0.0
    return pivot.astype(np.float32), radius


def _rate_matrix(
    method: str, x: np.ndarray, pivots: np.ndarray, radii: np.ndarray, assign: np.ndarray
) -> np.ndarray:
    rates = ovl.overlap_matrix(
        method,
        jnp.asarray(pivots),
        jnp.asarray(radii),
        x=jnp.asarray(x),
        assign=jnp.asarray(assign),
    )
    return np.asarray(rates)


def _lens_members(
    x: np.ndarray, members: np.ndarray, pivot_other: np.ndarray, radius_other: float
) -> np.ndarray:
    """Object ids among ``members`` that also lie inside the other ball."""
    d = np.sqrt(((x[members] - pivot_other) ** 2).sum(-1))
    return members[d <= radius_other]


def decide(
    x: np.ndarray,
    pivots: np.ndarray,
    radii: np.ndarray,
    assign: np.ndarray,
    *,
    method: str,
    xi_min: float,
    xi_max: float,
) -> tuple[list[Partition], DecisionStats]:
    """Apply §4.3 to DBSCAN partitions. Returns final groups + stats.

    ``method`` resolves through the overlap-method registry
    (``core.overlap.register_overlap_method``) — the paper's VBM/DBM/OBM are
    the built-in entries; any registered heuristic works here.  Unknown
    names fail fast with the registered list, before any work is done.
    """
    entry = ovl.get_overlap_method(method)
    x = np.asarray(x, np.float32)
    n_dim = x.shape[1]
    c0 = len(radii)
    stats = DecisionStats(n_initial=c0)
    stats.distance_computations += c0 * c0  # pivot-pivot distances
    if entry.needs_objects:
        stats.distance_computations += len(x) * c0  # ball membership pass

    rates = _rate_matrix(method, x, pivots, radii, assign)

    # ---- high overlap: merge via union-find --------------------------------
    uf = _UnionFind(c0)
    hi, hj = np.where(np.triu(rates, 1) >= xi_max)
    for a, b in zip(hi.tolist(), hj.tolist()):
        uf.union(a, b)
    stats.n_merged_pairs = len(hi)
    root_of = np.array([uf.find(i) for i in range(c0)])
    roots, new_ids = np.unique(root_of, return_inverse=True)
    assign_g = new_ids[assign]  # object -> merged group
    groups: list[Partition] = []
    for g in range(len(roots)):
        members = np.where(assign_g == g)[0]
        pivot, radius = _recompute(x, members)
        groups.append(Partition(members=members, pivot=pivot, radius=radius))
        stats.distance_computations += len(members)

    # ---- re-estimate rates on merged groups --------------------------------
    if len(groups) > 1:
        pv = np.stack([g.pivot for g in groups])
        rd = np.array([g.radius for g in groups], np.float32)
        rates = _rate_matrix(method, x, pv, rd, assign_g)
        stats.distance_computations += len(groups) ** 2
        if entry.needs_objects:
            stats.distance_computations += len(x) * len(groups)
    else:
        rates = np.zeros((1, 1), np.float32)

    # ---- medium overlap: extract lens objects into overlap indexes ---------
    med_i, med_j = np.where(np.triu(rates, 1) >= xi_min)
    med_mask = rates[med_i, med_j] < xi_max
    pairs = sorted(
        zip(med_i[med_mask].tolist(), med_j[med_mask].tolist()),
        key=lambda ij: -rates[ij[0], ij[1]],
    )
    extracted = np.zeros(len(x), bool)
    for a, b in pairs:
        ga, gb = groups[a], groups[b]
        lens_a = _lens_members(x, ga.members, gb.pivot, gb.radius)
        lens_b = _lens_members(x, gb.members, ga.pivot, ga.radius)
        stats.distance_computations += len(ga.members) + len(gb.members)
        lens = np.concatenate([lens_a, lens_b])
        lens = lens[~extracted[lens]]
        if len(lens) == 0:
            continue
        extracted[lens] = True
        oid = len(groups)
        pivot, radius = _recompute(x, lens)
        stats.distance_computations += len(lens)
        groups.append(
            Partition(members=lens, pivot=pivot, radius=radius,
                      neighbors=[a, b], is_overlap_index=True)
        )
        ga.neighbors.append(oid)
        gb.neighbors.append(oid)
        ga.members = ga.members[~np.isin(ga.members, lens_a)]
        gb.members = gb.members[~np.isin(gb.members, lens_b)]
        stats.n_overlap_indexes += 1

    # ---- low overlap: reassign smaller-cap lens objects --------------------
    low_i, low_j = np.where((np.triu(rates, 1) > 0) & (np.triu(rates, 1) < xi_min))
    for a, b in zip(low_i.tolist(), low_j.tolist()):
        ga, gb = groups[a], groups[b]
        d = float(np.sqrt(((ga.pivot - gb.pivot) ** 2).sum()))
        if d <= 0:
            continue
        # smaller cap = smaller cap height (equivalently smaller cap volume
        # for same-dim balls cut by the same radical plane ordering)
        ha = float(ovl.cap_height(ga.radius, ovl.cap_cos_theta(ga.radius, gb.radius, d)))
        hb = float(ovl.cap_height(gb.radius, ovl.cap_cos_theta(gb.radius, ga.radius, d)))
        src, dst = (a, b) if ha <= hb else (b, a)
        gs, gd = groups[src], groups[dst]
        lens_s = _lens_members(x, gs.members, gd.pivot, gd.radius)
        lens_s = lens_s[~extracted[lens_s]]
        stats.distance_computations += len(gs.members)
        if len(lens_s) == 0:
            continue
        gs.members = gs.members[~np.isin(gs.members, lens_s)]
        gd.members = np.concatenate([gd.members, lens_s])
        stats.n_low_moves += len(lens_s)

    # ---- finalize: drop empty groups, recompute geometry, remap neighbors --
    keep = [i for i, g in enumerate(groups) if len(g.members) > 0]
    remap = {old: new for new, old in enumerate(keep)}
    final: list[Partition] = []
    for old in keep:
        g = groups[old]
        pivot, radius = _recompute(x, g.members)
        stats.distance_computations += len(g.members)
        final.append(
            Partition(
                members=g.members,
                pivot=pivot,
                radius=radius,
                neighbors=sorted({remap[nb] for nb in g.neighbors if nb in remap}),
                is_overlap_index=g.is_overlap_index,
            )
        )
    # symmetrize neighbor links
    for i, g in enumerate(final):
        for nb in g.neighbors:
            if i not in final[nb].neighbors:
                final[nb].neighbors.append(i)
    for g in final:
        g.neighbors.sort()
    stats.n_final = len(final)
    return final, stats
