"""End-to-end index pipeline (paper §4): preprocessing -> overlap estimation
-> decision-making -> forest construction.

The supported entry point is the ``repro.api.OverlapIndex`` facade
(``OverlapIndex.build(x, cfg)`` / ``OverlapIndex.baseline(x, cfg)``), which
wraps the implementations here:

  build_index_core(x, cfg)     — the paper's proposed method (registry
                                 overlap heuristics: VBM / DBM / OBM / ...)
  build_baseline_core(x, cfg)  — the BCCF-tree baseline (single tree)

``build_index`` / ``build_baseline`` remain as thin deprecation shims.
"""
from __future__ import annotations

import math
import time
import warnings
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core.dbscan import dbscan, partitions_from_labels
from repro.core.decision import Partition, decide
from repro.core.forest import ForestArrays, build_forest
from repro.deprecation import warn_deprecated


@dataclass(frozen=True)
class IndexConfig:
    method: str = "vbm"  # vbm | dbm | obm
    xi_min: float = 0.4
    xi_max: float = 0.8
    eps: float = 1.0
    min_pts: int = 8
    c_max: int | None = None  # default sqrt(n)
    pivot_method: str = "gh"  # proposed trees use GH partitioning (§4.3)
    seed: int = 0
    dbscan_block: int = 1024


@dataclass
class BuildReport:
    config: IndexConfig
    n_objects: int = 0
    n_clusters: int = 0
    n_indexes: int = 0
    n_overlap_indexes: int = 0
    dbscan_distances: int = 0
    overlap_distances: int = 0
    tree_distances: int = 0
    tree_comparisons: int = 0
    wall_time_s: float = 0.0
    detail: dict[str, Any] = field(default_factory=dict)


def default_c_max(n: int) -> int:
    """Paper Def. 12: c_max = sqrt(n)."""
    return max(4, int(math.sqrt(n)))


def default_delta_capacity(n: int) -> int:
    """Per-index streaming delta-bucket capacity (stream/ingest.py).

    One c_max-sized tail per index keeps the search-time degradation of an
    un-merged delta bounded by roughly one extra bucket visit per selected
    index (the delta bucket is the same size as a full leaf), while giving
    the drift monitor a fill-fraction signal on the same scale the tree
    itself buckets at.  Floor of 64 so tiny seed sets still buffer usefully.
    """
    return max(64, default_c_max(n))


def build_index_core(x, cfg: IndexConfig) -> tuple[ForestArrays, BuildReport]:
    """The paper's pipeline: DBSCAN -> overlap -> decision -> forest."""
    t0 = time.perf_counter()
    x = np.asarray(x, np.float32)
    n = len(x)
    c_max = cfg.c_max or default_c_max(n)
    report = BuildReport(config=cfg, n_objects=n)

    # (i) preprocessing — DBSCAN (§4.1)
    res = dbscan(x, cfg.eps, cfg.min_pts, block=cfg.dbscan_block)
    report.dbscan_distances = res.distance_computations
    report.n_clusters = res.n_clusters
    pivots, radii, assign = partitions_from_labels(x, res.labels, res.n_clusters)

    # (ii)+(iii) overlap estimation + decision (§4.2, §4.3)
    groups, dstats = decide(
        x, pivots, radii, assign,
        method=cfg.method, xi_min=cfg.xi_min, xi_max=cfg.xi_max,
    )
    report.overlap_distances = dstats.distance_computations
    report.n_overlap_indexes = dstats.n_overlap_indexes

    # indexing — one BCCF tree per group, GH pivots (§4.3)
    forest = build_forest(
        x, groups, c_max=c_max, pivot_method=cfg.pivot_method, seed=cfg.seed
    )
    report.n_indexes = forest.n_indexes
    report.tree_distances = forest.build_stats["tree_distances"]
    report.tree_comparisons = forest.build_stats["tree_comparisons"]
    report.wall_time_s = time.perf_counter() - t0
    report.detail = dict(
        decision=dstats.__dict__,
        dbscan_iterations=res.n_iterations,
        structure=forest.aggregate_structure(),
    )
    return forest, report


def build_baseline_core(
    x, cfg: IndexConfig | None = None
) -> tuple[ForestArrays, BuildReport]:
    """BCCF-tree baseline [5]: one recursive tree over all data.

    The documented baseline semantics is 2-means ('kmeans') pivot selection
    — that is what ``cfg=None`` builds.  An explicit ``cfg`` is HONORED,
    including its ``pivot_method`` (it used to be silently overridden with
    'kmeans'); a non-kmeans choice emits a UserWarning because the result is
    then a single-tree ablation, not the paper's BCCF baseline.
    """
    t0 = time.perf_counter()
    x = np.asarray(x, np.float32)
    n = len(x)
    if cfg is None:
        cfg = IndexConfig(pivot_method="kmeans")
    elif cfg.pivot_method != "kmeans":
        warnings.warn(
            f"build_baseline honors cfg.pivot_method={cfg.pivot_method!r}, but "
            "the documented BCCF baseline uses 'kmeans' 2-means pivots; pass "
            "pivot_method='kmeans' (or cfg=None) to reproduce the paper's "
            "baseline",
            UserWarning,
            stacklevel=3,
        )
    c_max = cfg.c_max or default_c_max(n)
    pivot = x.mean(axis=0).astype(np.float32)
    radius = float(np.sqrt(((x - pivot) ** 2).sum(-1)).max())
    groups = [Partition(members=np.arange(n), pivot=pivot, radius=radius)]
    forest = build_forest(
        x, groups, c_max=c_max, pivot_method=cfg.pivot_method, seed=cfg.seed
    )
    report = BuildReport(config=cfg, n_objects=n, n_clusters=1, n_indexes=1)
    report.tree_distances = forest.build_stats["tree_distances"]
    report.tree_comparisons = forest.build_stats["tree_comparisons"]
    report.wall_time_s = time.perf_counter() - t0
    report.detail = dict(structure=forest.aggregate_structure())
    return forest, report


def build_index(x, cfg: IndexConfig) -> tuple[ForestArrays, BuildReport]:
    """Deprecated — use ``repro.api.OverlapIndex.build(x, cfg)``."""
    warn_deprecated(
        "repro.core.pipeline.build_index", "repro.api.OverlapIndex.build"
    )
    return build_index_core(x, cfg)


def build_baseline(x, cfg: IndexConfig | None = None) -> tuple[ForestArrays, BuildReport]:
    """Deprecated — use ``repro.api.OverlapIndex.baseline(x, cfg)``."""
    warn_deprecated(
        "repro.core.pipeline.build_baseline", "repro.api.OverlapIndex.baseline"
    )
    return build_baseline_core(x, cfg)
