"""Streaming ingestion + overlap-driven online index maintenance.

The write path the paper's Big-IoT-Data premise needs: jittable batched
inserts into device-resident per-index delta buckets (ingest.py), searched
exactly alongside the frozen forest by core.knn's two-phase bucket scan,
with the paper's own VBM/DBM/OBM overlap heuristics re-evaluated online as
the drift trigger for hot index rebuilds (maintenance.py).  See README.md
in this directory for the ingest → monitor → rebuild lifecycle.
"""
from repro.stream.ingest import (
    DeltaBuffer,
    alloc_delta,
    delta_view,
    ingest,
    ingest_host,
    main_index_sums,
    pull_delta_meta,
    route_batch_host,
    updated_geometry,
)
from repro.stream.maintenance import (
    DriftReport,
    MaintenanceConfig,
    OverlapMonitor,
    StreamingForest,
    object_assignment,
    rebuild_indexes,
)

__all__ = [
    "DeltaBuffer", "alloc_delta", "delta_view", "ingest", "ingest_host",
    "main_index_sums", "pull_delta_meta", "route_batch_host",
    "updated_geometry",
    "DriftReport", "MaintenanceConfig", "OverlapMonitor", "StreamingForest",
    "object_assignment", "rebuild_indexes",
]
