"""Streaming ingestion (IoT continuous-arrival path) — jittable inserts.

The paper builds its overlap-optimized forest once and serves it frozen; IoT
data never stops arriving.  This module adds the missing write path without
touching the frozen main structure: every index owns one fixed-capacity
**delta bucket** — a device-resident SoA tail array mirroring the forest's
bucket layout (coords / global ids / -1 padding) — and incoming batches are

  1. routed to their nearest index center (Alg. 2 STEP-1 routing, the same
     ``core.knn.route_points`` the query path uses),
  2. scatter-appended into that index's delta bucket (capacity-rejected
     points are reported back so the caller can trigger maintenance and
     retry — nothing is ever silently dropped),
  3. folded into per-index running sums (count / coordinate sum / max
     distance to the buffer pivot) so the maintenance monitor can recompute
     index centroids and conservative radius bounds WITHOUT touching the
     raw points again.

Search sees the buffers through ``core.knn.DeltaView`` (``delta_view``): one
extra bucket per index, scanned by the same fused Pallas bucket-scan kernel
as a second bounded phase and merged into the same top-k carry — forest +
delta search stays exact (tests/test_stream.py proves it against brute
force).  The buffer pivot is frozen at allocation (the owning index's
center), so the running ``radius`` is a valid lower-bound reference no
matter how many appends happen.

FITing-Tree's buffered-insert strategy (PAPERS.md) is the template: bounded
insert cost into a delta, bounded search degradation (one extra bucket per
selected index), periodic merge — here the merge trigger is the paper's own
overlap machinery (stream/maintenance.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import ForestArrays
from repro.core.knn import DeltaView, DeviceForest, route_points

Array = jax.Array


class DeltaBuffer(NamedTuple):
    """Per-index streaming append buffers + maintenance bookkeeping.

    The first five fields are the search-facing state (see ``delta_view``);
    the rest feed the overlap-drift monitor: ``sum_x``/``count`` give the
    delta centroid contribution, ``main_sum``/``main_count`` the frozen
    forest's contribution, so the *updated* index centroid is
    ``(main_sum + sum_x) / (main_count + count)`` with zero device scans.
    ``dropped`` counts capacity-rejected appends per index — any nonzero
    entry is a standing maintenance trigger.
    """

    x: Array  # (I, CAPD, D) f32 member coords, zero pad
    ids: Array  # (I, CAPD) i32 global object ids, -1 pad
    count: Array  # (I,) i32 live members per buffer
    pivot: Array  # (I, D) f32 frozen bound reference (index center at alloc)
    radius: Array  # (I,) f32 running max d(member, pivot)
    sum_x: Array  # (I, D) f32 running coordinate sum of delta members
    main_count: Array  # (I,) i32 member count of the frozen main forest
    main_sum: Array  # (I, D) f32 coordinate sum of the frozen main members
    main_radius: Array  # (I,) f32 frozen index radius (about ``pivot``)
    dropped: Array  # (I,) i32 capacity-rejected appends since alloc

    @property
    def capacity(self) -> int:
        return int(self.x.shape[1])


def main_index_sums(forest: ForestArrays) -> tuple[np.ndarray, np.ndarray]:
    """Per-index (member count, coordinate sum) of the frozen forest."""
    n_idx = forest.n_indexes
    dim = forest.bucket_x.shape[2]
    counts = np.zeros((n_idx,), np.int32)
    sums = np.zeros((n_idx, dim), np.float64)
    bcount = forest.bucket_mask.sum(axis=1)
    bsum = (forest.bucket_x * forest.bucket_mask[..., None]).sum(axis=1)
    np.add.at(counts, forest.bucket_index, bcount.astype(np.int32))
    np.add.at(sums, forest.bucket_index, bsum)
    return counts, sums.astype(np.float32)


def alloc_delta(forest: ForestArrays, capacity: int) -> DeltaBuffer:
    """Allocate empty delta buffers for every index of ``forest``."""
    n_idx = forest.n_indexes
    dim = forest.bucket_x.shape[2]
    main_count, main_sum = main_index_sums(forest)
    return DeltaBuffer(
        x=jnp.zeros((n_idx, capacity, dim), jnp.float32),
        ids=jnp.full((n_idx, capacity), -1, jnp.int32),
        count=jnp.zeros((n_idx,), jnp.int32),
        pivot=jnp.asarray(forest.index_centers, jnp.float32),
        radius=jnp.zeros((n_idx,), jnp.float32),
        sum_x=jnp.zeros((n_idx, dim), jnp.float32),
        main_count=jnp.asarray(main_count),
        main_sum=jnp.asarray(main_sum),
        main_radius=jnp.asarray(forest.index_radii, jnp.float32),
        dropped=jnp.zeros((n_idx,), jnp.int32),
    )


def delta_view(delta: DeltaBuffer) -> DeltaView:
    """Search-facing view (core.knn.DeltaView) of the append buffers."""
    mask = jnp.arange(delta.x.shape[1])[None, :] < delta.count[:, None]
    return DeltaView(
        x=delta.x, ids=delta.ids, mask=mask, pivot=delta.pivot, radius=delta.radius
    )


def append_routed(
    delta: DeltaBuffer, xb: Array, ids: Array, idx: Array, valid: Array
) -> tuple[DeltaBuffer, Array]:
    """Append one ALREADY-ROUTED batch; returns (new delta, accepted).

    ``idx`` (B,) i32 is the destination buffer row; any value >= the row
    count parks the point (every scatter drops the row, it consumes no slot,
    counts nowhere — not even ``dropped`` — and reports accepted=False when
    ``valid`` is also False).  ``valid`` (B,) bool marks the rows that are
    really in the batch this round.

    This is the executor body both device layouts share: the single-device
    path calls it with GLOBAL buffer rows, the sharded island per shard with
    LOCAL rows (other shards' points arrive parked).  Slot assignment sorts
    by destination and ranks within runs (O(B log B), no (B, B) mask) — a
    stable sort preserves batch order within each destination run, so the
    per-index slot layout is bitwise-identical across layouts.  Pure and
    un-jitted; callers own the compilation boundary.
    """
    b = xb.shape[0]
    n_idx = delta.count.shape[0]
    capd = delta.x.shape[1]

    # 1. slot assignment: rank within same-destination runs of the batch
    order = jnp.argsort(idx, stable=True)
    s = idx[order]  # (B,) sorted destinations
    pos = jnp.arange(b, dtype=jnp.int32)
    run_start = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    start_pos = jax.lax.cummax(jnp.where(run_start, pos, 0))
    rank = pos - start_pos  # position within the run
    slot = delta.count[s] + rank  # (B,) target slot in sorted order
    acc_sorted = slot < capd

    # 2. scatter-append (out-of-capacity slots drop out of the scatter)
    xs = xb[order]
    new_x = delta.x.at[s, slot].set(xs, mode="drop")
    new_ids = delta.ids.at[s, slot].set(ids[order], mode="drop")

    # unsort the accept mask back to batch order
    accepted = jnp.zeros((b,), bool).at[order].set(acc_sorted)
    accepted = accepted & valid  # parked rows: slot math is meaningless

    # 3. running bookkeeping (accepted points only; parked rows scatter to
    #    the out-of-range virtual index and drop)
    d_piv = jnp.sqrt(
        jnp.maximum(
            jnp.sum((xb - delta.pivot[jnp.minimum(idx, n_idx - 1)]) ** 2, axis=-1),
            0.0,
        )
    )  # (B,) distance to the frozen buffer pivot
    one = accepted.astype(jnp.int32)
    new_count = delta.count.at[idx].add(one, mode="drop")
    new_radius = delta.radius.at[idx].max(
        jnp.where(accepted, d_piv, -jnp.inf), mode="drop"
    )
    new_sum = delta.sum_x.at[idx].add(
        jnp.where(accepted[:, None], xb, 0.0), mode="drop"
    )
    new_dropped = delta.dropped.at[idx].add(1 - one, mode="drop")

    return (
        delta._replace(
            x=new_x, ids=new_ids, count=new_count, radius=new_radius,
            sum_x=new_sum, dropped=new_dropped,
        ),
        accepted,
    )


def ingest_impl(
    centers: Array,
    delta: DeltaBuffer,
    xb: Array,
    ids: Array,
    valid: Array | None = None,
) -> tuple[DeltaBuffer, Array]:
    """Route + append one batch (un-jitted executor body; see ``ingest``).

    Takes the routing CENTERS, not the whole ``DeviceForest``: ingest never
    reads the bucket arrays, and a maintenance rebuild changes their shapes
    — keying the jit cache on the full forest forced a full re-trace after
    every rebuild (the BENCH_stream ~360 points/s regression).  Centers keep
    a stable (I, D) shape for the life of the index.
    """
    n_idx = delta.count.shape[0]
    xb = xb.astype(jnp.float32)
    ids = ids.astype(jnp.int32)

    # route (STEP-1; same arithmetic as the query path)
    _, idx = route_points(centers, xb, kernel=True)  # (B,)
    if valid is None:
        valid = jnp.ones((xb.shape[0],), bool)
    else:
        idx = jnp.where(valid, idx, n_idx)  # park: every scatter drops row I
    return append_routed(delta, xb, ids, idx, valid)


_ingest_jit = jax.jit(ingest_impl)


def ingest(
    forest: DeviceForest,
    delta: DeltaBuffer,
    xb: Array,
    ids: Array,
    valid: Array | None = None,
) -> tuple[DeltaBuffer, Array]:
    """Route + append one batch; returns (new delta, accepted (B,) bool).

    Jitted end to end (cache keyed on the routing centers + operand shapes,
    NOT the forest's bucket arrays): routing reuses STEP-1
    (``route_points``), slot assignment sorts the batch by destination index
    and ranks within runs (O(B log B), no (B, B) mask), appends are a single
    scatter with ``mode='drop'`` — a slot past capacity falls outside the
    array and the point is reported rejected instead of written.

    ``accepted[j]`` is False only when point j's destination buffer is full;
    the caller requeues those points after running maintenance (see
    api.OverlapIndex.ingest, which never loses a point).

    ``valid`` (optional (B,) bool) masks rows out of the batch entirely:
    invalid rows are parked on a virtual out-of-range index so they consume
    no slots, store nothing, count nowhere (not even ``dropped``), and
    report accepted=False.  Retry loops keep the SAME batch shape across
    rounds by flipping the mask instead of slicing — one compiled program
    instead of one per rejected-point count.
    """
    return _ingest_jit(forest.index_centers, delta, xb, ids, valid)


def updated_geometry(delta: DeltaBuffer) -> tuple[Array, Array]:
    """Post-ingest index geometry from the running sums — no member scans.

    Returns (centers (I, D), radius upper bounds (I,)).  The center is the
    exact centroid of main + delta members.  The radius is a conservative
    upper bound: every member lies within ``max(r_main, r_delta)`` of the
    OLD center (main members by construction, delta members by the running
    max), so it lies within that plus the center shift of the NEW center.
    Conservative is the right direction for the drift monitor — overlap
    rates computed from upper-bound radii can only over-trigger, never miss
    a genuinely overlapping pair.
    """
    total = jnp.maximum(delta.main_count + delta.count, 1)
    centers = (delta.main_sum + delta.sum_x) / total[:, None].astype(jnp.float32)
    shift = jnp.sqrt(
        jnp.maximum(jnp.sum((centers - delta.pivot) ** 2, axis=-1), 0.0)
    )
    return centers, jnp.maximum(delta.main_radius, delta.radius) + shift


def ingest_host(
    forest: DeviceForest, delta: DeltaBuffer, xb: np.ndarray, ids: np.ndarray
) -> tuple[DeltaBuffer, np.ndarray]:
    """Host convenience wrapper around ``ingest``."""
    nd, acc = ingest(forest, delta, jnp.asarray(xb, jnp.float32), jnp.asarray(ids))
    return nd, np.asarray(acc)


def pull_delta_meta(delta: DeltaBuffer, *, ids: bool = False) -> dict[str, np.ndarray]:
    """Device -> host snapshot of the buffer METADATA (maintenance reads
    this).  Deliberately excludes the (I, CAPD, D) coordinate block — no
    consumer needs it on the host (rebuilds fetch rows from the caller's
    accumulated dataset by global id), and the drift monitor runs per batch,
    so copying megabytes of coordinates every check would dominate its cost.
    ``ids=True`` adds the (I, CAPD) id table (OBM assignment + rebuilds)."""
    out = {
        "count": np.asarray(delta.count),
        "radius": np.asarray(delta.radius),
        "sum_x": np.asarray(delta.sum_x),
        "main_count": np.asarray(delta.main_count),
        "main_sum": np.asarray(delta.main_sum),
        "main_radius": np.asarray(delta.main_radius),
        "dropped": np.asarray(delta.dropped),
    }
    if ids:
        out["ids"] = np.asarray(delta.ids)
    return out


def route_batch_host(forest: DeviceForest, xb: np.ndarray) -> np.ndarray:
    """Host helper: destination index per point (routing only, no append)."""
    _, idx = route_points(forest.index_centers, jnp.asarray(xb, jnp.float32))
    return np.asarray(idx)
