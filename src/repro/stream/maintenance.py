"""Overlap-driven online index maintenance — closing the loop the paper
leaves static.

The paper computes VBM/DBM/OBM overlap rates ONCE, at build time, to decide
the partition layout (§4.2-4.3).  Under streaming ingest the geometry
drifts: delta appends shift centroids and inflate radii, so the overlap
structure the layout was optimized for stops being true.  This module
re-evaluates the paper's own heuristics (core/overlap.py) on the *updated*
geometry — exact post-ingest centroids and conservative radius upper bounds
maintained incrementally by stream/ingest.py — and, past configurable ξ
thresholds, schedules host-side per-index rebuilds (core/bccf.build_tree)
that absorb the delta into a fresh tree and are swapped in atomically.

Trigger taxonomy (``DriftReport.reasons``):

  overlap   max_j rate[i, j] >= xi_rebuild — the updated geometry crossed
            the same kind of threshold the build-time decision stage uses;
            the index's layout is no longer what the heuristic would choose.
  drift     rate[i, j] rose by >= drift_margin over the build-time baseline
            (relative trigger; off unless drift_margin is set).
  fill      delta buffer fill fraction >= fill_rebuild — search degradation
            bound (one over-full tail bucket per selected index).
  overflow  capacity-rejected appends recorded — standing trigger, the
            rejected points are waiting to be re-ingested.

Rebuilds never drop queries: the new forest is built OFF to the side on the
host while the old (device forest, delta) pair keeps serving; the swap
installs the new device arrays, a fresh delta, and re-ingests the surviving
delta members of untouched indexes in one step (tests assert search is
exact across the swap boundary).  DIMS's serve-under-redistribution design
(PAPERS.md) is the pattern; FITing-Tree's buffered inserts bound the cost.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from time import perf_counter
from typing import Any

import numpy as np

from repro.core.bccf import build_tree
from repro.core.forest import ForestArrays, swap_trees
from repro.core.knn import DeviceForest, device_forest, knn_search
from repro.core.overlap import max_neighbor_rate, overlap_matrix
from repro.core.pipeline import IndexConfig, build_index, default_delta_capacity
from repro.stream.ingest import (
    DeltaBuffer,
    alloc_delta,
    delta_view,
    ingest,
    pull_delta_meta,
    updated_geometry,
)

import jax.numpy as jnp


@dataclass(frozen=True)
class MaintenanceConfig:
    """ξ thresholds and rebuild knobs for the drift monitor."""

    method: str = "dbm"  # vbm | dbm | obm — heuristic re-evaluated online
    xi_rebuild: float = 0.8  # absolute overlap rate forcing repartition
    drift_margin: float | None = None  # optional rise-over-baseline trigger
    fill_rebuild: float = 0.75  # delta fill fraction forcing a merge-rebuild
    pivot_method: str = "gh"
    c_max: int | None = None  # default: keep the forest's bucket capacity
    seed: int = 1


@dataclass
class DriftReport:
    """One monitor evaluation: updated rates vs baseline + fired triggers."""

    rates_baseline: np.ndarray  # (I, I) build-time overlap rates
    rates: np.ndarray  # (I, I) rates on the updated geometry
    centers: np.ndarray  # (I, D) post-ingest centroids
    radii: np.ndarray  # (I,) conservative radius upper bounds
    fill: np.ndarray  # (I,) delta fill fraction
    dropped: np.ndarray  # (I,) capacity-rejected appends
    triggers: list[int] = field(default_factory=list)
    reasons: dict[int, list[str]] = field(default_factory=dict)

    @property
    def should_rebuild(self) -> bool:
        return bool(self.triggers)


def object_assignment(
    forest: ForestArrays, delta_host: dict[str, np.ndarray] | None, n_total: int
) -> np.ndarray:
    """(N,) object id -> owning index, across main buckets and delta tails
    (the OBM monitor needs a full assignment, Def. 11's denominator)."""
    assign = np.full(n_total, -1, np.int64)
    m = forest.bucket_mask
    assign[forest.bucket_ids[m]] = np.repeat(forest.bucket_index, m.sum(axis=1))
    if delta_host is not None:
        for i in range(forest.n_indexes):
            c = int(delta_host["count"][i])
            if c:
                assign[delta_host["ids"][i, :c]] = i
    return assign


def _rates(
    method: str,
    centers: np.ndarray,
    radii: np.ndarray,
    x: np.ndarray | None,
    assign: np.ndarray | None,
) -> np.ndarray:
    if method == "obm" and (x is None or assign is None):
        raise ValueError("OBM drift monitoring needs the dataset + assignment")
    return np.asarray(
        overlap_matrix(
            method,
            jnp.asarray(centers, jnp.float32),
            jnp.asarray(radii, jnp.float32),
            x=None if x is None else jnp.asarray(x, jnp.float32),
            assign=None if assign is None else jnp.asarray(assign),
        )
    )


class OverlapMonitor:
    """Re-evaluates the paper's overlap heuristic as the geometry drifts.

    The baseline matrix is captured from the forest's build-time geometry;
    ``check`` recomputes the same heuristic on the post-ingest geometry
    (stream/ingest.updated_geometry) and classifies every index against the
    ξ thresholds.  Cheap by construction: O(I^2) rate math on incrementally
    maintained sums — no scan of the raw data (except OBM, which is defined
    over objects and receives them explicitly).
    """

    def __init__(
        self,
        forest: ForestArrays,
        cfg: MaintenanceConfig,
        *,
        x: np.ndarray | None = None,
    ):
        self.cfg = cfg
        self.forest = forest
        assign = None
        if cfg.method == "obm":
            if x is None:
                raise ValueError("OBM monitor needs the dataset at construction")
            assign = object_assignment(forest, None, len(x))
        self.rates_baseline = _rates(
            cfg.method, forest.index_centers, forest.index_radii, x, assign
        )

    def check(
        self, delta: DeltaBuffer, *, x: np.ndarray | None = None
    ) -> DriftReport:
        cfg = self.cfg
        centers_d, radii_d = updated_geometry(delta)
        centers = np.asarray(centers_d)
        radii = np.asarray(radii_d)
        host = pull_delta_meta(delta, ids=cfg.method == "obm")
        assign = None
        if cfg.method == "obm":
            if x is None:
                raise ValueError("OBM drift check needs the dataset")
            assign = object_assignment(self.forest, host, len(x))
        rates = _rates(cfg.method, centers, radii, x, assign)

        capd = delta.capacity
        fill = host["count"].astype(np.float64) / max(capd, 1)
        report = DriftReport(
            rates_baseline=self.rates_baseline,
            rates=rates,
            centers=centers,
            radii=radii,
            fill=fill,
            dropped=host["dropped"],
        )
        worst = np.asarray(max_neighbor_rate(jnp.asarray(rates)))
        worst_base = np.asarray(max_neighbor_rate(jnp.asarray(self.rates_baseline)))
        for i in range(len(radii)):
            why = []
            # Fire only on overlap the CURRENT layout doesn't account for:
            # if the post-rebuild baseline itself sits at/above the rate, a
            # per-index rebuild cannot reduce it (that pair needs a merge —
            # the decision stage's job, not maintenance's) and re-firing
            # would churn rebuilds forever.
            if worst[i] >= cfg.xi_rebuild and worst[i] > worst_base[i] + 1e-6:
                why.append("overlap")
            if cfg.drift_margin is not None and (
                worst[i] - worst_base[i] >= cfg.drift_margin
            ):
                why.append("drift")
            if fill[i] >= cfg.fill_rebuild:
                why.append("fill")
            if host["dropped"][i] > 0:
                why.append("overflow")
            if why:
                report.triggers.append(i)
                report.reasons[i] = why
        return report


def rebuild_indexes(
    forest: ForestArrays,
    delta: DeltaBuffer,
    x_all: np.ndarray,
    triggers: list[int],
    cfg: MaintenanceConfig,
) -> tuple[ForestArrays, dict[str, Any]]:
    """Rebuild the triggered indexes' BCCF trees with their delta absorbed.

    Host-side (the build path of any production vector store): per index,
    gather main members from the (fresh — see swap_trees) host tree copies
    plus the delta members, run ``core.bccf.build_tree``, recompute exact
    centroid/radius, and swap everything in via ``forest.swap_trees``.
    Returns (new ForestArrays, rebuild stats).
    """
    host = pull_delta_meta(delta, ids=True)
    replacements = {}
    centers = forest.index_centers.copy()
    radii = forest.index_radii.copy()
    n_absorbed = 0
    t0 = perf_counter()
    for gi in triggers:
        main_ids = np.concatenate(
            [np.asarray(m, np.int64) for m in forest.trees[gi].bucket_members]
        )
        c = int(host["count"][gi])
        d_ids = host["ids"][gi, :c].astype(np.int64)
        members = np.concatenate([main_ids, d_ids])
        n_absorbed += c
        pts = x_all[members]
        replacements[gi] = build_tree(
            pts,
            members,
            c_max=cfg.c_max or forest.c_max,
            pivot_method=cfg.pivot_method,
            seed=cfg.seed + gi,
        )
        center = pts.mean(axis=0).astype(np.float32)
        centers[gi] = center
        radii[gi] = float(np.sqrt(((pts - center) ** 2).sum(-1)).max())
    new_forest = swap_trees(
        forest, x_all, replacements, index_centers=centers, index_radii=radii
    )
    stats = dict(
        n_rebuilt=len(triggers),
        n_absorbed=n_absorbed,
        rebuild_distances=sum(t.counters.distances for t in replacements.values()),
        wall_time_s=perf_counter() - t0,
    )
    return new_forest, stats


class StreamingForest:
    """Ingest → monitor → rebuild lifecycle owner (single-writer).

    Wraps (host ForestArrays, device DeviceForest, DeltaBuffer, monitor)
    behind three calls:

      ids = sf.ingest(xb)        # batched insert; NEVER loses a point
      d, i, s = sf.search(q, k)  # forest + delta, exact within selection
      report = sf.maintain()     # drift check; rebuild + hot swap if fired

    Atomic swap discipline: queries issued before a swap use the old
    (device, delta) pair; queries after use the new pair — there is no
    intermediate state in which either structure is partially updated, so
    there is no search-correctness gap (tests/test_stream.py asserts
    exactness immediately before and after a swap).
    """

    def __init__(
        self,
        x0: np.ndarray,
        index_cfg: IndexConfig | None = None,
        maint_cfg: MaintenanceConfig | None = None,
        *,
        delta_capacity: int | None = None,
    ):
        x0 = np.asarray(x0, np.float32)
        self.index_cfg = index_cfg or IndexConfig()
        self.maint_cfg = maint_cfg or MaintenanceConfig()
        self.forest, self.build_report = build_index(x0, self.index_cfg)
        self.device: DeviceForest = device_forest(self.forest)
        self.capacity = delta_capacity or default_delta_capacity(len(x0))
        self.delta: DeltaBuffer = alloc_delta(self.forest, self.capacity)
        self._x_parts: list[np.ndarray] = [x0]
        self._x_cache: np.ndarray | None = x0
        self.n_total = len(x0)
        self.monitor = OverlapMonitor(
            self.forest, self.maint_cfg,
            x=x0 if self.maint_cfg.method == "obm" else None,
        )
        self.rebuild_log: list[dict[str, Any]] = []

    # --- dataset bookkeeping ------------------------------------------------
    @property
    def x_all(self) -> np.ndarray:
        if self._x_cache is None or len(self._x_cache) != self.n_total:
            self._x_cache = np.concatenate(self._x_parts)
            self._x_parts = [self._x_cache]
        return self._x_cache

    # --- write path ---------------------------------------------------------
    def ingest(self, xb: np.ndarray) -> np.ndarray:
        """Insert a batch; returns the assigned global object ids.

        Chunks the batch to the per-index buffer capacity so a forced
        maintenance pass (emptying the destination buffers) always makes the
        retry succeed — ingestion cannot silently drop or livelock.
        """
        xb = np.asarray(xb, np.float32)
        ids = np.arange(self.n_total, self.n_total + len(xb), dtype=np.int64)
        self._x_parts.append(xb)
        self.n_total += len(xb)
        self._x_cache = None
        for lo in range(0, len(xb), self.capacity):
            self._ingest_chunk(xb[lo : lo + self.capacity], ids[lo : lo + self.capacity])
        return ids

    def _ingest_chunk(self, xc: np.ndarray, ic: np.ndarray) -> None:
        # Termination argument: a round that rejects any point force-rebuilds
        # every rejecting index, emptying its buffer into the main structure.
        # A retried point (chunk size <= buffer capacity) can only be
        # rejected again by re-routing to a DIFFERENT still-full buffer, and
        # each round empties at least one of those — so at most n_indexes
        # rounds before every point is accepted.  Retries flip the ``valid``
        # mask instead of slicing the batch, so every round reuses one
        # compiled ingest program (shapes never depend on the reject count).
        xj, ij = jnp.asarray(xc), jnp.asarray(ic)
        pending = np.ones(len(xc), bool)
        for _ in range(self.forest.n_indexes + 1):
            self.delta, acc = ingest(
                self.device, self.delta, xj, ij, valid=jnp.asarray(pending)
            )
            pending &= ~np.asarray(acc)
            if not pending.any():
                return
            # capacity hit: force-rebuild the rejecting indexes, retry rest
            meta = pull_delta_meta(self.delta)
            full = [i for i in range(self.forest.n_indexes) if meta["dropped"][i] > 0]
            self._rebuild(full)
        raise RuntimeError(
            "ingest chunk still rejected after rebuilding every full index — "
            "invariant violation, please report"
        )

    # --- read path ----------------------------------------------------------
    def search(self, q, *, k: int, mode: str = "forest", beam: int = 1,
               kernel: bool = True):
        """kNN over main forest + delta (core.knn.knn_search two-phase)."""
        return knn_search(
            self.device, jnp.asarray(q, jnp.float32), k=k, mode=mode, beam=beam,
            kernel=kernel, delta=delta_view(self.delta),
        )

    # --- maintenance --------------------------------------------------------
    def check(self) -> DriftReport:
        """Drift evaluation only (no rebuild)."""
        x = self.x_all if self.maint_cfg.method == "obm" else None
        return self.monitor.check(self.delta, x=x)

    def maintain(self) -> DriftReport:
        """Run the monitor; rebuild + hot-swap every triggered index."""
        report = self.check()
        if report.triggers:
            self._rebuild(report.triggers, report)
        return report

    def _rebuild(self, triggers: list[int], report: DriftReport | None = None) -> None:
        if not triggers:
            return
        x_all = self.x_all
        new_forest, stats = rebuild_indexes(
            self.forest, self.delta, x_all, triggers, self.maint_cfg
        )
        # Survivors — delta members of indexes NOT rebuilt — keep their
        # original buffers wholesale: a kept index keeps its center, so the
        # old buffer's pivot/radius bound is still valid verbatim.  A pure
        # device-side select (no host round-trip, no re-routing) that BY
        # CONSTRUCTION cannot overflow: each kept buffer moves into a fresh
        # buffer of the same capacity.  Rebuilt indexes start empty (their
        # members were absorbed into the new trees); ``dropped`` resets —
        # rejected points were never stored and their owners retry them.
        new_device = device_forest(new_forest)
        fresh = alloc_delta(new_forest, self.capacity)
        keep = np.ones(self.forest.n_indexes, bool)
        keep[list(triggers)] = False
        n_migrated = int(np.asarray(self.delta.count)[keep].sum())
        kj = jnp.asarray(keep)
        old = self.delta
        new_delta = fresh._replace(
            x=jnp.where(kj[:, None, None], old.x, fresh.x),
            ids=jnp.where(kj[:, None], old.ids, fresh.ids),
            count=jnp.where(kj, old.count, fresh.count),
            pivot=jnp.where(kj[:, None], old.pivot, fresh.pivot),
            radius=jnp.where(kj, old.radius, fresh.radius),
            sum_x=jnp.where(kj[:, None], old.sum_x, fresh.sum_x),
        )

        # ---- atomic swap: a query sees the old pair or the new pair --------
        self.forest, self.device, self.delta = new_forest, new_device, new_delta
        self.monitor = OverlapMonitor(
            new_forest, self.maint_cfg,
            x=x_all if self.maint_cfg.method == "obm" else None,
        )
        stats["triggers"] = list(triggers)
        stats["reasons"] = dict(report.reasons) if report is not None else {}
        stats["n_migrated"] = n_migrated
        self.rebuild_log.append(stats)

    # --- introspection ------------------------------------------------------
    def structure(self) -> dict[str, Any]:
        """aggregate_structure + live delta occupancy (always fresh)."""
        s = self.forest.aggregate_structure()
        s["delta_fill"] = np.asarray(self.delta.count).tolist()
        s["delta_capacity"] = self.capacity
        s["n_objects"] = self.n_total
        s["rebuilds"] = self.forest.build_stats.get("rebuilds", 0)
        return s
