"""Overlap-driven online index maintenance — closing the loop the paper
leaves static.

The paper computes VBM/DBM/OBM overlap rates ONCE, at build time, to decide
the partition layout (§4.2-4.3).  Under streaming ingest the geometry
drifts: delta appends shift centroids and inflate radii, so the overlap
structure the layout was optimized for stops being true.  This module
re-evaluates the paper's own heuristics (core/overlap.py) on the *updated*
geometry — exact post-ingest centroids and conservative radius upper bounds
maintained incrementally by stream/ingest.py — and, past configurable ξ
thresholds, schedules host-side per-index rebuilds (core/bccf.build_tree)
that absorb the delta into a fresh tree and are swapped in atomically.

Trigger taxonomy (``DriftReport.reasons``):

  overlap   max_j rate[i, j] >= xi_rebuild — the updated geometry crossed
            the same kind of threshold the build-time decision stage uses;
            the index's layout is no longer what the heuristic would choose.
  drift     rate[i, j] rose by >= drift_margin over the build-time baseline
            (relative trigger; off unless drift_margin is set).
  fill      delta buffer fill fraction >= fill_rebuild — search degradation
            bound (one over-full tail bucket per selected index).
  overflow  capacity-rejected appends recorded — standing trigger, the
            rejected points are waiting to be re-ingested.
  wasted    MEASURED waste: ``OverlapIndex.explain`` attribution reported
            >= wasted_rebuild of the visits into this index's buckets as
            wasted (no member survived into any final top-k).  Unlike the
            geometry triggers above this one is evidence from executed
            queries, fed in via ``note_wasted``; off unless wasted_rebuild
            is set AND explain() runs.

Rebuilds never drop queries: the new forest is built OFF to the side on the
host while the old (device forest, delta) pair keeps serving; the swap
installs the new device arrays, a fresh delta, and re-ingests the surviving
delta members of untouched indexes in one step (tests assert search is
exact across the swap boundary).  DIMS's serve-under-redistribution design
(PAPERS.md) is the pattern; FITing-Tree's buffered inserts bound the cost.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from time import perf_counter
from typing import Any

import numpy as np

from repro.core.bccf import build_tree
from repro.core.forest import ForestArrays, swap_trees
from repro.core.overlap import (
    get_overlap_method,
    max_neighbor_rate,
    overlap_matrix,
)
from repro.core.pipeline import IndexConfig
from repro.deprecation import warn_deprecated
from repro.stream.ingest import DeltaBuffer, pull_delta_meta, updated_geometry

import jax.numpy as jnp


@dataclass(frozen=True)
class MaintenanceConfig:
    """ξ thresholds and rebuild knobs for the drift monitor.

    (The facade expresses the same knobs as ``repro.api.StreamConfig``;
    this struct remains the engine-room parameter set.)
    """

    method: str = "dbm"  # any registered overlap method, re-evaluated online
    xi_rebuild: float = 0.8  # absolute overlap rate forcing repartition
    drift_margin: float | None = None  # optional rise-over-baseline trigger
    fill_rebuild: float = 0.75  # delta fill fraction forcing a merge-rebuild
    wasted_rebuild: float | None = None  # measured wasted-visit share trigger
    pivot_method: str = "gh"
    c_max: int | None = None  # default: keep the forest's bucket capacity
    seed: int = 1


@dataclass
class DriftReport:
    """One monitor evaluation: updated rates vs baseline + fired triggers."""

    rates_baseline: np.ndarray  # (I, I) build-time overlap rates
    rates: np.ndarray  # (I, I) rates on the updated geometry
    centers: np.ndarray  # (I, D) post-ingest centroids
    radii: np.ndarray  # (I,) conservative radius upper bounds
    fill: np.ndarray  # (I,) delta fill fraction
    dropped: np.ndarray  # (I,) capacity-rejected appends
    triggers: list[int] = field(default_factory=list)
    reasons: dict[int, list[str]] = field(default_factory=dict)

    @property
    def should_rebuild(self) -> bool:
        return bool(self.triggers)


def object_assignment(
    forest: ForestArrays, delta_host: dict[str, np.ndarray] | None, n_total: int
) -> np.ndarray:
    """(N,) object id -> owning index, across main buckets and delta tails
    (the OBM monitor needs a full assignment, Def. 11's denominator)."""
    assign = np.full(n_total, -1, np.int64)
    m = forest.bucket_mask
    assign[forest.bucket_ids[m]] = np.repeat(forest.bucket_index, m.sum(axis=1))
    if delta_host is not None:
        for i in range(forest.n_indexes):
            c = int(delta_host["count"][i])
            if c:
                assign[delta_host["ids"][i, :c]] = i
    return assign


def _rates(
    method: str,
    centers: np.ndarray,
    radii: np.ndarray,
    x: np.ndarray | None,
    assign: np.ndarray | None,
) -> np.ndarray:
    if get_overlap_method(method).needs_objects and (x is None or assign is None):
        raise ValueError(
            f"object-based drift monitoring ({method!r}) needs the dataset "
            "+ assignment"
        )
    return np.asarray(
        overlap_matrix(
            method,
            jnp.asarray(centers, jnp.float32),
            jnp.asarray(radii, jnp.float32),
            x=None if x is None else jnp.asarray(x, jnp.float32),
            assign=None if assign is None else jnp.asarray(assign),
        )
    )


class OverlapMonitor:
    """Re-evaluates the paper's overlap heuristic as the geometry drifts.

    The baseline matrix is captured from the forest's build-time geometry;
    ``check`` recomputes the same heuristic on the post-ingest geometry
    (stream/ingest.updated_geometry) and classifies every index against the
    ξ thresholds.  Cheap by construction: O(I^2) rate math on incrementally
    maintained sums — no scan of the raw data (except OBM, which is defined
    over objects and receives them explicitly).
    """

    def __init__(
        self,
        forest: ForestArrays,
        cfg: MaintenanceConfig,
        *,
        x: np.ndarray | None = None,
    ):
        self.cfg = cfg
        self.forest = forest
        needs_objects = get_overlap_method(cfg.method).needs_objects
        assign = None
        if needs_objects:
            if x is None:
                raise ValueError(
                    f"object-based monitor ({cfg.method!r}) needs the dataset "
                    "at construction"
                )
            assign = object_assignment(forest, None, len(x))
        self.rates_baseline = _rates(
            cfg.method, forest.index_centers, forest.index_radii, x, assign
        )
        n_idx = forest.n_indexes
        # measured-waste accumulators (explain() attribution evidence);
        # recreated-with-the-monitor after a rebuild, so they reset exactly
        # when the geometry they judged stops existing
        self.wasted_visits = np.zeros(n_idx, np.int64)  # wasted, by visited
        self.attr_visits = np.zeros(n_idx, np.int64)  # all, by visited

    # minimum attributed visits into an index before the measured-waste
    # trigger may fire — a handful of explain()ed queries must not force a
    # rebuild off noise
    WASTED_MIN_VISITS = 16

    def note_wasted(
        self, wasted_pair: np.ndarray, visited_pair: np.ndarray
    ) -> None:
        """Fold one ``ExplainReport``'s (visited, home) pair matrices into
        the lifetime accumulators (rows: visited index)."""
        self.wasted_visits += np.asarray(wasted_pair, np.int64).sum(axis=1)
        self.attr_visits += np.asarray(visited_pair, np.int64).sum(axis=1)

    def wasted_share(self) -> np.ndarray:
        """(I,) fraction of attributed visits into each index that were
        wasted (0 where nothing was attributed yet)."""
        return self.wasted_visits / np.maximum(self.attr_visits, 1)

    def check(
        self, delta: DeltaBuffer, *, x: np.ndarray | None = None
    ) -> DriftReport:
        cfg = self.cfg
        needs_objects = get_overlap_method(cfg.method).needs_objects
        centers_d, radii_d = updated_geometry(delta)
        centers = np.asarray(centers_d)
        radii = np.asarray(radii_d)
        host = pull_delta_meta(delta, ids=needs_objects)
        assign = None
        if needs_objects:
            if x is None:
                raise ValueError(
                    f"object-based drift check ({cfg.method!r}) needs the dataset"
                )
            assign = object_assignment(self.forest, host, len(x))
        rates = _rates(cfg.method, centers, radii, x, assign)

        capd = delta.capacity
        fill = host["count"].astype(np.float64) / max(capd, 1)
        report = DriftReport(
            rates_baseline=self.rates_baseline,
            rates=rates,
            centers=centers,
            radii=radii,
            fill=fill,
            dropped=host["dropped"],
        )
        worst = np.asarray(max_neighbor_rate(jnp.asarray(rates)))
        worst_base = np.asarray(max_neighbor_rate(jnp.asarray(self.rates_baseline)))
        for i in range(len(radii)):
            why = []
            # Fire only on overlap the CURRENT layout doesn't account for:
            # if the post-rebuild baseline itself sits at/above the rate, a
            # per-index rebuild cannot reduce it (that pair needs a merge —
            # the decision stage's job, not maintenance's) and re-firing
            # would churn rebuilds forever.
            if worst[i] >= cfg.xi_rebuild and worst[i] > worst_base[i] + 1e-6:
                why.append("overlap")
            if cfg.drift_margin is not None and (
                worst[i] - worst_base[i] >= cfg.drift_margin
            ):
                why.append("drift")
            if fill[i] >= cfg.fill_rebuild:
                why.append("fill")
            if host["dropped"][i] > 0:
                why.append("overflow")
            if (
                cfg.wasted_rebuild is not None
                and self.attr_visits[i] >= self.WASTED_MIN_VISITS
                and self.wasted_share()[i] >= cfg.wasted_rebuild
            ):
                why.append("wasted")
            if why:
                report.triggers.append(i)
                report.reasons[i] = why
        return report


def rebuild_indexes(
    forest: ForestArrays,
    delta: DeltaBuffer,
    x_all: np.ndarray,
    triggers: list[int],
    cfg: MaintenanceConfig,
) -> tuple[ForestArrays, dict[str, Any]]:
    """Rebuild the triggered indexes' BCCF trees with their delta absorbed.

    Host-side (the build path of any production vector store): per index,
    gather main members from the (fresh — see swap_trees) host tree copies
    plus the delta members, run ``core.bccf.build_tree``, recompute exact
    centroid/radius, and swap everything in via ``forest.swap_trees``.
    Returns (new ForestArrays, rebuild stats).
    """
    host = pull_delta_meta(delta, ids=True)
    replacements = {}
    centers = forest.index_centers.copy()
    radii = forest.index_radii.copy()
    n_absorbed = 0
    t0 = perf_counter()
    for gi in triggers:
        main_ids = np.concatenate(
            [np.asarray(m, np.int64) for m in forest.trees[gi].bucket_members]
        )
        c = int(host["count"][gi])
        d_ids = host["ids"][gi, :c].astype(np.int64)
        members = np.concatenate([main_ids, d_ids])
        n_absorbed += c
        pts = x_all[members]
        replacements[gi] = build_tree(
            pts,
            members,
            c_max=cfg.c_max or forest.c_max,
            pivot_method=cfg.pivot_method,
            seed=cfg.seed + gi,
        )
        center = pts.mean(axis=0).astype(np.float32)
        centers[gi] = center
        radii[gi] = float(np.sqrt(((pts - center) ** 2).sum(-1)).max())
    new_forest = swap_trees(
        forest, x_all, replacements, index_centers=centers, index_radii=radii
    )
    stats = dict(
        n_rebuilt=len(triggers),
        n_absorbed=n_absorbed,
        rebuild_distances=sum(t.counters.distances for t in replacements.values()),
        wall_time_s=perf_counter() - t0,
    )
    return new_forest, stats


class StreamingForest:
    """Deprecated shim — use ``repro.api.OverlapIndex``.

    The ingest → monitor → rebuild lifecycle this class used to own lives
    on the facade now (``OverlapIndex.ingest`` / ``.maintain`` /
    ``.search``); this wrapper only translates the legacy
    ``(IndexConfig, MaintenanceConfig, delta_capacity)`` argument triple
    into one ``repro.api.Config`` tree and delegates, preserving the old
    attribute surface (``forest`` / ``device`` / ``delta`` / ``monitor`` /
    ``rebuild_log`` / ...) and the old device-tuple ``search`` return.
    """

    def __init__(
        self,
        x0: np.ndarray,
        index_cfg: IndexConfig | None = None,
        maint_cfg: MaintenanceConfig | None = None,
        *,
        delta_capacity: int | None = None,
    ):
        warn_deprecated("repro.stream.StreamingForest", "repro.api.OverlapIndex")
        from repro.api import Config, OverlapIndex, StreamConfig, as_index_config

        mc = maint_cfg or MaintenanceConfig()
        cfg = Config(
            index=as_index_config(index_cfg or IndexConfig()),
            stream=StreamConfig(
                capacity=delta_capacity,
                monitor_method=mc.method,
                xi_rebuild=mc.xi_rebuild,
                drift_margin=mc.drift_margin,
                fill_rebuild=mc.fill_rebuild,
                wasted_rebuild=mc.wasted_rebuild,
                pivot_method=mc.pivot_method,
                c_max=mc.c_max,
                seed=mc.seed,
            ),
        )
        self._ix = OverlapIndex.build(np.asarray(x0, np.float32), cfg)
        # legacy semantics: buffers + monitor live from construction
        self._ix._ensure_delta()
        self.index_cfg = cfg.index
        self.maint_cfg = mc

    # --- lifecycle delegation ----------------------------------------------
    def ingest(self, xb: np.ndarray) -> np.ndarray:
        return self._ix.ingest(xb)

    def search(self, q, *, k: int, mode: str = "forest", beam: int = 1,
               kernel: bool = True):
        """Device triple (dists, ids, SearchStats) — the legacy return."""
        return self._ix._search_device(q, k=k, mode=mode, beam=beam, kernel=kernel)

    def check(self) -> DriftReport:
        return self._ix.check()

    def maintain(self) -> DriftReport:
        return self._ix.maintain()

    def structure(self) -> dict[str, Any]:
        return self._ix.structure()

    # --- legacy attribute surface -------------------------------------------
    @property
    def forest(self) -> ForestArrays:
        return self._ix.forest

    @property
    def device(self):
        return self._ix.device

    @property
    def delta(self) -> DeltaBuffer:
        return self._ix.delta

    @property
    def monitor(self) -> OverlapMonitor:
        return self._ix.monitor

    @property
    def capacity(self) -> int:
        return self._ix.capacity

    @property
    def build_report(self):
        return self._ix.build_report

    @property
    def rebuild_log(self) -> list[dict[str, Any]]:
        return self._ix.rebuild_log

    @property
    def x_all(self) -> np.ndarray:
        return self._ix.x_all

    @property
    def n_total(self) -> int:
        return self._ix.n_total
