"""Mamba-1 selective SSM mixer (Jamba's sequence-mixing layer).

Training/prefill run a ``lax.scan`` over time that computes the per-step
discretization INSIDE the step — the (B, S, d_inner, d_state) tensor the
naive formulation materializes would be terabytes at Jamba scale; the scan
carries only (B, d_inner, d_state).  d_inner is sharded over 'tensor'
(Megatron-style: in_proj column-parallel, out_proj row-parallel) so the
recurrence is embarrassingly parallel across the mesh; the only collective
is out_proj's psum, inserted by GSPMD.

Decode carries (conv window, ssm state) — O(1) per token in context length,
which is why Jamba runs the long_500k cell.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

Array = jax.Array
PyTree = Any


def _dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return d_in, s.d_state, s.d_conv, dt_rank


def init_mamba(key, cfg: ModelConfig, dtype) -> PyTree:
    d = cfg.d_model
    d_in, ds, dc, dtr = _dims(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    a_init = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (d_in, ds))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in), dtype),
        "conv_w": dense_init(ks[1], (dc, d_in), dtype, scale=dc**-0.5),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(ks[2], (d_in, dtr + 2 * ds), dtype),
        "dt_proj": dense_init(ks[3], (dtr, d_in), dtype, scale=dtr**-0.5),
        "dt_bias": jnp.log(jnp.expm1(jnp.exp(
            jax.random.uniform(ks[4], (d_in,)) * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001)
        ))).astype(jnp.float32),
        "a_log": jnp.log(a_init),
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[5], (d_in, d), dtype),
    }


def _conv_full(p: PyTree, u: Array, dc: int) -> Array:
    """Causal depthwise conv over (B, S, d_in)."""
    dt = u.dtype
    pad = jnp.pad(u, ((0, 0), (dc - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad,
        p["conv_w"].astype(dt)[:, None, :],  # (W, I=1, O=d_in)
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=u.shape[-1],
    )
    return out + p["conv_b"].astype(dt)


def _ssm_step(h, inputs, a):
    """h: (B, d_in, ds); one selective-SSM step (discretize + update)."""
    xt, dtt, bt, ct = inputs  # (B,d_in) (B,d_in) (B,ds) (B,ds)
    da = jnp.exp(dtt[..., None] * a[None])  # (B, d_in, ds)
    dbx = (dtt * xt)[..., None] * bt[:, None, :]  # (B, d_in, ds)
    h = da * h + dbx
    y = jnp.einsum("bds,bs->bd", h, ct)
    return h, y


def mamba_forward(p: PyTree, x: Array, cfg: ModelConfig) -> tuple[Array, dict]:
    """Full-sequence mixer. Returns (out (B,S,D), final_state dict)."""
    d_in, ds, dc, dtr = _dims(cfg)
    dt = x.dtype
    b, s, _ = x.shape
    xz = x @ p["in_proj"].astype(dt)
    u, z = jnp.split(xz, 2, axis=-1)  # (B,S,d_in)
    u = jax.nn.silu(_conv_full(p, u, dc))
    proj = u @ p["x_proj"].astype(dt)
    dt_in, b_in, c_in = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    delta = jax.nn.softplus(
        dt_in.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )  # (B,S,d_in) f32
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (d_in, ds)

    uf = u.astype(jnp.float32)
    h0 = jnp.zeros((b, d_in, ds), jnp.float32)
    xs = (
        uf.swapaxes(0, 1),
        delta.swapaxes(0, 1),
        b_in.astype(jnp.float32).swapaxes(0, 1),
        c_in.astype(jnp.float32).swapaxes(0, 1),
    )
    h_last, ys = jax.lax.scan(lambda h, i: _ssm_step(h, i, a), h0, xs)
    y = ys.swapaxes(0, 1)  # (B,S,d_in)
    y = y + uf * p["d_skip"].astype(jnp.float32)[None, None, :]
    out = (y.astype(dt) * jax.nn.silu(z)) @ p["out_proj"].astype(dt)
    state = {
        "conv": xz[..., :d_in][:, -(dc - 1):, :] if s >= dc - 1 else
                jnp.pad(xz[..., :d_in], ((0, 0), (dc - 1 - s, 0), (0, 0))),
        "ssm": h_last.astype(jnp.float32),
    }
    return out, state


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    d_in, ds, dc, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, dc - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, ds), jnp.float32),
    }


def mamba_decode(
    p: PyTree, x: Array, cfg: ModelConfig, state: dict
) -> tuple[Array, dict]:
    """One-token step: x (B, 1, D)."""
    d_in, ds, dc, dtr = _dims(cfg)
    dt = x.dtype
    xz = x[:, 0, :] @ p["in_proj"].astype(dt)  # (B, 2*d_in)
    u, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([state["conv"].astype(dt), u[:, None, :]], axis=1)  # (B,dc,d_in)
    u_c = jnp.einsum("bwd,wd->bd", window, p["conv_w"].astype(dt)) + p["conv_b"].astype(dt)
    u_c = jax.nn.silu(u_c)
    proj = u_c @ p["x_proj"].astype(dt)
    dt_in, b_in, c_in = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    delta = jax.nn.softplus(
        dt_in.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    h, y = _ssm_step(
        state["ssm"],
        (u_c.astype(jnp.float32), delta, b_in.astype(jnp.float32), c_in.astype(jnp.float32)),
        a,
    )
    y = y + u_c.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, :]
    out = (y.astype(dt) * jax.nn.silu(z)) @ p["out_proj"].astype(dt)
    return out[:, None, :], {"conv": window[:, 1:, :].astype(state["conv"].dtype), "ssm": h}
