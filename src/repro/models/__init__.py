"""Architecture zoo: unified Model wrapper over GQA/MLA transformers, MoE,
Mamba-hybrid, RWKV6 and enc-dec families (see configs/ for the registry)."""
from repro.models.model import Model, num_params

__all__ = ["Model", "num_params"]
