"""Mixture-of-Experts FFN with expert parallelism.

Routing (token top-k over a softmax router, renormalized; load-balance +
router-z aux losses) runs in plain GSPMD-land — token-parallel math.  The
expert computation runs inside a ``shard_map`` island over the 'model' axis:

  * experts are sharded over 'model' (E_loc = E / tp per shard) and their
    weight matrices are additionally FSDP-sharded over the batch axes; the
    island all-gathers the FSDP shards (AD turns that into the ZeRO-style
    reduce-scatter on the backward pass);
  * each shard sort-dispatches ITS OWN data-shard tokens to ITS local
    experts into fixed ``(E_loc, C, D)`` capacity buffers (pure static-shape
    argsort/searchsorted/gather — no dynamic shapes, no host sync);
  * expert FFN is one batched einsum over local experts;
  * contributions are scatter-added back to token space and ``psum`` over
    'model' combines expert + shared-expert partial outputs.

Without a mesh (unit tests, CPU examples) the identical math runs with
E_loc = E and no collectives.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import context as dctx
from repro.models.layers import dense_init

Array = jax.Array
PyTree = Any


def init_moe(key, cfg: ModelConfig, dtype) -> PyTree:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (d, m.num_experts), jnp.float32, scale=d**-0.5),
        "w_in": dense_init(ks[1], (m.num_experts, d, m.d_ff_expert), dtype),
        "w_gate": dense_init(ks[2], (m.num_experts, d, m.d_ff_expert), dtype),
        "w_out": dense_init(ks[3], (m.num_experts, m.d_ff_expert, d), dtype),
    }
    if m.num_shared:
        fs = m.num_shared * m.d_ff_expert
        p["shared"] = {
            "w_in": dense_init(ks[4], (d, fs), dtype),
            "w_gate": dense_init(ks[5], (d, fs), dtype),
            "w_out": dense_init(ks[6], (fs, d), dtype),
        }
    return p


def _route(x2d: Array, router: Array, top_k: int):
    """Token top-k routing. Returns (top_e, top_p, aux_losses)."""
    logits = x2d.astype(jnp.float32) @ router.astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    e = router.shape[1]
    # load-balance (Switch): E * sum_e f_e * p_e
    f_e = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
    f_e = f_e / jnp.maximum(f_e.sum(), 1.0)
    p_e = probs.mean(axis=0)
    aux = e * jnp.sum(f_e * p_e)
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return top_e, top_p, {"router_aux": aux, "router_z": z}


def _dispatch_compute(
    x2d: Array,
    top_e: Array,
    top_p: Array,
    w_in: Array,
    w_gate: Array,
    w_out: Array,
    *,
    e_start: Array | int,
    e_loc: int,
    capacity: int,
) -> Array:
    """Capacity-buffer expert FFN for experts [e_start, e_start + e_loc)."""
    t, k = top_e.shape
    dt = x2d.dtype
    flat_e = top_e.reshape(-1)  # (T*k,)
    local_id = flat_e - e_start
    is_local = (local_id >= 0) & (local_id < e_loc)
    sort_key = jnp.where(is_local, local_id, e_loc)  # non-local -> tail bucket
    sort_idx = jnp.argsort(sort_key, stable=True)
    sorted_key = sort_key[sort_idx]
    seg_start = jnp.searchsorted(sorted_key, jnp.arange(e_loc), side="left")
    seg_end = jnp.searchsorted(sorted_key, jnp.arange(e_loc), side="right")
    slot_pos = seg_start[:, None] + jnp.arange(capacity)[None, :]  # (E_loc, C)
    valid = slot_pos < seg_end[:, None]  # capacity-drop beyond C
    slot_flat = jnp.take(sort_idx, jnp.clip(slot_pos, 0, t * k - 1))  # (E_loc, C)
    tok = slot_flat // k
    xb = jnp.take(x2d, tok, axis=0) * valid[..., None].astype(dt)  # (E_loc, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, w_gate.astype(dt))) * jnp.einsum(
        "ecd,edf->ecf", xb, w_in.astype(dt)
    )
    y = jnp.einsum("ecf,efd->ecd", h, w_out.astype(dt))  # (E_loc, C, D)
    gate = jnp.take(top_p.reshape(-1), slot_flat) * valid  # (E_loc, C)
    contrib = y * gate[..., None].astype(dt)
    out = jnp.zeros_like(x2d).at[tok.reshape(-1)].add(
        contrib.reshape(-1, x2d.shape[-1])
    )
    return out


def _shared_ffn(x2d: Array, shared: PyTree) -> Array:
    dt = x2d.dtype
    h = jax.nn.silu(x2d @ shared["w_gate"].astype(dt)) * (x2d @ shared["w_in"].astype(dt))
    return h @ shared["w_out"].astype(dt)


def moe_ffn(p: PyTree, x: Array, cfg: ModelConfig) -> tuple[Array, dict[str, Array]]:
    """MoE FFN over x (B, S, D). Returns (out, aux_losses)."""
    m = cfg.moe
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    top_e, top_p, aux = _route(x2d, p["router"], m.top_k)

    mesh = dctx.current_mesh()
    tp = dctx.model_axis_size(mesh)
    e_loc = m.num_experts // tp
    if m.num_experts % tp:
        raise ValueError(f"{m.num_experts} experts not divisible by tp={tp}")

    if mesh is None or tp == 1:
        t_tokens = x2d.shape[0]
        capacity = _capacity(t_tokens, m.top_k, m.num_experts, m.capacity_factor)
        out = _dispatch_compute(
            x2d, top_e, top_p, p["w_in"], p["w_gate"], p["w_out"],
            e_start=0, e_loc=m.num_experts, capacity=capacity,
        )
        if m.num_shared:
            out = out + _shared_ffn(x2d, p["shared"])
        return out.reshape(b, s, d), aux

    batch_axes = dctx.batch_axes(mesh)
    # Weight-sharding axes follow the ACTIVE fsdp rule (sharding.py), not the
    # mesh: at serving time fsdp=() replicates weights over the batch axes
    # and the island must not re-shard + re-gather them (measured 56 GB/step
    # of spurious all-gathers on deepseek-v2 decode_32k otherwise).
    from repro.distributed.sharding import LOGICAL_AXES

    fsdp_axes = tuple(a for a in LOGICAL_AXES.get("fsdp", ()) if a in mesh.axis_names)
    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= mesh.shape[a]
    # Decode / small-batch: moving 2x the expert weights over the wire to
    # meet a handful of tokens is backwards.  The weight-stationary island
    # contracts over the LOCAL D-slice and psums the (tiny) activations —
    # wire bytes O(T * F_e) instead of O(E_loc * D * F_e) per layer
    # (measured: 56 GB -> ~MBs per decode step on deepseek-v2 decode_32k).
    # Tokens are REPLICATED over the batch axes in this mode (every shard
    # computes all T tokens for its D-slice; psums complete contractions).
    weight_stationary = bool(fsdp_axes) and (b * s) * m.top_k <= 4096

    # B=1 decode and other indivisible token counts: replicate tokens over
    # the batch axes (expert parallelism still splits the work over 'model').
    token_sharded = (batch_axes and (b * s) % n_batch_shards == 0
                     and not weight_stationary)
    t_local = (b * s) // n_batch_shards if token_sharded else b * s
    capacity = _capacity(t_local, m.top_k, m.num_experts, m.capacity_factor)

    def _fsdp_index():
        idx = 0
        for a in fsdp_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    def island(x_l, te_l, tp_l, w_in, w_gate, w_out, shared):
        e_start = jax.lax.axis_index(dctx.MODEL_AXIS) * e_loc
        if not weight_stationary:
            # train path: FSDP-gather the D shards (ZeRO-3 style; AD emits
            # the matching reduce-scatter on the backward pass).
            if fsdp_axes:
                w_in = jax.lax.all_gather(w_in, fsdp_axes, axis=1, tiled=True)
                w_gate = jax.lax.all_gather(w_gate, fsdp_axes, axis=1, tiled=True)
                w_out = jax.lax.all_gather(w_out, fsdp_axes, axis=2, tiled=True)
            out = _dispatch_compute(
                x_l, te_l, tp_l, w_in, w_gate, w_out,
                e_start=e_start, e_loc=e_loc, capacity=capacity,
            )
            if shared is not None:
                if fsdp_axes:
                    sh = {
                        "w_in": jax.lax.all_gather(shared["w_in"], fsdp_axes, axis=0, tiled=True),
                        "w_gate": jax.lax.all_gather(shared["w_gate"], fsdp_axes, axis=0, tiled=True),
                        "w_out": jax.lax.all_gather(shared["w_out"], fsdp_axes, axis=1, tiled=True),
                    }
                else:
                    sh = shared
                out = out + _shared_ffn(x_l, sh)
            return jax.lax.psum(out, dctx.MODEL_AXIS)

        # ---- weight-stationary decode path ---------------------------------
        t, k = te_l.shape
        dt = x_l.dtype
        d_loc = w_in.shape[1]
        x_slice = jax.lax.dynamic_slice_in_dim(x_l, _fsdp_index() * d_loc, d_loc, axis=1)
        # same static-shape dispatch as _dispatch_compute, D-sliced
        flat_e = te_l.reshape(-1)
        local_id = flat_e - e_start
        is_local = (local_id >= 0) & (local_id < e_loc)
        sort_key = jnp.where(is_local, local_id, e_loc)
        sort_idx = jnp.argsort(sort_key, stable=True)
        sorted_key = sort_key[sort_idx]
        seg_start = jnp.searchsorted(sorted_key, jnp.arange(e_loc), side="left")
        seg_end = jnp.searchsorted(sorted_key, jnp.arange(e_loc), side="right")
        slot_pos = seg_start[:, None] + jnp.arange(capacity)[None, :]
        valid = slot_pos < seg_end[:, None]
        slot_flat = jnp.take(sort_idx, jnp.clip(slot_pos, 0, t * k - 1))
        tok = slot_flat // k
        xb = jnp.take(x_slice, tok, axis=0) * valid[..., None].astype(dt)  # (E_loc,C,D_loc)
        # contract local D slice, psum to complete before the nonlinearity
        h_gate = jax.lax.psum(jnp.einsum("ecd,edf->ecf", xb, w_gate.astype(dt)), fsdp_axes)
        h_in = jax.lax.psum(jnp.einsum("ecd,edf->ecf", xb, w_in.astype(dt)), fsdp_axes)
        h = jax.nn.silu(h_gate) * h_in  # (E_loc, C, F_e)
        y_slice = jnp.einsum("ecf,efd->ecd", h, w_out.astype(dt))  # (E_loc,C,D_loc)
        gate = jnp.take(tp_l.reshape(-1), slot_flat) * valid
        contrib = y_slice * gate[..., None].astype(dt)
        out_slice = jnp.zeros_like(x_slice).at[tok.reshape(-1)].add(
            contrib.reshape(-1, d_loc))
        if shared is not None:
            hs_g = jax.lax.psum(x_slice @ shared["w_gate"].astype(dt), fsdp_axes)
            hs_i = jax.lax.psum(x_slice @ shared["w_in"].astype(dt), fsdp_axes)
            hs = jax.nn.silu(hs_g) * hs_i  # (T, Fs_loc)
            out_slice = out_slice + hs @ shared["w_out"].astype(dt)
        out = jax.lax.all_gather(out_slice, fsdp_axes, axis=1, tiled=True)
        return jax.lax.psum(out, dctx.MODEL_AXIS)

    # ---- all-to-all EP dispatch (training/prefill; cfg.moe_a2a) -----------
    # Tokens are sharded over batch AND model axes (T_cell per device);
    # assignments travel to the expert's shard via all_to_all instead of
    # replicating compute + psumming full (T_loc, D) activations — wire
    # bytes drop from O(T_loc * D) to O(T_cell * k * D) per layer.
    cell_axes = tuple(batch_axes) + (dctx.MODEL_AXIS,)
    n_cells = n_batch_shards * tp
    use_a2a = (
        cfg.moe_a2a and not weight_stationary and batch_axes
        and (b * s) % n_cells == 0
    )
    if use_a2a:
        t_cell = (b * s) // n_cells
        cap_send = _capacity(t_cell, m.top_k, m.num_experts, m.capacity_factor)

        def island_a2a(x_l, te_l, tp_l, w_in, w_gate, w_out, shared):
            if fsdp_axes:
                w_in = jax.lax.all_gather(w_in, fsdp_axes, axis=1, tiled=True)
                w_gate = jax.lax.all_gather(w_gate, fsdp_axes, axis=1, tiled=True)
                w_out = jax.lax.all_gather(w_out, fsdp_axes, axis=2, tiled=True)
            t, k = te_l.shape
            dt = x_l.dtype
            e = m.num_experts
            # slot tokens by GLOBAL expert id -> (E, C_send) send buffer
            flat_e = te_l.reshape(-1)
            sort_idx = jnp.argsort(flat_e, stable=True)
            sorted_e = flat_e[sort_idx]
            seg_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
            seg_end = jnp.searchsorted(sorted_e, jnp.arange(e), side="right")
            slot_pos = seg_start[:, None] + jnp.arange(cap_send)[None, :]
            valid = slot_pos < seg_end[:, None]  # (E, C_send)
            slot_flat = jnp.take(sort_idx, jnp.clip(slot_pos, 0, t * k - 1))
            tok = slot_flat // k
            xb = jnp.take(x_l, tok, axis=0) * valid[..., None].astype(dt)
            # (E, C, D) -> (tp, E_loc, C, D) -> a2a over 'model'
            xb = xb.reshape(tp, e_loc, cap_send, -1)
            xr = jax.lax.all_to_all(
                xb, dctx.MODEL_AXIS, split_axis=0, concat_axis=0, tiled=False)
            # received: (tp sources, E_loc, C, D) -> (E_loc, tp*C, D)
            xr = xr.transpose(1, 0, 2, 3).reshape(e_loc, tp * cap_send, -1)
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xr, w_gate.astype(dt))) \
                * jnp.einsum("ecd,edf->ecf", xr, w_in.astype(dt))
            y = jnp.einsum("ecf,efd->ecd", h, w_out.astype(dt))
            # route results back: (E_loc, tp, C, D) -> a2a -> (E, C, D)
            y = y.reshape(e_loc, tp, cap_send, -1).transpose(1, 0, 2, 3)
            yr = jax.lax.all_to_all(
                y, dctx.MODEL_AXIS, split_axis=0, concat_axis=0, tiled=False)
            yr = yr.reshape(e * cap_send, -1)
            gate = (jnp.take(tp_l.reshape(-1), slot_flat) * valid).reshape(-1)
            out = jnp.zeros_like(x_l).at[tok.reshape(-1)].add(
                yr * gate[:, None].astype(dt))
            if shared is not None:
                # shared experts stay row/col-parallel over 'model' with a
                # psum of the (small) T_cell slice
                sh = shared
                if fsdp_axes:
                    sh = {
                        "w_in": jax.lax.all_gather(shared["w_in"], fsdp_axes, axis=0, tiled=True),
                        "w_gate": jax.lax.all_gather(shared["w_gate"], fsdp_axes, axis=0, tiled=True),
                        "w_out": jax.lax.all_gather(shared["w_out"], fsdp_axes, axis=1, tiled=True),
                    }
                out = out + jax.lax.psum(_shared_ffn(x_l, sh), dctx.MODEL_AXIS)
            return out

        cell_spec = P(cell_axes, None)
        out = dctx.shard_map(
            island_a2a,
            mesh=mesh,
            in_specs=(
                cell_spec, cell_spec, cell_spec,
                P(dctx.MODEL_AXIS, fsdp_axes if fsdp_axes else None, None),
                P(dctx.MODEL_AXIS, fsdp_axes if fsdp_axes else None, None),
                P(dctx.MODEL_AXIS, None, fsdp_axes if fsdp_axes else None),
                (
                    {"w_in": P(fsdp_axes if fsdp_axes else None, dctx.MODEL_AXIS),
                     "w_gate": P(fsdp_axes if fsdp_axes else None, dctx.MODEL_AXIS),
                     "w_out": P(dctx.MODEL_AXIS, fsdp_axes if fsdp_axes else None)}
                    if m.num_shared else None
                ),
            ),
            out_specs=cell_spec,
            check_vma=False,
        )(x2d, top_e, top_p, p["w_in"], p["w_gate"], p["w_out"], p.get("shared"))
        return out.reshape(b, s, d), aux

    x_spec = P(batch_axes if token_sharded else None, None)
    w_fsdp = fsdp_axes if fsdp_axes else None
    shared_specs = (
        {"w_in": P(w_fsdp, dctx.MODEL_AXIS),
         "w_gate": P(w_fsdp, dctx.MODEL_AXIS),
         "w_out": P(dctx.MODEL_AXIS, w_fsdp)}
        if m.num_shared
        else None
    )
    out = dctx.shard_map(
        island,
        mesh=mesh,
        in_specs=(
            x_spec,
            x_spec,
            x_spec,
            P(dctx.MODEL_AXIS, w_fsdp, None),
            P(dctx.MODEL_AXIS, w_fsdp, None),
            P(dctx.MODEL_AXIS, None, w_fsdp),
            shared_specs,
        ),
        out_specs=x_spec,
        check_vma=False,
    )(x2d, top_e, top_p, p["w_in"], p["w_gate"], p["w_out"], p.get("shared"))
    return out.reshape(b, s, d), aux


def _capacity(tokens: int, top_k: int, num_experts: int, factor: float) -> int:
    cap = int(tokens * top_k / num_experts * factor) + 1
    return max(8, -(-cap // 8) * 8)  # round up to 8 lanes
