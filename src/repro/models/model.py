"""Unified model wrapper: embedding/frontend -> stages -> head, with
train-forward, prefill and decode entry points, plus the kNN-LM retrieval
hook (the paper's technique) at the head during decode."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import logical_constraint
from repro.models.layers import (
    dense_init,
    dtype_of,
    init_embedding,
    layer_norm,
    rms_norm,
    sinusoidal_at,
    sinusoidal_positions,
)
from repro.models.transformer import (
    Stage,
    init_stage,
    init_stage_cache,
    plan_stages,
    stage_decode,
    stage_forward,
)

Array = jax.Array
PyTree = Any


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---------------------------------------------------------------- init
    @property
    def stages(self) -> list[Stage]:
        return plan_stages(self.cfg)

    @property
    def enc_stage(self) -> Stage | None:
        c = self.cfg
        if c.family != "encdec":
            return None
        return Stage(("wenc",), c.encoder_layers, c.scan_layers)

    def init(self, rng: Array) -> PyTree:
        c = self.cfg
        pdt = dtype_of(c.param_dtype)
        keys = jax.random.split(rng, 8 + len(self.stages))
        params: dict[str, Any] = {
            "embed": init_embedding(keys[0], c.padded_vocab, c.d_model, pdt),
            "final_norm": jnp.zeros((c.d_model,), jnp.float32),
        }
        if not c.tie_embeddings:
            params["lm_head"] = dense_init(keys[1], (c.d_model, c.padded_vocab), pdt)
        if c.family == "encdec":
            params["final_norm_bias"] = jnp.zeros((c.d_model,), jnp.float32)
            params["frame_proj"] = dense_init(keys[2], (c.d_model, c.d_model), pdt)
            params["enc"] = init_stage(self.enc_stage, keys[3], c, pdt)
            params["enc_norm"] = jnp.zeros((c.d_model,), jnp.float32)
            params["enc_norm_bias"] = jnp.zeros((c.d_model,), jnp.float32)
        if c.frontend == "vision_stub":
            params["patch_proj"] = dense_init(keys[4], (c.d_model, c.d_model), pdt)
        params["stages"] = [
            init_stage(st, keys[8 + i], c, pdt) for i, st in enumerate(self.stages)
        ]
        return params

    # ------------------------------------------------------------- helpers
    def _embed_tokens(self, params: PyTree, tokens: Array, pos0: Array | int = 0) -> Array:
        cdt = dtype_of(self.cfg.compute_dtype)
        x = params["embed"][tokens].astype(cdt)
        if self.cfg.family == "encdec":
            # whisper: learned-position stand-in (sinusoidal, offset-aware);
            # pos0 may be a scalar or a per-row (B,) vector (serving slots)
            pos0 = jnp.reshape(jnp.asarray(pos0, jnp.int32), (-1, 1))
            positions = pos0 + jnp.arange(tokens.shape[1])[None, :]
            x = x + sinusoidal_at(positions, self.cfg.d_model).astype(cdt)
        return logical_constraint(x, ("batch", "seq", "embed"))

    def _frontend(self, params: PyTree, x: Array, batch: dict) -> Array:
        """vlm stub: precomputed patch embeddings replace leading positions."""
        c = self.cfg
        if c.frontend == "vision_stub" and "patches" in batch:
            cdt = dtype_of(c.compute_dtype)
            patches = batch["patches"].astype(cdt) @ params["patch_proj"].astype(cdt)
            n = patches.shape[1]
            x = jnp.concatenate([patches, x[:, n:, :]], axis=1)
        return x

    def _encode(self, params: PyTree, frames: Array) -> Array:
        """audio stub: precomputed frame embeddings -> encoder stack."""
        c = self.cfg
        cdt = dtype_of(c.compute_dtype)
        x = frames.astype(cdt) @ params["frame_proj"].astype(cdt)
        x = x + sinusoidal_positions(x.shape[1], c.d_model)[None].astype(cdt)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        aux: dict[str, Array] = {}
        x, _ = stage_forward(self.enc_stage, params["enc"], x, c, positions, aux)
        return layer_norm(x, params["enc_norm"], params["enc_norm_bias"], c.norm_eps)

    def _head(self, params: PyTree, x: Array) -> Array:
        c = self.cfg
        if c.family == "encdec":
            x = layer_norm(x, params["final_norm"], params["final_norm_bias"], c.norm_eps)
        else:
            x = rms_norm(x, params["final_norm"], c.norm_eps)
        if c.tie_embeddings:
            logits = jnp.einsum(
                "bsd,vd->bsv", x.astype(jnp.float32), params["embed"].astype(jnp.float32)
            )
        else:
            logits = x.astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
        return logical_constraint(logits, ("batch", "seq", "vocab"))

    # ------------------------------------------------------------- forward
    def forward(
        self, params: PyTree, batch: dict, *, collect_cache: bool = False
    ) -> tuple[Array, dict, PyTree | None]:
        """Teacher-forced forward (train / prefill).

        batch: {'tokens': (B,S) i32, 'frames': (B,S_enc,D)?, 'patches': ?}
        Returns (logits (B,S,V), aux, caches or None).
        """
        c = self.cfg
        tokens = batch["tokens"]
        x = self._embed_tokens(params, tokens)
        x = self._frontend(params, x, batch)
        enc_out = self._encode(params, batch["frames"]) if c.family == "encdec" else None
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
        aux: dict[str, Array] = {}
        caches = []
        for st, sp in zip(self.stages, params["stages"]):
            x, cache = stage_forward(
                st, sp, x, c, positions, aux,
                collect_cache=collect_cache, enc_out=enc_out,
            )
            caches.append(cache)
        logits = self._head(params, x)
        return logits, aux, (caches if collect_cache else None)

    # ------------------------------------------------------------- prefill
    def prefill(
        self, params: PyTree, batch: dict, *, max_len: int
    ) -> tuple[Array, PyTree]:
        """Process the prompt; return (logits (B,S,V), cache padded to
        ``max_len``) ready for decode_step at pos = prompt_len."""
        tokens = batch["tokens"]
        logits, _, caches = self.forward(params, batch, collect_cache=True)
        template = self.init_cache(tokens.shape[0], max_len)

        def pad_like(got, tmpl):
            if got is None:
                return tmpl
            pads = [(0, t - g) for g, t in zip(got.shape, tmpl.shape)]
            return jnp.pad(got.astype(tmpl.dtype), pads)

        cache = jax.tree.map(pad_like, caches, template)
        return logits, cache

    # -------------------------------------------------------------- decode
    def init_cache(self, batch_size: int, max_len: int) -> PyTree:
        cdt = dtype_of(self.cfg.compute_dtype)
        return [
            init_stage_cache(st, self.cfg, batch_size, max_len, cdt)
            for st in self.stages
        ]

    def decode_step(
        self,
        params: PyTree,
        tokens: Array,  # (B, 1)
        cache: PyTree,
        pos: Array,  # current position: scalar, or (B,) per-slot positions
        *,
        datastore: PyTree | None = None,
    ) -> tuple[Array, PyTree]:
        """One decode step. Returns (logits (B, V), new_cache).

        ``pos`` may be a (B,) vector so a continuous-batching engine can
        advance every slot at its own cache position in one jitted step.

        When ``datastore`` is provided and cfg.retrieval.enabled, the output
        distribution is interpolated with the kNN-LM distribution retrieved
        from the paper's overlap-optimized datastore (serve/retrieval.py).
        """
        c = self.cfg
        x = self._embed_tokens(params, tokens, pos0=pos)
        aux: dict[str, Array] = {}
        new_caches = []
        for st, sp, sc in zip(self.stages, params["stages"], cache):
            x, nc = stage_decode(st, sp, x, c, sc, pos, aux)
            new_caches.append(nc)
        hidden = x  # (B, 1, D) pre-head hidden state = retrieval query
        logits = self._head(params, x)[:, 0, :]
        if datastore is not None and c.retrieval.enabled:
            from repro.serve.retrieval import knn_interpolate

            logits = knn_interpolate(logits, hidden[:, 0, :], datastore, c)
        return logits, new_caches

    # ---------------------------------------------------------------- loss
    def loss(self, params: PyTree, batch: dict) -> tuple[Array, dict]:
        """Mean next-token CE (+ router aux losses). batch needs 'targets'."""
        c = self.cfg
        logits, aux, _ = self.forward(params, batch)
        targets = batch["targets"]
        mask = (targets >= 0) & (targets < c.vocab_size)
        tsafe = jnp.clip(targets, 0, c.padded_vocab - 1)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tsafe[..., None], axis=-1)[..., 0]
        ce = (logz - gold) * mask
        denom = jnp.maximum(mask.sum(), 1)
        loss = ce.sum() / denom
        metrics = {"ce": loss, "tokens": denom}
        if c.moe is not None:
            loss = loss + c.moe.router_aux_coef * aux.get("router_aux", 0.0)
            loss = loss + c.moe.router_z_coef * aux.get("router_z", 0.0)
            metrics["router_aux"] = aux.get("router_aux", 0.0)
        return loss, metrics


def num_params(params: PyTree) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
