"""RWKV-6 "Finch" block (attention-free, data-dependent per-channel decay).

Time-mix recurrence per head (state S in R^{hd x hd}):

    y_t = r_t ( S_t + (u * k_t) v_t^T )
    S_{t+1} = diag(w_t) S_t + k_t v_t^T          (w_t data-dependent)

Training scans over time carrying (B, H, hd, hd) — O(1) in sequence length,
which is why rwkv6 runs the long_500k cell trivially.  Token-shift mixing
uses the Finch data-dependent lerp (ddlerp) with the 5-way low-rank delta.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

Array = jax.Array
PyTree = Any

_MIX_NAMES = ("r", "k", "v", "g", "w")


def _dims(cfg: ModelConfig) -> tuple[int, int]:
    hd = cfg.rwkv.head_dim
    return cfg.d_model // hd, hd  # (heads, head_dim)


def init_time_mix(key, cfg: ModelConfig, dtype) -> PyTree:
    d = cfg.d_model
    h, hd = _dims(cfg)
    r = cfg.rwkv
    ks = jax.random.split(key, 12)
    return {
        "mu_x": jnp.zeros((d,), jnp.float32),
        "mu": jnp.zeros((5, d), jnp.float32),  # r,k,v,g,w base lerp factors
        "lora_a": dense_init(ks[0], (d, 5 * 32), dtype),
        "lora_b": dense_init(ks[1], (5, 32, d), dtype, scale=32**-0.5),
        "wr": dense_init(ks[2], (d, d), dtype),
        "wk": dense_init(ks[3], (d, d), dtype),
        "wv": dense_init(ks[4], (d, d), dtype),
        "wg": dense_init(ks[5], (d, d), dtype),
        "wo": dense_init(ks[6], (d, d), dtype),
        "w0": jnp.full((d,), -5.0, jnp.float32),  # decay bias (slow decay init)
        "wd_a": dense_init(ks[7], (d, r.decay_lora), dtype),
        "wd_b": dense_init(ks[8], (r.decay_lora, d), dtype, scale=r.decay_lora**-0.5),
        "u": (jax.random.normal(ks[9], (h, hd)) * 0.1).astype(jnp.float32),
        "ln_scale": jnp.zeros((d,), jnp.float32),  # per-head group norm
        "ln_bias": jnp.zeros((d,), jnp.float32),
    }


def init_channel_mix(key, cfg: ModelConfig, dtype) -> PyTree:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((d,), jnp.float32),
        "mu_r": jnp.zeros((d,), jnp.float32),
        "wk": dense_init(ks[0], (d, f), dtype),
        "wv": dense_init(ks[1], (f, d), dtype),
        "wr": dense_init(ks[2], (d, d), dtype),
    }


def _ddlerp(p: PyTree, x: Array, xx: Array) -> list[Array]:
    """Finch data-dependent lerp: 5 mixed inputs (r,k,v,g,w)."""
    dt = x.dtype
    diff = xx - x
    xm = x + diff * p["mu_x"].astype(dt)
    lo = jnp.tanh(xm @ p["lora_a"].astype(dt))  # (B,S,5*32)
    lo = lo.reshape(*lo.shape[:-1], 5, 32)
    delta = jnp.einsum("bsfr,frd->bsfd", lo, p["lora_b"].astype(dt))  # (B,S,5,D)
    outs = []
    for i in range(5):
        mi = p["mu"][i].astype(dt) + delta[..., i, :]
        outs.append(x + diff * mi)
    return outs


def _group_norm(y: Array, scale: Array, bias: Array, h: int, eps: float = 64e-5) -> Array:
    """Per-head LayerNorm on (B, S, D) viewed as (..., H, hd)."""
    b, s, d = y.shape
    yf = y.astype(jnp.float32).reshape(b, s, h, d // h)
    mu = yf.mean(-1, keepdims=True)
    var = ((yf - mu) ** 2).mean(-1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + eps)
    yn = yn.reshape(b, s, d)
    return (yn * (1.0 + scale.astype(jnp.float32)) + bias.astype(jnp.float32)).astype(y.dtype)


def _rkvgw(p: PyTree, x: Array, xx: Array, cfg: ModelConfig):
    h, hd = _dims(cfg)
    dt = x.dtype
    xr, xk, xv, xg, xw = _ddlerp(p, x, xx)
    b, s, d = x.shape
    r = (xr @ p["wr"].astype(dt)).reshape(b, s, h, hd)
    k = (xk @ p["wk"].astype(dt)).reshape(b, s, h, hd)
    v = (xv @ p["wv"].astype(dt)).reshape(b, s, h, hd)
    g = jax.nn.silu(xg @ p["wg"].astype(dt))
    wdec = p["w0"].astype(jnp.float32) + (
        jnp.tanh(xw @ p["wd_a"].astype(dt)).astype(jnp.float32)
        @ p["wd_b"].astype(jnp.float32)
    )
    w = jnp.exp(-jnp.exp(wdec)).reshape(b, s, h, hd)  # in (0,1), data-dependent
    return r, k, v, g, w


def _wkv_step(S, inputs, u):
    """S: (B, H, hd_k, hd_v)."""
    r, k, v, w = inputs  # each (B, H, hd)
    kv = k[..., :, None] * v[..., None, :]  # (B,H,hd,hd)
    y = jnp.einsum("bhk,bhkv->bhv", r, S + u[None, :, :, None] * kv)
    S = w[..., :, None] * S + kv
    return S, y


def time_mix_forward(
    p: PyTree, x: Array, cfg: ModelConfig, state: dict | None = None
) -> tuple[Array, dict]:
    """Full-sequence time-mix. state carries (shift, wkv) for continuation."""
    h, hd = _dims(cfg)
    b, s, d = x.shape
    prev = state["shift"] if state else jnp.zeros((b, 1, d), x.dtype)
    xx = jnp.concatenate([prev, x[:, :-1, :]], axis=1)  # token shift
    r, k, v, g, w = _rkvgw(p, x, xx, cfg)
    s0 = state["wkv"] if state else jnp.zeros((b, h, hd, hd), jnp.float32)
    xs = (
        r.astype(jnp.float32).swapaxes(0, 1),
        k.astype(jnp.float32).swapaxes(0, 1),
        v.astype(jnp.float32).swapaxes(0, 1),
        w.astype(jnp.float32).swapaxes(0, 1),
    )
    s_last, ys = jax.lax.scan(lambda c, i: _wkv_step(c, i, p["u"]), s0, xs)
    y = ys.swapaxes(0, 1).reshape(b, s, d).astype(x.dtype)
    y = _group_norm(y, p["ln_scale"], p["ln_bias"], h) * g
    out = y @ p["wo"].astype(x.dtype)
    return out, {"shift": x[:, -1:, :], "wkv": s_last}


def time_mix_decode(p: PyTree, x: Array, cfg: ModelConfig, state: dict) -> tuple[Array, dict]:
    """x: (B, 1, D) — single step via the same scan with s=1."""
    return time_mix_forward(p, x, cfg, state)


def channel_mix_forward(
    p: PyTree, x: Array, cfg: ModelConfig, state: dict | None = None
) -> tuple[Array, dict]:
    dt = x.dtype
    b, s, d = x.shape
    prev = state["shift"] if state else jnp.zeros((b, 1, d), x.dtype)
    xx = jnp.concatenate([prev, x[:, :-1, :]], axis=1)
    xk = x + (xx - x) * p["mu_k"].astype(dt)
    xr = x + (xx - x) * p["mu_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dt)))
    out = jax.nn.sigmoid(xr @ p["wr"].astype(dt)) * (kk @ p["wv"].astype(dt))
    return out, {"shift": x[:, -1:, :]}


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    h, hd = _dims(cfg)
    d = cfg.d_model
    return {
        "tm": {"shift": jnp.zeros((batch, 1, d), dtype),
               "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32)},
        "cm": {"shift": jnp.zeros((batch, 1, d), dtype)},
    }
