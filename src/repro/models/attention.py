"""Attention modules: GQA (llama family, whisper, hybrid attn layers) and
MLA (DeepSeek-V2 multi-head latent attention, incl. the absorbed decode path
that attends directly over the compressed KV cache)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.layers import (
    apply_rope,
    chunked_attention,
    decode_attention,
    dense_init,
)

Array = jax.Array
PyTree = Any


def pos_cols(pos: Array, batch: int) -> Array:
    """Positions as an i32 (B, 1) column; accepts a scalar or a (B,) vector.

    The serving engine steps every slot at its OWN cache position
    (continuous batching refills slots with shorter prompts mid-flight), so
    the whole decode path accepts per-row positions.
    """
    p = jnp.reshape(jnp.asarray(pos, jnp.int32), (-1, 1))
    return jnp.broadcast_to(p, (batch, 1))


def cache_update(cache: Array, new: Array, pos: Array) -> Array:
    """Write ``new`` (B, 1, ...) into ``cache`` (B, S, ...) at position pos
    (scalar, or (B,) for per-row positions).

    Implemented as a masked select instead of dynamic_update_slice: DUS with
    a traced index on a sharded S dimension makes GSPMD all-gather the whole
    cache (measured: ~58 GB/step on deepseek-v2 decode_32k); the iota==pos
    select is shard-local — each shard touches only its own S slice.
    """
    s = cache.shape[1]
    cols = jnp.reshape(jnp.asarray(pos, jnp.int32), (-1, 1))  # (1|B, 1)
    mask = (jnp.arange(s)[None, :] == cols).reshape(
        (cols.shape[0], s) + (1,) * (cache.ndim - 2)
    )
    return jnp.where(mask, new.astype(cache.dtype), cache)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ModelConfig, dtype) -> PyTree:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), dtype, scale=d**-0.5),
        "wk": dense_init(ks[1], (d, kv, hd), dtype, scale=d**-0.5),
        "wv": dense_init(ks[2], (d, kv, hd), dtype, scale=d**-0.5),
        "wo": dense_init(ks[3], (h, hd, d), dtype, scale=(h * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    return p


def gqa_qkv(p: PyTree, x: Array, cfg: ModelConfig, positions: Array, *, rope: bool = True):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dvk->bsvk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dvk->bsvk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_forward(
    p: PyTree,
    x: Array,
    cfg: ModelConfig,
    positions: Array,
    *,
    causal: bool = True,
    rope: bool = True,
    kv_block: int = 1024,
) -> tuple[Array, tuple[Array, Array]]:
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    q, k, v = gqa_qkv(p, x, cfg, positions, rope=rope)
    o = chunked_attention(q, k, v, causal=causal, kv_block=kv_block)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, (k, v)


def gqa_cross_forward(
    p: PyTree, x: Array, k: Array, v: Array, cfg: ModelConfig, positions: Array
) -> Array:
    """Cross-attention with precomputed encoder K/V (whisper decoder)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
    o = chunked_attention(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))


def gqa_decode(
    p: PyTree,
    x: Array,
    cfg: ModelConfig,
    cache: dict[str, Array],
    pos: Array,
    *,
    rope: bool = True,
) -> tuple[Array, dict[str, Array]]:
    """One-token decode. cache: {'k': (B,S,KV,hd), 'v': ...}; pos scalar or (B,)."""
    positions = pos_cols(pos, x.shape[0])
    q, k, v = gqa_qkv(p, x, cfg, positions, rope=rope)
    k_cache = cache_update(cache["k"], k, pos)
    v_cache = cache_update(cache["v"], v, pos)
    o = decode_attention(q, k_cache, v_cache, pos + 1)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig, dtype) -> PyTree:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qd = m.nope_head_dim + m.rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "wdq": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "wuq": dense_init(ks[1], (m.q_lora_rank, h, qd), dtype),
        "wdkv": dense_init(ks[2], (d, m.kv_lora_rank), dtype),
        "wk_rope": dense_init(ks[3], (d, m.rope_head_dim), dtype),
        "wuk": dense_init(ks[4], (m.kv_lora_rank, h, m.nope_head_dim), dtype),
        "wuv": dense_init(ks[5], (m.kv_lora_rank, h, m.v_head_dim), dtype),
        "wo": dense_init(ks[6], (h, m.v_head_dim, d), dtype, scale=(h * m.v_head_dim) ** -0.5),
    }


def _mla_q(p, x, cfg, positions):
    m = cfg.mla
    dt = x.dtype
    cq = x @ p["wdq"].astype(dt)  # (B,S,q_lora)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wuq"].astype(dt))
    q_nope = q[..., : m.nope_head_dim]
    q_rope = apply_rope(q[..., m.nope_head_dim :], positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(
    p: PyTree, x: Array, cfg: ModelConfig, positions: Array, *, kv_block: int = 1024
) -> tuple[Array, tuple[Array, Array]]:
    """Full-sequence MLA (train / prefill): decompress K/V per head, run the
    same chunked attention; cache is the COMPRESSED (c_kv, k_rope) pair."""
    m = cfg.mla
    dt = x.dtype
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv = x @ p["wdkv"].astype(dt)  # (B,S,lora)
    k_rope = apply_rope((x @ p["wk_rope"].astype(dt))[:, :, None, :], positions, cfg.rope_theta)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wuk"].astype(dt))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wuv"].astype(dt))
    h = cfg.num_heads
    k_rope_b = jnp.broadcast_to(k_rope, (*k_rope.shape[:2], h, m.rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    # pad v's head_dim up to q/k head dim for the shared attention helper,
    # then slice back (keeps one attention implementation).
    qd = m.nope_head_dim + m.rope_head_dim
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qd - m.v_head_dim)))
    o = chunked_attention(q, k, v_pad, causal=True, kv_block=kv_block)[..., : m.v_head_dim]
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
    return out, (c_kv, k_rope[:, :, 0, :])


def mla_decode(
    p: PyTree, x: Array, cfg: ModelConfig, cache: dict[str, Array], pos: Array
) -> tuple[Array, dict[str, Array]]:
    """Absorbed decode: attend over the compressed cache directly.

    scores = (q_nope W_uk) c_kv^T + q_rope k_rope^T  — never materializes
    per-head K/V for the full context; this is the production MLA trick and
    the reason the 32k cache is (S, 512+64) instead of (S, H*2*128).
    """
    m = cfg.mla
    dt = x.dtype
    positions = pos_cols(pos, x.shape[0])
    q_nope, q_rope = _mla_q(p, x, cfg, positions)  # (B,1,H,*)
    c_kv_new = x @ p["wdkv"].astype(dt)  # (B,1,lora)
    k_rope_new = apply_rope((x @ p["wk_rope"].astype(dt))[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    c_cache = cache_update(cache["c_kv"], c_kv_new, pos)
    r_cache = cache_update(cache["k_rope"], k_rope_new, pos)
    # absorb W_uk into q: (B,1,H,nope) @ (lora,H,nope) -> (B,1,H,lora)
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, p["wuk"].astype(dt))
    s_c = jnp.einsum("bshr,btr->bhst", q_abs, c_cache.astype(dt))  # (B,H,1,S)
    s_r = jnp.einsum("bshk,btk->bhst", q_rope, r_cache.astype(dt))
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    scores = (s_c + s_r).astype(jnp.float32) * scale
    cur = jnp.reshape(jnp.asarray(pos, jnp.int32) + 1, (-1, 1))  # (1|B, 1)
    mask = (jnp.arange(c_cache.shape[1])[None, :] < cur)[:, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    pattn = jax.nn.softmax(scores, axis=-1)
    # attend in compressed space, decompress with W_uv afterwards
    o_c = jnp.einsum("bhst,btr->bshr", pattn.astype(dt), c_cache.astype(dt))  # (B,1,H,lora)
    o = jnp.einsum("bshr,rhk->bshk", o_c, p["wuv"].astype(dt))  # (B,1,H,v_hd)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
    return out, {"c_kv": c_cache, "k_rope": r_cache}
