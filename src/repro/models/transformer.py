"""Block assembly for every architecture family.

A model is a sequence of STAGES; each stage is a repeated UNIT of one or
more sub-layer kinds.  Homogeneous stages are executed with
``lax.scan`` over stacked parameters (weights carry a leading ``n_repeat``
axis) so the HLO stays O(unit) instead of O(layers) — mandatory for the
95-layer configs on the 512-device dry-run, and the production-idiomatic
layout (MaxText-style).  Units with interleaved kinds (Jamba's 1-attention:
7-mamba groups with alternating dense/MoE FFNs) unroll the heterogeneous
pattern INSIDE the scanned unit body.

Sub-layer kinds:
  gqa_dense / gqa_moe    — GQA attention + SwiGLU or MoE FFN (llama family)
  mla_dense / mla_moe    — DeepSeek-V2 latent attention + FFN
  mamba_dense / mamba_moe— Mamba mixer + FFN (Jamba)
  rwkv                   — RWKV6 time-mix + channel-mix
  wenc / wdec            — whisper encoder / decoder (LayerNorm + GELU)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import logical_constraint
from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models.layers import (
    init_mlp,
    init_mlp_gelu,
    layer_norm,
    mlp_gelu,
    mlp_swiglu,
    rms_norm,
    stack_init,
)

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# Stage planning
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Stage:
    unit: tuple[str, ...]  # sub-layer kinds within one unit
    n: int                 # unit repeats
    scan: bool


def plan_stages(cfg: ModelConfig) -> list[Stage]:
    if cfg.family == "encdec":
        return [Stage(("wdec",), cfg.num_layers, cfg.scan_layers)]
    if cfg.family == "ssm":
        return [Stage(("rwkv",), cfg.num_layers, cfg.scan_layers)]
    if cfg.family == "hybrid":
        gsize = cfg.attn_period
        assert cfg.num_layers % gsize == 0
        unit = []
        for j in range(gsize):
            mix = "attn" if j == cfg.attn_offset else "mamba"
            ffn = "moe" if cfg.is_moe_layer(j) else "dense"
            unit.append(("gqa" if mix == "attn" else "mamba") + "_" + ffn)
        return [Stage(tuple(unit), cfg.num_layers // gsize, cfg.scan_layers)]
    base = "mla" if cfg.mla is not None else "gqa"
    if cfg.moe is None:
        return [Stage((f"{base}_dense",), cfg.num_layers, cfg.scan_layers)]
    stages = []
    fd = cfg.moe.first_dense
    if fd:
        stages.append(Stage((f"{base}_dense",), fd, False))
    stages.append(Stage((f"{base}_moe",), cfg.num_layers - fd, cfg.scan_layers))
    return stages


# ---------------------------------------------------------------------------
# Sub-layer init / forward / decode
# ---------------------------------------------------------------------------


def _init_sublayer(kind: str, key, cfg: ModelConfig, dtype) -> PyTree:
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind == "rwkv":
        return {
            "ln1": jnp.zeros((d,), jnp.float32),
            "tm": rwkv_lib.init_time_mix(k1, cfg, dtype),
            "ln2": jnp.zeros((d,), jnp.float32),
            "cm": rwkv_lib.init_channel_mix(k2, cfg, dtype),
        }
    if kind == "wenc":
        return {
            "ln1": jnp.zeros((d,), jnp.float32), "lb1": jnp.zeros((d,), jnp.float32),
            "attn": attn.init_gqa(k1, cfg, dtype),
            "ln2": jnp.zeros((d,), jnp.float32), "lb2": jnp.zeros((d,), jnp.float32),
            "mlp": init_mlp_gelu(k2, d, cfg.d_ff, dtype),
        }
    if kind == "wdec":
        return {
            "ln1": jnp.zeros((d,), jnp.float32), "lb1": jnp.zeros((d,), jnp.float32),
            "attn": attn.init_gqa(k1, cfg, dtype),
            "ln2": jnp.zeros((d,), jnp.float32), "lb2": jnp.zeros((d,), jnp.float32),
            "cross": attn.init_gqa(k2, cfg, dtype),
            "ln3": jnp.zeros((d,), jnp.float32), "lb3": jnp.zeros((d,), jnp.float32),
            "mlp": init_mlp_gelu(k3, d, cfg.d_ff, dtype),
        }
    mix, ffn = kind.split("_")
    p = {"ln1": jnp.zeros((d,), jnp.float32), "ln2": jnp.zeros((d,), jnp.float32)}
    if mix == "gqa":
        p["attn"] = attn.init_gqa(k1, cfg, dtype)
    elif mix == "mla":
        p["attn"] = attn.init_mla(k1, cfg, dtype)
    elif mix == "mamba":
        p["mamba"] = mam.init_mamba(k1, cfg, dtype)
    if ffn == "dense":
        p["mlp"] = init_mlp(k2, d, cfg.d_ff, dtype)
    else:
        p["moe"] = moe_lib.init_moe(k2, cfg, dtype)
    return p


def _ffn(kind: str, p: PyTree, x: Array, cfg: ModelConfig, aux: dict) -> Array:
    if kind.endswith("_moe"):
        out, losses = moe_lib.moe_ffn(p["moe"], x, cfg)
        aux["router_aux"] = aux.get("router_aux", 0.0) + losses["router_aux"]
        aux["router_z"] = aux.get("router_z", 0.0) + losses["router_z"]
        return out
    return mlp_swiglu(p["mlp"], x)


def sublayer_forward(
    kind: str,
    p: PyTree,
    x: Array,
    cfg: ModelConfig,
    positions: Array,
    aux: dict,
    *,
    collect_cache: bool,
    enc_out: Array | None = None,
) -> tuple[Array, PyTree | None]:
    eps = cfg.norm_eps
    x = logical_constraint(x, ("batch", "seq", "embed"))

    def _post(h):
        # sequence-parallel TP (Korthikanti et al.): pin sub-layer outputs to
        # the seq-sharded layout so GSPMD lowers the TP combine as
        # reduce-scatter instead of all-reduce (halves the wire bytes).
        if cfg.constrain_sublayer_outputs:
            return logical_constraint(h, ("batch", "seq", "embed"))
        return h

    cache = None
    if kind == "rwkv":
        h, st_tm = rwkv_lib.time_mix_forward(p["tm"], rms_norm(x, p["ln1"], eps), cfg)
        x = x + _post(h)
        h, st_cm = rwkv_lib.channel_mix_forward(p["cm"], rms_norm(x, p["ln2"], eps), cfg)
        x = x + _post(h)
        cache = {"tm": st_tm, "cm": st_cm} if collect_cache else None
    elif kind == "wenc":
        h, _ = attn.gqa_forward(
            p["attn"], layer_norm(x, p["ln1"], p["lb1"], eps), cfg, positions,
            causal=False, rope=False,
        )
        x = x + h
        x = x + mlp_gelu(p["mlp"], layer_norm(x, p["ln2"], p["lb2"], eps))
    elif kind == "wdec":
        h, (k, v) = attn.gqa_forward(
            p["attn"], layer_norm(x, p["ln1"], p["lb1"], eps), cfg, positions,
            causal=True, rope=False,
        )
        x = x + h
        dt = x.dtype
        ck = jnp.einsum("bsd,dvk->bsvk", enc_out, p["cross"]["wk"].astype(dt))
        cv = jnp.einsum("bsd,dvk->bsvk", enc_out, p["cross"]["wv"].astype(dt))
        x = x + attn.gqa_cross_forward(
            p["cross"], layer_norm(x, p["ln2"], p["lb2"], eps), ck, cv, cfg, positions
        )
        x = x + mlp_gelu(p["mlp"], layer_norm(x, p["ln3"], p["lb3"], eps))
        if collect_cache:
            cache = {"k": k, "v": v, "ck": ck, "cv": cv}
    else:
        mix = kind.split("_")[0]
        if mix in ("gqa", "mla"):
            fwd = attn.mla_forward if mix == "mla" else attn.gqa_forward
            h, kv = fwd(p["attn"], rms_norm(x, p["ln1"], eps), cfg, positions)
            if collect_cache:
                cache = (
                    {"c_kv": kv[0], "k_rope": kv[1]} if mix == "mla"
                    else {"k": kv[0], "v": kv[1]}
                )
        else:  # mamba
            h, st = mam.mamba_forward(p["mamba"], rms_norm(x, p["ln1"], eps), cfg)
            cache = st if collect_cache else None
        x = x + _post(h)
        x = x + _post(_ffn(kind, p, rms_norm(x, p["ln2"], eps), cfg, aux))
    return x, cache


def sublayer_decode(
    kind: str,
    p: PyTree,
    x: Array,
    cfg: ModelConfig,
    cache: PyTree,
    pos: Array,
    aux: dict,
) -> tuple[Array, PyTree]:
    eps = cfg.norm_eps
    if kind == "rwkv":
        h, st_tm = rwkv_lib.time_mix_decode(p["tm"], rms_norm(x, p["ln1"], eps), cfg, cache["tm"])
        x = x + h
        h, st_cm = rwkv_lib.channel_mix_forward(p["cm"], rms_norm(x, p["ln2"], eps), cfg, cache["cm"])
        x = x + h
        return x, {"tm": st_tm, "cm": st_cm}
    if kind == "wdec":
        h, kv = attn.gqa_decode(
            p["attn"], layer_norm(x, p["ln1"], p["lb1"], eps), cfg,
            {"k": cache["k"], "v": cache["v"]}, pos, rope=False,
        )
        x = x + h
        positions = attn.pos_cols(pos, x.shape[0])
        x = x + attn.gqa_cross_forward(
            p["cross"], layer_norm(x, p["ln2"], p["lb2"], eps),
            cache["ck"], cache["cv"], cfg, positions,
        )
        x = x + mlp_gelu(p["mlp"], layer_norm(x, p["ln3"], p["lb3"], eps))
        return x, {**kv, "ck": cache["ck"], "cv": cache["cv"]}
    mix = kind.split("_")[0]
    if mix == "gqa":
        h, new_cache = attn.gqa_decode(p["attn"], rms_norm(x, p["ln1"], eps), cfg, cache, pos)
    elif mix == "mla":
        h, new_cache = attn.mla_decode(p["attn"], rms_norm(x, p["ln1"], eps), cfg, cache, pos)
    else:
        h, new_cache = mam.mamba_decode(p["mamba"], rms_norm(x, p["ln1"], eps), cfg, cache)
    x = x + h
    x = x + _ffn(kind, p, rms_norm(x, p["ln2"], eps), cfg, aux)
    return x, new_cache


def init_sublayer_cache(
    kind: str, cfg: ModelConfig, batch: int, max_len: int, dtype
) -> PyTree:
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if kind == "rwkv":
        return rwkv_lib.init_rwkv_state(cfg, batch, dtype)
    if kind == "wdec":
        return {
            "k": jnp.zeros((batch, max_len, kv, hd), dtype),
            "v": jnp.zeros((batch, max_len, kv, hd), dtype),
            "ck": jnp.zeros((batch, cfg.encoder_seq, kv, hd), dtype),
            "cv": jnp.zeros((batch, cfg.encoder_seq, kv, hd), dtype),
        }
    mix = kind.split("_")[0]
    if mix == "gqa":
        return {
            "k": jnp.zeros((batch, max_len, kv, hd), dtype),
            "v": jnp.zeros((batch, max_len, kv, hd), dtype),
        }
    if mix == "mla":
        m = cfg.mla
        return {
            "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, m.rope_head_dim), dtype),
        }
    return mam.init_mamba_state(cfg, batch, dtype)


# ---------------------------------------------------------------------------
# Stage execution
# ---------------------------------------------------------------------------


def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint_policies.nothing_saveable
    if cfg.remat == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.everything_saveable


def init_stage(stage: Stage, key, cfg: ModelConfig, dtype) -> PyTree:
    def unit_init(k):
        ks = jax.random.split(k, len(stage.unit))
        return {f"u{j}": _init_sublayer(kind, ks[j], cfg, dtype)
                for j, kind in enumerate(stage.unit)}

    if stage.scan:
        return stack_init(key, stage.n, unit_init)
    ks = jax.random.split(key, stage.n)
    return [unit_init(k) for k in ks]


def stage_forward(
    stage: Stage,
    params: PyTree,
    x: Array,
    cfg: ModelConfig,
    positions: Array,
    aux: dict,
    *,
    collect_cache: bool = False,
    enc_out: Array | None = None,
) -> tuple[Array, PyTree | None]:
    def unit_body(x, unit_params):
        a = {}
        caches = {}
        for j, kind in enumerate(stage.unit):
            x, c = sublayer_forward(
                kind, unit_params[f"u{j}"], x, cfg, positions, a,
                collect_cache=collect_cache, enc_out=enc_out,
            )
            if collect_cache:
                caches[f"u{j}"] = c
        extras = (jnp.asarray(a.get("router_aux", 0.0), jnp.float32),
                  jnp.asarray(a.get("router_z", 0.0), jnp.float32))
        return x, (caches if collect_cache else None, extras)

    body = jax.checkpoint(unit_body, policy=_remat_policy(cfg), static_argnums=()) \
        if cfg.remat != "none" else unit_body

    if stage.scan:
        x, (cache, extras) = jax.lax.scan(body, x, params)
        aux["router_aux"] = aux.get("router_aux", 0.0) + extras[0].sum()
        aux["router_z"] = aux.get("router_z", 0.0) + extras[1].sum()
        return x, cache
    caches = []
    for up in params:
        x, (c, extras) = body(x, up)
        aux["router_aux"] = aux.get("router_aux", 0.0) + extras[0]
        aux["router_z"] = aux.get("router_z", 0.0) + extras[1]
        caches.append(c)
    return x, (caches if collect_cache else None)


def stage_decode(
    stage: Stage,
    params: PyTree,
    x: Array,
    cfg: ModelConfig,
    cache: PyTree,
    pos: Array,
    aux: dict,
) -> tuple[Array, PyTree]:
    def unit_body(x, scanned):
        unit_params, unit_cache = scanned
        a = {}
        new_caches = {}
        for j, kind in enumerate(stage.unit):
            x, c = sublayer_decode(kind, unit_params[f"u{j}"], x, cfg, unit_cache[f"u{j}"], pos, a)
            new_caches[f"u{j}"] = c
        extras = (jnp.asarray(a.get("router_aux", 0.0), jnp.float32),
                  jnp.asarray(a.get("router_z", 0.0), jnp.float32))
        return x, (new_caches, extras)

    if stage.scan:
        x, (new_cache, extras) = jax.lax.scan(unit_body, x, (params, cache))
        return x, new_cache
    new_caches = []
    for up, uc in zip(params, cache):
        x, (c, _) = unit_body(x, (up, uc))
        new_caches.append(c)
    return x, new_caches


def init_stage_cache(
    stage: Stage, cfg: ModelConfig, batch: int, max_len: int, dtype
) -> PyTree:
    def unit_cache():
        return {f"u{j}": init_sublayer_cache(kind, cfg, batch, max_len, dtype)
                for j, kind in enumerate(stage.unit)}

    if stage.scan:
        one = unit_cache()
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (stage.n, *a.shape)).copy(), one)
    return [unit_cache() for _ in range(stage.n)]
