"""Shared model layers: norms, RoPE, embeddings, MLPs, chunked (flash-style)
attention.  All functions are pure (params-first), dtype-disciplined (params
may be f32/bf16; compute dtype from config; reductions in f32), and shaped to
shard well under GSPMD (see distributed/sharding.py for the axis rules)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) > 1 else shape[-1]
    scale = scale if scale is not None else fan_in**-0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def stack_init(key, n: int, init_fn):
    """Initialize n per-layer pytrees and stack leaves on a leading axis."""
    keys = jax.random.split(key, n)
    trees = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ---------------------------------------------------------------------------
# RMSNorm / LayerNorm
# ---------------------------------------------------------------------------


def rms_norm(x: Array, scale: Array, eps: float) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    nrm = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (nrm * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32)) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int) -> Array:
    """Whisper-style fixed sinusoidal table (seq, dim)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    ang = pos * div[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def sinusoidal_at(positions: Array, dim: int) -> Array:
    """Sinusoidal embedding at arbitrary integer positions (B, S) -> (B, S, dim)."""
    div = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    ang = positions.astype(jnp.float32)[..., None] * div
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_in": dense_init(k1, (d_model, d_ff), dtype),
        "w_gate": dense_init(k2, (d_model, d_ff), dtype),
        "w_out": dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp_swiglu(p: PyTree, x: Array) -> Array:
    dt = x.dtype
    h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * (x @ p["w_in"].astype(dt))
    return h @ p["w_out"].astype(dt)


def init_mlp_gelu(key, d_model: int, d_ff: int, dtype) -> PyTree:
    k1, k2 = jax.random.split(key)
    return {
        "w_in": dense_init(k1, (d_model, d_ff), dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": dense_init(k2, (d_ff, d_model), dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def mlp_gelu(p: PyTree, x: Array) -> Array:
    dt = x.dtype
    h = jax.nn.gelu(x @ p["w_in"].astype(dt) + p["b_in"].astype(dt))
    return h @ p["w_out"].astype(dt) + p["b_out"].astype(dt)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention — O(S) memory, pure JAX
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def chunked_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool,
    q_offset: Array | int = 0,
    kv_block: int = 1024,
) -> Array:
    """Online-softmax attention.

    q: (B, Sq, H, hd);  k, v: (B, Skv, KV, hd) with H % KV == 0.
    Never materializes (Sq, Skv): scans KV blocks carrying running
    (max, denom, accum) — the flash-attention recurrence.  ``q_offset`` is
    the absolute position of q[0] for causal masking (prefill = 0).
    """
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    groups = h // kv
    scale = hd**-0.5
    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, kv, groups, hd)

    n_blocks = -(-skv // kv_block)
    pad = n_blocks * kv_block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kf = k.astype(jnp.float32).reshape(b, n_blocks, kv_block, kv, hd)
    vf = v.astype(jnp.float32).reshape(b, n_blocks, kv_block, kv, hd)

    q_pos = (jnp.asarray(q_offset) + jnp.arange(sq))[None, :, None]  # (1,Sq,1)

    def body(carry, blk):
        # NOTE: the block index lives in the CARRY, not in scan xs — if the
        # mask depends only on xs, XLA hoists it out of the loop and
        # materializes the full (n_blocks, B, Sq, ..., blk) boolean mask
        # (O(S^2) bytes, gigabytes at 32k).  Carry-threading keeps it O(S).
        m, denom, acc, blk_idx = carry
        kb, vb = blk
        # scores: (B, Sq, KV, G, blk)
        s = jnp.einsum("bsvgh,bkvh->bsvgk", qf, kb)
        kv_pos = (blk_idx * kv_block + jnp.arange(kv_block))[None, None, :]
        mask = kv_pos <= q_pos if causal else (kv_pos < skv + jnp.zeros_like(q_pos))
        mask = mask & (kv_pos < skv)  # drop padding
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        denom = denom * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bsvgk,bkvh->bsvgh", p, vb)
        return (m_new, denom, acc, blk_idx + 1), None

    init = (
        jnp.full((b, sq, kv, groups), NEG_INF, jnp.float32),
        jnp.zeros((b, sq, kv, groups), jnp.float32),
        jnp.zeros((b, sq, kv, groups, hd), jnp.float32),
        jnp.int32(0),
    )
    (m, denom, acc, _), _ = jax.lax.scan(
        body, init, (kf.swapaxes(0, 1), vf.swapaxes(0, 1))
    )
    out = acc / jnp.maximum(denom, 1e-30)[..., None]
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def decode_attention(q: Array, k_cache: Array, v_cache: Array, cur_len: Array) -> Array:
    """Single-position attention against a (B, S, KV, hd) cache.

    q: (B, 1, H, hd). Positions >= cur_len are masked. O(S) compute/memory.
    """
    b, _, h, hd = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    groups = h // kv
    qf = (q.astype(jnp.float32) * hd**-0.5).reshape(b, kv, groups, hd)
    kf = k_cache.astype(jnp.float32)
    scores = jnp.einsum("bvgh,bkvh->bvgk", qf, kf)  # (B, KV, G, S)
    cur = cur_len[:, None] if jnp.ndim(cur_len) == 1 else cur_len
    mask = jnp.arange(s)[None, :] < cur  # (B or 1, S)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bvgk,bkvh->bvgh", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)


def embed(table: Array, tokens: Array, compute_dtype) -> Array:
    return table[tokens].astype(compute_dtype)


def unembed(table: Array, x: Array) -> Array:
    """Tied unembedding: logits in f32 for a stable softmax/loss."""
    return jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32), table.astype(jnp.float32))
