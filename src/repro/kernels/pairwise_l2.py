"""Tiled squared-L2 pairwise-distance Pallas kernel.

The retrieval hot loop (DBSCAN eps-graph, bucket bounds, bucket evaluation,
datastore scan) is dominated by ``(Q, D) x (N, D) -> (Q, N)`` distance
matrices.  On TPU this is an MXU matmul plus rank-1 norm updates:

    d2[i, j] = ||q_i||^2 + ||x_j||^2 - 2 <q_i, x_j>

Grid: (Q/bq, N/bn, D/bd) with accumulation over the contraction axis (last
grid dimension; same output block revisited, ``dimension_semantics``
marks it "arbitrary" on real TPU).  Per-step VMEM working set is
``bq*bd + bn*bd + bq*bn`` f32 — defaults (256, 256, 256) give 768 KB,
comfortably inside the ~16 MB v5e VMEM while keeping MXU tiles
128-aligned.

The int8 variant dequantizes the datastore tile in-register (per-row scale),
halving (vs bf16) or quartering (vs f32) the HBM traffic of a datastore
scan — the memory-roofline lever for decode-time retrieval.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _pairwise_kernel(q_ref, x_ref, o_ref):
    """One (bq, bn) output tile, accumulated over D-axis grid steps."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)  # (bq, bd)
    x = x_ref[...].astype(jnp.float32)  # (bn, bd)
    qq = jnp.sum(q * q, axis=1)  # (bq,)
    xx = jnp.sum(x * x, axis=1)  # (bn,)
    cross = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bn)
    o_ref[...] += qq[:, None] + xx[None, :] - 2.0 * cross


def _pairwise_int8_kernel(q_ref, x_ref, scale_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32) * scale_ref[...].astype(jnp.float32)[:, None]
    qq = jnp.sum(q * q, axis=1)
    xx = jnp.sum(x * x, axis=1)
    cross = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] += qq[:, None] + xx[None, :] - 2.0 * cross


def _pad_to(a: Array, axis: int, mult: int, value: float = 0.0) -> Array:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


@functools.partial(
    jax.jit, static_argnames=("bq", "bn", "bd", "interpret")
)
def pairwise_sq_l2_pallas(
    q: Array,
    x: Array,
    *,
    bq: int = 256,
    bn: int = 256,
    bd: int = 256,
    interpret: bool = False,
) -> Array:
    """(Q, D) x (N, D) -> (Q, N) squared L2 distances (f32)."""
    qn, d = q.shape
    n = x.shape[0]
    qp = _pad_to(q.astype(jnp.float32), 0, bq)
    qp = _pad_to(qp, 1, bd)
    xp = _pad_to(x.astype(jnp.float32), 0, bn)
    xp = _pad_to(xp, 1, bd)
    grid = (qp.shape[0] // bq, xp.shape[0] // bn, qp.shape[1] // bd)
    out = pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], xp.shape[0]), jnp.float32),
        interpret=interpret,
    )(qp, xp)
    return jnp.maximum(out[:qn, :n], 0.0)


@functools.partial(
    jax.jit, static_argnames=("bq", "bn", "bd", "interpret")
)
def pairwise_sq_l2_int8_pallas(
    q: Array,
    x_q: Array,
    scale: Array,
    *,
    bq: int = 256,
    bn: int = 256,
    bd: int = 256,
    interpret: bool = False,
) -> Array:
    """f32 queries vs int8 per-row-quantized datastore -> (Q, N) sq-L2."""
    qn, d = q.shape
    n = x_q.shape[0]
    qp = _pad_to(q.astype(jnp.float32), 0, bq)
    qp = _pad_to(qp, 1, bd)
    xp = _pad_to(x_q, 0, bn)
    xp = _pad_to(xp, 1, bd)
    sp = _pad_to(scale.astype(jnp.float32), 0, bn)
    grid = (qp.shape[0] // bq, xp.shape[0] // bn, qp.shape[1] // bd)
    out = pl.pallas_call(
        _pairwise_int8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], xp.shape[0]), jnp.float32),
        interpret=interpret,
    )(qp, xp, sp)
    return jnp.maximum(out[:qn, :n], 0.0)
