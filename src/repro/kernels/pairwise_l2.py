"""Tiled squared-L2 pairwise-distance Pallas kernel.

The retrieval hot loop (DBSCAN eps-graph, bucket bounds, bucket evaluation,
datastore scan) is dominated by ``(Q, D) x (N, D) -> (Q, N)`` distance
matrices.  On TPU this is an MXU matmul plus rank-1 norm updates:

    d2[i, j] = ||q_i||^2 + ||x_j||^2 - 2 <q_i, x_j>

Grid: (Q/bq, N/bn, D/bd) with accumulation over the contraction axis (last
grid dimension; same output block revisited, ``dimension_semantics``
marks it "arbitrary" on real TPU).  Per-step VMEM working set is
``bq*bd + bn*bd + bq*bn`` f32 — defaults (256, 256, 256) give 768 KB,
comfortably inside the ~16 MB v5e VMEM while keeping MXU tiles
128-aligned.

The int8 variant dequantizes the datastore tile in-register (per-row scale),
halving (vs bf16) or quartering (vs f32) the HBM traffic of a datastore
scan — the memory-roofline lever for decode-time retrieval.

The ``eps_*`` kernels below fuse DBSCAN's eps-neighbor-graph reductions
(core counting, min-label propagation, nearest-core border assignment) into
the same tiled distance stream: grid (Q/bq, N/bn) with D whole inside the
block (padded to 128) and the N axis sequential over a (bq, 1)-shaped
running output, so the per-query distance row is thresholded/reduced
in-register and the (Q, N) block never reaches HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _pairwise_kernel(q_ref, x_ref, o_ref):
    """One (bq, bn) output tile, accumulated over D-axis grid steps."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)  # (bq, bd)
    x = x_ref[...].astype(jnp.float32)  # (bn, bd)
    qq = jnp.sum(q * q, axis=1)  # (bq,)
    xx = jnp.sum(x * x, axis=1)  # (bn,)
    cross = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bn)
    o_ref[...] += qq[:, None] + xx[None, :] - 2.0 * cross


def _pairwise_int8_kernel(q_ref, x_ref, scale_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32) * scale_ref[...].astype(jnp.float32)[:, None]
    qq = jnp.sum(q * q, axis=1)
    xx = jnp.sum(x * x, axis=1)
    cross = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] += qq[:, None] + xx[None, :] - 2.0 * cross


def _pad_to(a: Array, axis: int, mult: int, value: float = 0.0) -> Array:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


@functools.partial(
    jax.jit, static_argnames=("bq", "bn", "bd", "interpret")
)
def pairwise_sq_l2_pallas(
    q: Array,
    x: Array,
    *,
    bq: int = 256,
    bn: int = 256,
    bd: int = 256,
    interpret: bool = False,
) -> Array:
    """(Q, D) x (N, D) -> (Q, N) squared L2 distances (f32)."""
    qn, d = q.shape
    n = x.shape[0]
    qp = _pad_to(q.astype(jnp.float32), 0, bq)
    qp = _pad_to(qp, 1, bd)
    xp = _pad_to(x.astype(jnp.float32), 0, bn)
    xp = _pad_to(xp, 1, bd)
    grid = (qp.shape[0] // bq, xp.shape[0] // bn, qp.shape[1] // bd)
    out = pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], xp.shape[0]), jnp.float32),
        interpret=interpret,
    )(qp, xp)
    return jnp.maximum(out[:qn, :n], 0.0)


# --- fused DBSCAN eps-graph reductions -------------------------------------
# Shared tile shape: q (bq, Dp), x (bn, Dp) with Dp the whole (128-padded)
# feature axis; each kernel reduces its (bq, bn) in-register distance tile
# straight into a (bq, 1) running output.  ``n_real`` masks the N padding.


def _tile_sq_l2(q_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    qq = jnp.sum(q * q, axis=1)
    xx = jnp.sum(x * x, axis=1)
    cross = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    return jnp.maximum(qq[:, None] + xx[None, :] - 2.0 * cross, 0.0)


def _eps_count_kernel(q_ref, x_ref, eps_ref, o_ref, *, bn, n_real):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d2 = _tile_sq_l2(q_ref, x_ref)
    gidx = j * bn + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    within = (d2 <= eps_ref[0, 0]) & (gidx < n_real)
    o_ref[...] += jnp.sum(within, axis=1, keepdims=True).astype(jnp.int32)


def _eps_min_label_kernel(q_ref, x_ref, lab_ref, core_ref, eps_ref, o_ref, *, bn, n_real):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, n_real)

    d2 = _tile_sq_l2(q_ref, x_ref)
    gidx = j * bn + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    adj = (
        (d2 <= eps_ref[0, 0]) & (core_ref[...] != 0)[None, :] & (gidx < n_real)
    )
    cand = jnp.where(adj, lab_ref[...][None, :], jnp.int32(n_real))
    o_ref[...] = jnp.minimum(o_ref[...], jnp.min(cand, axis=1, keepdims=True))


def _eps_nearest_core_kernel(
    q_ref, x_ref, lab_ref, core_ref, o_d_ref, o_lab_ref, *, bn, n_real
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_d_ref[...] = jnp.full_like(o_d_ref, jnp.inf)
        o_lab_ref[...] = jnp.full_like(o_lab_ref, n_real)

    d2 = _tile_sq_l2(q_ref, x_ref)
    gidx = j * bn + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    d2 = jnp.where((core_ref[...] != 0)[None, :] & (gidx < n_real), d2, jnp.inf)
    a = jnp.argmin(d2, axis=1)  # first-index-wins inside the tile
    dmin = jnp.take_along_axis(d2, a[:, None], axis=1)  # (bq, 1)
    lab = lab_ref[...][a][:, None]
    # strict <: the earliest tile keeps ties, matching a full-row argmin
    better = dmin < o_d_ref[...]
    o_lab_ref[...] = jnp.where(better, lab, o_lab_ref[...])
    o_d_ref[...] = jnp.where(better, dmin, o_d_ref[...])


def _eps_operands(q, x, bq, bn):
    qp = _pad_to(q.astype(jnp.float32), 0, bq)
    qp = _pad_to(qp, 1, 128)
    xp = _pad_to(x.astype(jnp.float32), 0, bn)
    xp = _pad_to(xp, 1, 128)
    grid = (qp.shape[0] // bq, xp.shape[0] // bn)
    qspec = pl.BlockSpec((bq, qp.shape[1]), lambda i, j: (i, 0))
    xspec = pl.BlockSpec((bn, xp.shape[1]), lambda i, j: (j, 0))
    nspec = pl.BlockSpec((bn,), lambda i, j: (j,))  # per-row N-axis operands
    espec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))  # replicated scalar
    ospec = pl.BlockSpec((bq, 1), lambda i, j: (i, 0))
    return qp, xp, grid, qspec, xspec, nspec, espec, ospec


@functools.partial(jax.jit, static_argnames=("bq", "bn", "interpret"))
def eps_count_pallas(
    q: Array,
    x: Array,
    eps_sq: Array,
    *,
    bq: int = 256,
    bn: int = 256,
    interpret: bool = False,
) -> Array:
    """(Q,) i32: per query, |{j : d2(q, x_j) <= eps_sq}|."""
    qn, n = q.shape[0], x.shape[0]
    qp, xp, grid, qspec, xspec, _, espec, ospec = _eps_operands(q, x, bq, bn)
    out = pl.pallas_call(
        functools.partial(_eps_count_kernel, bn=bn, n_real=n),
        grid=grid,
        in_specs=[qspec, xspec, espec],
        out_specs=ospec,
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], 1), jnp.int32),
        interpret=interpret,
    )(qp, xp, jnp.asarray(eps_sq, jnp.float32).reshape(1, 1))
    return out[:qn, 0]


@functools.partial(jax.jit, static_argnames=("bq", "bn", "interpret"))
def eps_min_label_pallas(
    q: Array,
    x: Array,
    labels: Array,
    core: Array,
    eps_sq: Array,
    *,
    bq: int = 256,
    bn: int = 256,
    interpret: bool = False,
) -> Array:
    """(Q,) i32: min label over eps-neighbors that are core; N (= len(x))
    when a query has none — DBSCAN's sentinel convention."""
    qn, n = q.shape[0], x.shape[0]
    qp, xp, grid, qspec, xspec, nspec, espec, ospec = _eps_operands(q, x, bq, bn)
    out = pl.pallas_call(
        functools.partial(_eps_min_label_kernel, bn=bn, n_real=n),
        grid=grid,
        in_specs=[qspec, xspec, nspec, nspec, espec],
        out_specs=ospec,
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], 1), jnp.int32),
        interpret=interpret,
    )(
        qp, xp,
        _pad_to(labels.astype(jnp.int32), 0, bn),
        _pad_to(core.astype(jnp.int32), 0, bn),
        jnp.asarray(eps_sq, jnp.float32).reshape(1, 1),
    )
    return out[:qn, 0]


@functools.partial(jax.jit, static_argnames=("bq", "bn", "interpret"))
def eps_nearest_core_pallas(
    q: Array,
    x: Array,
    labels: Array,
    core: Array,
    *,
    bq: int = 256,
    bn: int = 256,
    interpret: bool = False,
) -> tuple[Array, Array]:
    """Per query: (d2 to the nearest core point, that point's label) —
    (+inf, N) when no core point exists.  First-index tie-breaking matches
    ``jnp.argmin`` over the masked full row (the jnp oracle)."""
    qn, n = q.shape[0], x.shape[0]
    qp, xp, grid, qspec, xspec, nspec, _, ospec = _eps_operands(q, x, bq, bn)
    dmin, lab = pl.pallas_call(
        functools.partial(_eps_nearest_core_kernel, bn=bn, n_real=n),
        grid=grid,
        in_specs=[qspec, xspec, nspec, nspec],
        out_specs=[ospec, ospec],
        out_shape=[
            jax.ShapeDtypeStruct((qp.shape[0], 1), jnp.float32),
            jax.ShapeDtypeStruct((qp.shape[0], 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        qp, xp,
        _pad_to(labels.astype(jnp.int32), 0, bn),
        _pad_to(core.astype(jnp.int32), 0, bn),
    )
    return dmin[:qn, 0], lab[:qn, 0]


@functools.partial(
    jax.jit, static_argnames=("bq", "bn", "bd", "interpret")
)
def pairwise_sq_l2_int8_pallas(
    q: Array,
    x_q: Array,
    scale: Array,
    *,
    bq: int = 256,
    bn: int = 256,
    bd: int = 256,
    interpret: bool = False,
) -> Array:
    """f32 queries vs int8 per-row-quantized datastore -> (Q, N) sq-L2."""
    qn, d = q.shape
    n = x_q.shape[0]
    qp = _pad_to(q.astype(jnp.float32), 0, bq)
    qp = _pad_to(qp, 1, bd)
    xp = _pad_to(x_q, 0, bn)
    xp = _pad_to(xp, 1, bd)
    sp = _pad_to(scale.astype(jnp.float32), 0, bn)
    grid = (qp.shape[0] // bq, xp.shape[0] // bn, qp.shape[1] // bd)
    out = pl.pallas_call(
        _pairwise_int8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], xp.shape[0]), jnp.float32),
        interpret=interpret,
    )(qp, xp, sp)
    return jnp.maximum(out[:qn, :n], 0.0)
