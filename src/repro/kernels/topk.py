"""Fused streaming distance + top-k Pallas kernel.

For decode-time retrieval (kNN-LM) the naive two-pass plan

    d2 = pairwise(q, datastore)   # (Q, N) materialized in HBM
    topk(d2, k)                   # second HBM pass

writes and re-reads an (Q, N) f32 matrix.  At datastore shard sizes of
10^6+ rows this is pure memory-roofline waste.  This kernel keeps the
running per-query top-k (values + global indices) resident in the output
VMEM blocks while streaming datastore tiles through the MXU, so the (Q, N)
matrix never exists.

Grid: (Q/bq, N/bn); the N axis is sequential (accumulation over the same
output block).  D is kept whole inside the block (padded to 128): retrieval
key dims (<= 8K) fit VMEM comfortably at bq = bn = 256.

Top-k maintenance: per N-tile, iteratively extract the k smallest of
[running top-k | tile distances] (k is small and static — k extraction
steps of a (bq, k + bn) min/argmin).  Indices are tracked through the same
selection.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _knn_topk_kernel(q_ref, x_ref, o_val_ref, o_idx_ref, *, k: int, bn: int, n_real: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_val_ref[...] = jnp.full_like(o_val_ref, jnp.inf)
        o_idx_ref[...] = jnp.full_like(o_idx_ref, -1)

    q = q_ref[...].astype(jnp.float32)  # (bq, D)
    x = x_ref[...].astype(jnp.float32)  # (bn, D)
    qq = jnp.sum(q * q, axis=1)
    xx = jnp.sum(x * x, axis=1)
    cross = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = jnp.maximum(qq[:, None] + xx[None, :] - 2.0 * cross, 0.0)  # (bq, bn)
    gidx = j * bn + jax.lax.broadcasted_iota(jnp.int32, (d2.shape[0], bn), 1)
    d2 = jnp.where(gidx < n_real, d2, jnp.inf)

    vals = jnp.concatenate([o_val_ref[...], d2], axis=1)  # (bq, k+bn)
    idxs = jnp.concatenate([o_idx_ref[...], gidx], axis=1)
    new_vals = []
    new_idxs = []
    for _ in range(k):
        m = jnp.min(vals, axis=1)
        a = jnp.argmin(vals, axis=1)
        new_vals.append(m)
        new_idxs.append(jnp.take_along_axis(idxs, a[:, None], axis=1)[:, 0])
        vals = jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1) == a[:, None],
            jnp.inf,
            vals,
        )
    o_val_ref[...] = jnp.stack(new_vals, axis=1)
    o_idx_ref[...] = jnp.stack(new_idxs, axis=1)


def _pad_to(a: Array, axis: int, mult: int) -> Array:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("k", "bq", "bn", "interpret"))
def knn_topk_pallas(
    q: Array,
    x: Array,
    *,
    k: int,
    bq: int = 256,
    bn: int = 256,
    interpret: bool = False,
) -> tuple[Array, Array]:
    """k smallest squared-L2 distances of each query against the datastore.

    Returns (values (Q, k) ascending, indices (Q, k)); indices are -1 / inf
    when the datastore has fewer than k rows.
    """
    qn = q.shape[0]
    n = x.shape[0]
    qp = _pad_to(q.astype(jnp.float32), 0, bq)
    qp = _pad_to(qp, 1, 128)
    xp = _pad_to(x.astype(jnp.float32), 0, bn)
    xp = _pad_to(xp, 1, 128)
    grid = (qp.shape[0] // bq, xp.shape[0] // bn)
    kernel = functools.partial(_knn_topk_kernel, k=k, bn=bn, n_real=n)
    vals, idxs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, qp.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, xp.shape[1]), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((qp.shape[0], k), jnp.int32),
        ],
        interpret=interpret,
    )(qp, xp)
    return vals[:qn], idxs[:qn]
