"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel in kernels/ is validated against these references over
shape/dtype sweeps in tests/test_kernels_*.py (interpret mode on CPU,
compiled on real TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def pairwise_sq_l2_ref(q: Array, x: Array) -> Array:
    """(Q, D) x (N, D) -> (Q, N) squared L2, via the MXU-friendly expansion."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    qq = jnp.sum(q * q, axis=-1)[:, None]
    xx = jnp.sum(x * x, axis=-1)[None, :]
    return jnp.maximum(qq + xx - 2.0 * (q @ x.T), 0.0)


def eps_count_ref(q: Array, x: Array, eps_sq: Array) -> Array:
    """(Q,) i32 eps-neighbor counts — DBSCAN's core test."""
    d2 = pairwise_sq_l2_ref(q, x)
    return jnp.sum(d2 <= eps_sq, axis=1).astype(jnp.int32)


def eps_min_label_ref(
    q: Array, x: Array, labels: Array, core: Array, eps_sq: Array
) -> Array:
    """(Q,) i32 min label over core eps-neighbors; N (sentinel) if none."""
    d2 = pairwise_sq_l2_ref(q, x)
    adj = (d2 <= eps_sq) & (core != 0)[None, :]
    sentinel = jnp.int32(x.shape[0])
    return jnp.min(jnp.where(adj, labels[None, :].astype(jnp.int32), sentinel), axis=1)


def eps_nearest_core_ref(
    q: Array, x: Array, labels: Array, core: Array
) -> tuple[Array, Array]:
    """Per query: (d2 to nearest core point, its label); (+inf, N) if none."""
    d2 = pairwise_sq_l2_ref(q, x)
    d2 = jnp.where((core != 0)[None, :], d2, jnp.inf)
    j = jnp.argmin(d2, axis=1)
    dmin = jnp.take_along_axis(d2, j[:, None], axis=1)[:, 0]
    lab = jnp.where(
        jnp.isinf(dmin), jnp.int32(x.shape[0]), labels.astype(jnp.int32)[j]
    )
    return dmin, lab


def knn_topk_ref(q: Array, x: Array, k: int) -> tuple[Array, Array]:
    """Exact k smallest squared-L2 distances + indices: (Q, k), (Q, k)."""
    d2 = pairwise_sq_l2_ref(q, x)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx


def masked_knn_topk_ref(q: Array, x: Array, mask: Array, k: int) -> tuple[Array, Array]:
    """As knn_topk_ref but positions with mask==False excluded (dist=+inf)."""
    d2 = pairwise_sq_l2_ref(q, x)
    d2 = jnp.where(mask[None, :], d2, jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx


def bucket_scan_topk_ref(
    q: Array,
    bucket_x: Array,
    bucket_ids: Array,
    bsel: Array,
    act: Array,
    top_d: Array,
    top_i: Array,
    scale: Array | None = None,
) -> tuple[Array, Array]:
    """One forest-scan step: gather selected buckets, distance, top-k merge.

    q (Q, D); bucket_x (NB, C, D) f32 or int8 (then ``scale`` (NB, C) holds
    per-member dequant scales); bsel/act (Q, beam); top_d/top_i (Q, kk) the
    running per-query top-k (squared distances ascending, object ids).
    Members with id < 0 (padding) and buckets with act == False contribute
    nothing.  Returns the merged (top_d, top_i).
    """
    qn, kk = top_d.shape
    q = q.astype(jnp.float32)
    bx = bucket_x[bsel]  # (Q, beam, C, D)
    if scale is not None:
        bx = bx.astype(jnp.float32) * scale[bsel][..., None].astype(jnp.float32)
    else:
        bx = bx.astype(jnp.float32)
    bids = bucket_ids[bsel]  # (Q, beam, C)
    live = (bids >= 0) & act[:, :, None]
    d2 = (
        jnp.sum(q * q, axis=-1)[:, None, None]
        + jnp.sum(bx * bx, axis=-1)
        - 2.0 * jnp.einsum("qbcd,qd->qbc", bx, q)
    )
    d2 = jnp.where(live, jnp.maximum(d2, 0.0), jnp.inf)
    cand_d = d2.reshape(qn, -1)
    cand_i = jnp.where(live, bids, -1).reshape(qn, -1)
    merged_d = jnp.concatenate([top_d, cand_d], axis=1)
    merged_i = jnp.concatenate([top_i, cand_i], axis=1)
    neg, pos = jax.lax.top_k(-merged_d, kk)
    return -neg, jnp.take_along_axis(merged_i, pos, axis=1)


def pairwise_sq_l2_int8_ref(q: Array, x_q: Array, scale: Array) -> Array:
    """Quantized-datastore distances: x stored int8 with per-row scales.

    Dequantized row j is ``x_q[j] * scale[j]``; distances are computed against
    the f32 queries.  (ADC-style retrieval; beyond-paper optimization.)
    """
    x = x_q.astype(jnp.float32) * scale[:, None].astype(jnp.float32)
    return pairwise_sq_l2_ref(q, x)
