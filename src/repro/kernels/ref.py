"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel in kernels/ is validated against these references over
shape/dtype sweeps in tests/test_kernels_*.py (interpret mode on CPU,
compiled on real TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def pairwise_sq_l2_ref(q: Array, x: Array) -> Array:
    """(Q, D) x (N, D) -> (Q, N) squared L2, via the MXU-friendly expansion."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    qq = jnp.sum(q * q, axis=-1)[:, None]
    xx = jnp.sum(x * x, axis=-1)[None, :]
    return jnp.maximum(qq + xx - 2.0 * (q @ x.T), 0.0)


def knn_topk_ref(q: Array, x: Array, k: int) -> tuple[Array, Array]:
    """Exact k smallest squared-L2 distances + indices: (Q, k), (Q, k)."""
    d2 = pairwise_sq_l2_ref(q, x)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx


def masked_knn_topk_ref(q: Array, x: Array, mask: Array, k: int) -> tuple[Array, Array]:
    """As knn_topk_ref but positions with mask==False excluded (dist=+inf)."""
    d2 = pairwise_sq_l2_ref(q, x)
    d2 = jnp.where(mask[None, :], d2, jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx


def pairwise_sq_l2_int8_ref(q: Array, x_q: Array, scale: Array) -> Array:
    """Quantized-datastore distances: x stored int8 with per-row scales.

    Dequantized row j is ``x_q[j] * scale[j]``; distances are computed against
    the f32 queries.  (ADC-style retrieval; beyond-paper optimization.)
    """
    x = x_q.astype(jnp.float32) * scale[:, None].astype(jnp.float32)
    return pairwise_sq_l2_ref(q, x)
