"""Pallas TPU kernels for the retrieval hot spots the paper optimizes:
pairwise distance matrices (construction + search) and the fused streaming
distance+top-k datastore scan (decode-time kNN-LM retrieval).

Layout per kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
dispatching wrapper), ref.py (pure-jnp oracle used in allclose sweeps).
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
