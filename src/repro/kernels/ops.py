"""Public jit'd wrappers for the Pallas kernels.

Dispatch policy:
* On TPU: compiled Pallas kernels with MXU-aligned default tiles.
* Elsewhere (this container is CPU): ``interpret=True`` executes the kernel
  body in Python for correctness validation, but is slow — so small shapes
  and non-TPU hot paths route to the jnp reference (identical math; the
  kernels are validated against it in tests/test_kernels_pairwise.py).

Set ``repro_kernels_force_pallas`` (env REPRO_FORCE_PALLAS=1) to force the
Pallas path everywhere — used by the kernel test sweeps.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.pairwise_l2 import (
    pairwise_sq_l2_int8_pallas,
    pairwise_sq_l2_pallas,
)
from repro.kernels.topk import knn_topk_pallas

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _force_pallas() -> bool:
    return os.environ.get("REPRO_FORCE_PALLAS", "0") == "1"


def pairwise_sq_l2(q: Array, x: Array) -> Array:
    """(Q, D) x (N, D) -> (Q, N) squared L2 distances."""
    if _on_tpu():
        return pairwise_sq_l2_pallas(q, x)
    if _force_pallas():
        return pairwise_sq_l2_pallas(q, x, bq=64, bn=64, bd=64, interpret=True)
    return ref.pairwise_sq_l2_ref(q, x)


def pairwise_sq_l2_int8(q: Array, x_q: Array, scale: Array) -> Array:
    """f32 queries vs int8 per-row-quantized datastore."""
    if _on_tpu():
        return pairwise_sq_l2_int8_pallas(q, x_q, scale)
    if _force_pallas():
        return pairwise_sq_l2_int8_pallas(q, x_q, scale, bq=64, bn=64, bd=64, interpret=True)
    return ref.pairwise_sq_l2_int8_ref(q, x_q, scale)


def knn_topk(q: Array, x: Array, *, k: int) -> tuple[Array, Array]:
    """Fused streaming distance + top-k (values ascending, indices)."""
    if _on_tpu():
        return knn_topk_pallas(q, x, k=k)
    if _force_pallas():
        return knn_topk_pallas(q, x, k=k, bq=32, bn=64, interpret=True)
    return ref.knn_topk_ref(q, x, k)


def quantize_datastore(x: Array) -> tuple[Array, Array]:
    """Symmetric per-row int8 quantization for the retrieval datastore."""
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1), 1e-8) / 127.0
    xq = jnp.clip(jnp.round(x / scale[:, None]), -127, 127).astype(jnp.int8)
    return xq, scale.astype(jnp.float32)
