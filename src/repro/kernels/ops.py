"""Public jit'd wrappers for the Pallas kernels — the ONE dispatch layer.

Every distance computed on the serving/search path (index routing, bucket
lower bounds, bucket member scan, flat datastore scan) goes through this
module, so backend tuning happens in exactly one place.

Dispatch policy (each wrapper below):
* On TPU: compiled Pallas kernels with MXU-aligned default tiles.
* ``REPRO_FORCE_PALLAS=1`` in the environment: the Pallas kernel body runs
  under ``interpret=True`` everywhere (slow; Python-interpreted) — this is
  how the kernel test sweeps validate kernel math off-TPU.
* Otherwise (e.g. this container's CPU): the pure-jnp reference from
  ``kernels/ref.py`` — identical math, validated against the kernels in
  tests/test_kernels_pairwise.py and tests/test_bucket_scan.py.

Datastore storage knobs:
* ``quantize_datastore`` produces the symmetric per-row int8 layout; the
  ``*_int8`` kernels and the ``scale=`` argument of ``bucket_scan_topk``
  dequantize in-register (4x less HBM traffic than f32 on the scan).
  The forest equivalent is ``core.knn.device_forest(..., quantize=True)``,
  which stores ``bucket_x`` int8 with per-member scales.

Streaming delta buckets (repro.stream): the per-index append buffers are
scanned by the SAME fused bucket-scan kernel — a delta buffer is just a
bucket datastore of shape (I, CAP_d, D) with -1-id padding, so
``bucket_scan_prepad`` + ``bucket_scan_topk`` (alias ``delta_scan_topk``)
cover the delta phase of ``core.knn.knn_search`` with no new kernel.
Delta members always scan f32 (``scale=None``) even when the main forest
is int8-quantized: freshly streamed rows have no quantization pass yet —
they pick up int8 storage when maintenance absorbs them into the tree.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bucket_scan import bucket_scan_topk_pallas, prepad_buckets
from repro.kernels.pairwise_l2 import (
    eps_count_pallas,
    eps_min_label_pallas,
    eps_nearest_core_pallas,
    pairwise_sq_l2_int8_pallas,
    pairwise_sq_l2_pallas,
)
from repro.kernels.topk import knn_topk_pallas

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _force_pallas() -> bool:
    return os.environ.get("REPRO_FORCE_PALLAS", "0") == "1"


def pairwise_sq_l2(q: Array, x: Array) -> Array:
    """(Q, D) x (N, D) -> (Q, N) squared L2 distances."""
    if _on_tpu():
        return pairwise_sq_l2_pallas(q, x)
    if _force_pallas():
        return pairwise_sq_l2_pallas(q, x, bq=64, bn=64, bd=64, interpret=True)
    return ref.pairwise_sq_l2_ref(q, x)


def pairwise_sq_l2_int8(q: Array, x_q: Array, scale: Array) -> Array:
    """f32 queries vs int8 per-row-quantized datastore."""
    if _on_tpu():
        return pairwise_sq_l2_int8_pallas(q, x_q, scale)
    if _force_pallas():
        return pairwise_sq_l2_int8_pallas(q, x_q, scale, bq=64, bn=64, bd=64, interpret=True)
    return ref.pairwise_sq_l2_int8_ref(q, x_q, scale)


def eps_count(q: Array, x: Array, eps_sq: Array) -> Array:
    """DBSCAN core test: per-query count of eps-neighbors (thresholding
    fused into the distance tiles — no (Q, N) block reaches HBM)."""
    if _on_tpu():
        return eps_count_pallas(q, x, eps_sq)
    if _force_pallas():
        return eps_count_pallas(q, x, eps_sq, bq=64, bn=64, interpret=True)
    return ref.eps_count_ref(q, x, eps_sq)


def eps_min_label(
    q: Array, x: Array, labels: Array, core: Array, eps_sq: Array
) -> Array:
    """DBSCAN label sweep: min label over core eps-neighbors (N if none)."""
    if _on_tpu():
        return eps_min_label_pallas(q, x, labels, core, eps_sq)
    if _force_pallas():
        return eps_min_label_pallas(
            q, x, labels, core, eps_sq, bq=64, bn=64, interpret=True
        )
    return ref.eps_min_label_ref(q, x, labels, core, eps_sq)


def eps_nearest_core(
    q: Array, x: Array, labels: Array, core: Array
) -> tuple[Array, Array]:
    """DBSCAN border pass: (d2, label) of each query's nearest core point."""
    if _on_tpu():
        return eps_nearest_core_pallas(q, x, labels, core)
    if _force_pallas():
        return eps_nearest_core_pallas(
            q, x, labels, core, bq=64, bn=64, interpret=True
        )
    return ref.eps_nearest_core_ref(q, x, labels, core)


def knn_topk(q: Array, x: Array, *, k: int) -> tuple[Array, Array]:
    """Fused streaming distance + top-k (values ascending, indices)."""
    if _on_tpu():
        return knn_topk_pallas(q, x, k=k)
    if _force_pallas():
        return knn_topk_pallas(q, x, k=k, bq=32, bn=64, interpret=True)
    return ref.knn_topk_ref(q, x, k)


def bucket_scan_prepad(
    bucket_x: Array, bucket_ids: Array, scale: Array | None = None
) -> tuple[Array, Array, Array | None]:
    """Apply ``bucket_scan_topk``'s padding policy once, at upload time.

    Looping callers (core/knn.py's while-loop) pre-pad the datastore-sized
    operands here so the defensive per-step pads inside the kernel wrapper
    are no-ops instead of a full-datastore copy per step.  Identity on the
    jnp-reference path (no tiling there).
    """
    if _on_tpu():
        return prepad_buckets(bucket_x, bucket_ids, scale, interpret=False)
    if _force_pallas():
        return prepad_buckets(bucket_x, bucket_ids, scale, interpret=True)
    return bucket_x, bucket_ids, scale


def bucket_scan_topk(
    q: Array,
    bucket_x: Array,
    bucket_ids: Array,
    bsel: Array,
    act: Array,
    top_d: Array,
    top_i: Array,
    scale: Array | None = None,
) -> tuple[Array, Array]:
    """Fused forest-scan step: gather ``bsel`` buckets, distances, top-k merge.

    See kernels/bucket_scan.py for the kernel and kernels/ref.py for the
    oracle.  ``scale`` enables the int8 bucket storage path.
    """
    if _on_tpu():
        return bucket_scan_topk_pallas(q, bucket_x, bucket_ids, bsel, act, top_d, top_i, scale)
    if _force_pallas():
        return bucket_scan_topk_pallas(
            q, bucket_x, bucket_ids, bsel, act, top_d, top_i, scale, interpret=True
        )
    return ref.bucket_scan_topk_ref(q, bucket_x, bucket_ids, bsel, act, top_d, top_i, scale)


# The streaming delta phase dispatches through the identical kernel step —
# named so call sites (core/knn.py STEP 2c) read as what they scan.
delta_scan_topk = bucket_scan_topk


def quantize_datastore(x: Array) -> tuple[Array, Array]:
    """Symmetric per-row int8 quantization for the retrieval datastore."""
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1), 1e-8) / 127.0
    xq = jnp.clip(jnp.round(x / scale[:, None]), -127, 127).astype(jnp.int8)
    return xq, scale.astype(jnp.float32)
