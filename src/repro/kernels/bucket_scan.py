"""Fused bucket gather + squared-L2 + running top-k merge Pallas kernel.

The forest search hot loop (core/knn.py STEP 2b) evaluates, per while-loop
step, the next ``beam`` buckets of every query: gather the selected bucket
members, compute query->member distances, and merge them into the running
per-query top-k.  The jnp formulation materializes a ``(Q, beam, C, D)``
gather plus a ``(Q, kk + beam*C)`` merge buffer through HBM on *every* step
— at production bucket capacities that is the entire search cost.

This kernel fuses the three stages in VMEM:

* the bucket ids selected for this step (``bsel``, (Q, beam)) and the
  per-(query, bucket) active mask (``act``) ride in as **scalar-prefetch**
  operands, so the grid's DMA engine gathers exactly the ``(C, D)`` bucket
  tiles the step needs straight from the flattened ``bucket_x`` in HBM —
  the (Q, beam, C, D) intermediate never exists;
* distances are one MXU ``(1, D) x (C, D)^T`` contraction per
  (query, bucket) program;
* the running ``(1, kk)`` top-k (values + global object ids) stays resident
  in the output VMEM block across the sequential ``beam`` axis, maintained
  with the same k-step min-extraction as kernels/topk.py.

Grid: ``(Q, beam)`` with beam innermost (sequential accumulation into the
same output block, exactly the revisiting pattern of topk.py's N axis).

An int8 variant dequantizes the gathered bucket tile in-register against
per-member scales (``ops.quantize_datastore`` layout), quartering the HBM
traffic of the member gather — the memory-roofline lever for serving.

Validated against ``ref.bucket_scan_topk_ref`` in tests/test_bucket_scan.py
(interpret mode on CPU, compiled on real TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _scan_kernel(
    bsel_ref,  # scalar prefetch (Q, beam) i32
    act_ref,  # scalar prefetch (Q, beam) i32
    q_ref,  # (1, Dp)
    x_ref,  # (1, Cp, Dp) gathered bucket tile (f32 or int8)
    ids_ref,  # (1, Cp) i32, -1 pad
    scale_ref,  # (1, Cp) f32 per-member dequant scales (ones when f32)
    top_d_ref,  # (1, kkp) incoming running top-k values
    top_i_ref,  # (1, kkp) incoming running top-k ids
    o_val_ref,  # (1, kkp) out
    o_idx_ref,  # (1, kkp) out
    *,
    kk: int,
):
    qi = pl.program_id(0)
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        o_val_ref[...] = top_d_ref[...]
        o_idx_ref[...] = top_i_ref[...]

    qv = q_ref[...].astype(jnp.float32)  # (1, Dp)
    x = x_ref[0].astype(jnp.float32) * scale_ref[...].astype(jnp.float32).T  # (Cp, Dp)
    ids = ids_ref[...]  # (1, Cp)
    qq = jnp.sum(qv * qv, axis=1)  # (1,)
    xx = jnp.sum(x * x, axis=1)  # (Cp,)
    cross = jax.lax.dot_general(
        qv, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (1, Cp)
    d2 = jnp.maximum(qq[:, None] + xx[None, :] - 2.0 * cross, 0.0)  # (1, Cp)
    live = (ids >= 0) & (act_ref[qi, b] > 0)
    d2 = jnp.where(live, d2, jnp.inf)
    cand_i = jnp.where(live, ids, -1)

    vals = jnp.concatenate([o_val_ref[...], d2], axis=1)  # (1, kkp + Cp)
    idxs = jnp.concatenate([o_idx_ref[...], cand_i], axis=1)
    kkp = o_val_ref.shape[1]
    new_vals = []
    new_idxs = []
    for _ in range(kk):
        m = jnp.min(vals, axis=1)
        a = jnp.argmin(vals, axis=1)
        new_vals.append(m)
        # An inf extraction means the pool ran dry: argmin then points at an
        # arbitrary (already-extracted) slot whose id must not be re-emitted.
        # Distances are inf only for masked/padded candidates (id -1), so
        # inf => -1 matches the oracle's contract.
        picked = jnp.take_along_axis(idxs, a[:, None], axis=1)[:, 0]
        new_idxs.append(jnp.where(jnp.isinf(m), -1, picked))
        vals = jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1) == a[:, None],
            jnp.inf,
            vals,
        )
    for _ in range(kkp - kk):  # alignment tail stays empty
        new_vals.append(jnp.full((1,), jnp.inf, jnp.float32))
        new_idxs.append(jnp.full((1,), -1, jnp.int32))
    o_val_ref[...] = jnp.stack(new_vals, axis=1)
    o_idx_ref[...] = jnp.stack(new_idxs, axis=1)


def _pad_to(a: Array, axis: int, mult: int, value=0) -> Array:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def _pad_multiples(interpret: bool) -> tuple[int, int]:
    """(lane, C-axis) padding multiples for the kernel's blocks.

    C is the sublane axis of the (1, C, D) member blocks AND the lane axis
    of the (1, C) id/scale blocks, so compiled mode gives it the full lane
    multiple (which also satisfies the int8 sublane-32 requirement).  The
    interpreter has no tiling constraints; small multiples keep the CPU
    test sweeps exercising the padding paths the compiled kernel relies on.
    """
    return (8, 2) if interpret else (128, 128)


def prepad_buckets(
    bucket_x: Array,
    bucket_ids: Array,
    scale: Array | None = None,
    *,
    interpret: bool = False,
) -> tuple[Array, Array, Array | None]:
    """Pad the per-datastore operands to the kernel's tile multiples ONCE.

    ``bucket_scan_topk_pallas`` pads defensively on every call; done inside
    a search while-loop that would copy the whole datastore each step, so
    callers that loop (core/knn.py) pre-pad at upload time and the per-step
    pads become no-ops.
    """
    lane, cmult = _pad_multiples(interpret)
    xp = _pad_to(_pad_to(bucket_x, 2, lane), 1, cmult)
    idsp = _pad_to(bucket_ids, 1, cmult, value=-1)
    scalep = None if scale is None else _pad_to(scale.astype(jnp.float32), 1, cmult)
    return xp, idsp, scalep


@functools.partial(jax.jit, static_argnames=("interpret",))
def bucket_scan_topk_pallas(
    q: Array,  # (Q, D) f32
    bucket_x: Array,  # (NB, C, D) f32 or int8
    bucket_ids: Array,  # (NB, C) i32, -1 pad
    bsel: Array,  # (Q, beam) i32 bucket selection for this step
    act: Array,  # (Q, beam) bool/int — bucket still inside the bound
    top_d: Array,  # (Q, kk) running top-k squared distances (ascending)
    top_i: Array,  # (Q, kk) running top-k object ids
    scale: Array | None = None,  # (NB, C) f32 when bucket_x is int8
    *,
    interpret: bool = False,
) -> tuple[Array, Array]:
    """One fused scan step; returns the merged (top_d, top_i), both (Q, kk)."""
    qn, _ = q.shape
    nb, cap, _ = bucket_x.shape
    beam = bsel.shape[1]
    kk = top_d.shape[1]

    lane, cmult = _pad_multiples(interpret)
    qp = _pad_to(q.astype(jnp.float32), 1, lane)
    xp = _pad_to(_pad_to(bucket_x, 2, lane), 1, cmult)
    idsp = _pad_to(bucket_ids, 1, cmult, value=-1)
    if scale is None:
        scalep = jnp.ones(idsp.shape, jnp.float32)
    else:
        scalep = _pad_to(scale.astype(jnp.float32), 1, cmult)
    kkp = kk + (-kk) % lane
    top_dp = _pad_to(top_d.astype(jnp.float32), 1, lane, value=jnp.inf)
    top_ip = _pad_to(top_i.astype(jnp.int32), 1, lane, value=-1)

    cp, dp = xp.shape[1], xp.shape[2]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(qn, beam),
        in_specs=[
            pl.BlockSpec((1, dp), lambda i, j, bsel, act: (i, 0)),
            pl.BlockSpec((1, cp, dp), lambda i, j, bsel, act: (bsel[i, j], 0, 0)),
            pl.BlockSpec((1, cp), lambda i, j, bsel, act: (bsel[i, j], 0)),
            pl.BlockSpec((1, cp), lambda i, j, bsel, act: (bsel[i, j], 0)),
            pl.BlockSpec((1, kkp), lambda i, j, bsel, act: (i, 0)),
            pl.BlockSpec((1, kkp), lambda i, j, bsel, act: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, kkp), lambda i, j, bsel, act: (i, 0)),
            pl.BlockSpec((1, kkp), lambda i, j, bsel, act: (i, 0)),
        ],
    )
    vals, idxs = pl.pallas_call(
        functools.partial(_scan_kernel, kk=kk),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qn, kkp), jnp.float32),
            jax.ShapeDtypeStruct((qn, kkp), jnp.int32),
        ],
        interpret=interpret,
    )(
        bsel.astype(jnp.int32),
        act.astype(jnp.int32),
        qp,
        xp,
        idsp,
        scalep,
        top_dp,
        top_ip,
    )
    return vals[:, :kk], idxs[:, :kk]
