"""Public facade for the overlap-optimized kNN index.

    from repro.api import Config, IndexConfig, OverlapIndex

    ix = OverlapIndex.build(x, Config(index=IndexConfig(method="vbm", eps=2.0)))
    res = ix.search(q, k=10)      # SearchResult(dists, ids, stats)
    ix.ingest(batch); ix.maintain()
    ix.save("index.npz"); ix2 = OverlapIndex.load("index.npz")

Overlap heuristics (the paper's VBM/DBM/OBM and any registered extension)
resolve through ``register_overlap_method`` / ``available_overlap_methods``.
"""
from repro.api.config import (
    Config,
    ConfigError,
    IndexConfig,
    LayoutConfig,
    ObsConfig,
    RoutingConfig,
    SearchConfig,
    StreamConfig,
    as_index_config,
)
from repro.api.executor import make_backend
from repro.api.index import OverlapIndex
from repro.api.plan import PlanCache, PlanKey, SearchPlan, SearchResult
from repro.core.overlap import (
    OverlapMethod,
    available_overlap_methods,
    get_overlap_method,
    register_overlap_method,
    unregister_overlap_method,
)
from repro.deprecation import RepoDeprecationWarning

__all__ = [
    "Config", "ConfigError", "IndexConfig", "LayoutConfig", "ObsConfig",
    "RoutingConfig",
    "SearchConfig", "StreamConfig", "as_index_config", "make_backend",
    "OverlapIndex",
    "PlanCache", "PlanKey", "SearchPlan", "SearchResult",
    "OverlapMethod", "available_overlap_methods", "get_overlap_method",
    "register_overlap_method", "unregister_overlap_method",
    "RepoDeprecationWarning",
]
