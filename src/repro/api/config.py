"""One frozen configuration tree for the whole index lifecycle.

``Config(index=IndexConfig, search=SearchConfig, stream=StreamConfig)``
replaces the scattered constructor kwargs that used to be threaded by hand
through ``build_index`` / ``knn_search`` / ``StreamingForest`` /
``ForestDatastore``.  Every field is validated at construction with an
actionable message (``ConfigError``) — a typo like ``method="vbmm"`` fails
here, naming the registered alternatives, instead of deep inside the
decision stage.

``IndexConfig`` subclasses the legacy ``core.pipeline.IndexConfig`` (same
fields), so the validated tree flows into the core pipeline unchanged and
``isinstance`` checks in legacy call sites keep working.
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields

from repro.core.overlap import available_overlap_methods
from repro.core.pipeline import IndexConfig as _LegacyIndexConfig

PIVOT_METHODS = ("gh", "kmeans")
SEARCH_MODES = ("forest", "all")
DEVICE_LAYOUTS = ("single", "sharded", "routed")
FANOUT_MODES = ("auto", "targeted", "all")


class ConfigError(ValueError):
    """A configuration field failed validation (message says how to fix it)."""


def _require(ok: bool, msg: str) -> None:
    if not ok:
        raise ConfigError(msg)


def _check_method(name: str, *, owner: str, field_name: str) -> None:
    if name not in available_overlap_methods():
        raise ConfigError(
            f"{owner}.{field_name}={name!r} is not a registered overlap "
            f"method; choose one of {', '.join(available_overlap_methods())} "
            "or add yours with repro.api.register_overlap_method(name, fn)"
        )


def _check_pivot(name: str, *, owner: str) -> None:
    _require(
        name in PIVOT_METHODS,
        f"{owner}.pivot_method={name!r} is unknown; choose 'gh' (the paper's "
        "cheap generalized-hyperplane pivots) or 'kmeans' (the BCCF "
        "baseline's 2-means pivots)",
    )


@dataclass(frozen=True)
class IndexConfig(_LegacyIndexConfig):
    """Build-time knobs (paper §4.1-4.3); validated superset of the legacy
    ``core.pipeline.IndexConfig`` field-for-field."""

    def __post_init__(self) -> None:
        _check_method(self.method, owner="IndexConfig", field_name="method")
        _require(
            0.0 <= self.xi_min < self.xi_max <= 1.0,
            f"IndexConfig thresholds need 0 <= xi_min < xi_max <= 1, got "
            f"xi_min={self.xi_min}, xi_max={self.xi_max} (xi_min is the "
            "overlap-index extraction threshold, xi_max the merge threshold "
            "— paper §4.3)",
        )
        _require(
            self.eps > 0.0,
            f"IndexConfig.eps={self.eps} must be > 0 (DBSCAN neighborhood "
            "radius; try the k-dist elbow of your data, paper §4.1)",
        )
        _require(
            self.min_pts >= 1,
            f"IndexConfig.min_pts={self.min_pts} must be >= 1 (DBSCAN core-"
            "point density threshold)",
        )
        _require(
            self.c_max is None or self.c_max >= 2,
            f"IndexConfig.c_max={self.c_max} must be >= 2 or None (None "
            "picks the paper's Def. 12 default, sqrt(n))",
        )
        _check_pivot(self.pivot_method, owner="IndexConfig")
        _require(
            self.dbscan_block >= 1,
            f"IndexConfig.dbscan_block={self.dbscan_block} must be >= 1 "
            "(pairwise block size of the DBSCAN eps-graph sweep)",
        )


@dataclass(frozen=True)
class SearchConfig:
    """Query-time defaults; each ``OverlapIndex.search`` call may override
    ``k`` / ``mode`` / ``beam`` per call (each combination is one cached
    ``SearchPlan``)."""

    k: int = 10
    mode: str = "forest"  # forest (Alg. 2 routing) | all (exact, no routing)
    beam: int = 1  # buckets evaluated per scan step
    kernel: bool = True  # kernels/ops dispatch (Pallas on TPU) vs jnp ref
    quantize: bool = False  # int8 bucket-member storage on device

    def __post_init__(self) -> None:
        _require(
            self.k >= 1, f"SearchConfig.k={self.k} must be >= 1 neighbors"
        )
        _require(
            self.mode in SEARCH_MODES,
            f"SearchConfig.mode={self.mode!r} is unknown; choose 'forest' "
            "(Alg. 2 routed search) or 'all' (scan every index — exact "
            "global kNN at higher cost)",
        )
        _require(
            self.beam >= 1,
            f"SearchConfig.beam={self.beam} must be >= 1 (buckets evaluated "
            "per bounded-scan step)",
        )


@dataclass(frozen=True)
class StreamConfig:
    """Streaming ingest + online-maintenance knobs (stream/ subsystem)."""

    capacity: int | None = None  # per-index delta capacity; None -> sqrt(n)
    monitor_method: str = "dbm"  # overlap heuristic re-evaluated online
    xi_rebuild: float = 0.8  # absolute overlap rate forcing repartition
    drift_margin: float | None = None  # optional rise-over-baseline trigger
    fill_rebuild: float = 0.75  # delta fill fraction forcing a merge-rebuild
    # measured-waste trigger: rebuild when explain() attribution shows this
    # share of an index's bucket visits were wasted; None keeps the trigger
    # off (it only sees data when explain() runs, so it is opt-in)
    wasted_rebuild: float | None = None
    pivot_method: str = "gh"  # pivot rule for maintenance rebuilds
    c_max: int | None = None  # rebuild bucket capacity; None -> keep forest's
    seed: int = 1

    def __post_init__(self) -> None:
        _require(
            self.capacity is None or self.capacity >= 1,
            f"StreamConfig.capacity={self.capacity} must be >= 1 or None "
            "(None sizes the per-index delta buffers at sqrt(n), floor 64)",
        )
        _check_method(
            self.monitor_method, owner="StreamConfig", field_name="monitor_method"
        )
        _require(
            0.0 < self.xi_rebuild <= 1.0,
            f"StreamConfig.xi_rebuild={self.xi_rebuild} must lie in (0, 1] "
            "(overlap rates are rates — 1.0 disables the absolute trigger "
            "short of full containment)",
        )
        _require(
            self.drift_margin is None or self.drift_margin > 0.0,
            f"StreamConfig.drift_margin={self.drift_margin} must be > 0 or "
            "None (None disables the rise-over-baseline trigger)",
        )
        _require(
            0.0 < self.fill_rebuild <= 1.0,
            f"StreamConfig.fill_rebuild={self.fill_rebuild} must lie in "
            "(0, 1] (fraction of delta capacity that forces a merge-rebuild)",
        )
        _require(
            self.wasted_rebuild is None or 0.0 < self.wasted_rebuild <= 1.0,
            f"StreamConfig.wasted_rebuild={self.wasted_rebuild} must lie in "
            "(0, 1] or None (share of MEASURED wasted bucket visits — from "
            "OverlapIndex.explain attribution — that flags an index for "
            "rebuild; None disables the trigger)",
        )
        _check_pivot(self.pivot_method, owner="StreamConfig")
        _require(
            self.c_max is None or self.c_max >= 2,
            f"StreamConfig.c_max={self.c_max} must be >= 2 or None (None "
            "keeps the forest's bucket capacity on rebuilds)",
        )


@dataclass(frozen=True)
class RoutingConfig:
    """Routing-tier knobs for ``LayoutConfig(kind='routed')`` (the DIMS-style
    multi-host layer, distributed/router/).

    ``fanout`` picks the dispatch mode: ``'auto'`` lets the cost model
    choose per query batch between targeted routing (heterogeneous — only
    hosts whose regions can contain an answer) and full fan-out
    (homogeneous); ``'targeted'``/``'all'`` force one side, which exists for
    tests and for fleets whose operators already know their workload shape.
    ``overlap_method`` names the registered VBM/DBM/OBM heuristic used to
    estimate overlap rates between host-level regions in the routing table.
    """

    fanout: str = "auto"  # auto | targeted | all
    overlap_method: str = "dbm"  # host-region overlap rates in the table

    def __post_init__(self) -> None:
        _require(
            self.fanout in FANOUT_MODES,
            f"RoutingConfig.fanout={self.fanout!r} is unknown; choose 'auto' "
            "(cost model picks per batch), 'targeted' (always prune hosts) "
            "or 'all' (always fan out — DIMS homogeneous search)",
        )
        _check_method(
            self.overlap_method, owner="RoutingConfig",
            field_name="overlap_method",
        )


@dataclass(frozen=True)
class LayoutConfig:
    """Device layout of the executor layer (repro.api.executor).

    ``kind='single'`` (default) keeps the whole forest + delta on one
    device — the behavior every prior release had.  ``kind='sharded'``
    splits the bucket rows and delta buffers over the first ``shards``
    local devices along the ``axis`` mesh axis and runs searches/ingests
    inside one ``shard_map`` island (distributed/knn_island.py) — results
    stay bitwise-identical to the single layout.  ``kind='routed'`` is the
    sharded layout plus the multi-host routing tier (distributed/router/):
    a replicated per-host routing table prunes the hosts each query batch
    must touch, and a cost model picks targeted routing vs full fan-out —
    still bitwise-identical to both other layouts.
    """

    kind: str = "single"  # single | sharded | routed
    shards: int | None = None  # sharded/routed: device count; None -> all
    axis: str = "model"  # mesh axis name the rows shard over
    routing: RoutingConfig = field(default_factory=RoutingConfig)

    def __post_init__(self) -> None:
        _require(
            self.kind in DEVICE_LAYOUTS,
            f"LayoutConfig.kind={self.kind!r} is unknown; choose 'single' "
            "(one device, the default), 'sharded' (bucket rows + delta "
            "buffers split over the model axis) or 'routed' (sharded plus "
            "the per-host routing table + cost-model dispatch)",
        )
        _require(
            self.shards is None or self.shards >= 1,
            f"LayoutConfig.shards={self.shards} must be >= 1 or None "
            "(None uses every local device under kind='sharded'/'routed')",
        )
        _require(
            self.kind in ("sharded", "routed") or self.shards is None,
            f"LayoutConfig.shards={self.shards} only applies to "
            "kind='sharded'/'routed' (the single layout always uses one "
            "device)",
        )
        _require(
            isinstance(self.axis, str) and len(self.axis) > 0,
            f"LayoutConfig.axis={self.axis!r} must be a non-empty mesh "
            "axis name (the serving mesh calls it 'model')",
        )
        if not isinstance(self.routing, RoutingConfig):
            raise ConfigError(
                "LayoutConfig.routing must be a RoutingConfig (got "
                f"{type(self.routing).__name__}); construct it as "
                "LayoutConfig(kind='routed', routing=RoutingConfig(...))"
            )


@dataclass(frozen=True)
class ObsConfig:
    """Telemetry knobs (repro.obs): the per-index metrics registry.

    ``enabled=False`` turns the whole layer into shared no-op objects —
    search results are bitwise-identical either way (metrics are host-side
    bookkeeping only); the toggle exists for overhead-sensitive benches.
    ``events_path`` attaches a JSONL span/event log; ``None`` falls back to
    the ``REPRO_OBS_EVENTS`` environment variable, else events stay off.
    ``trace_sample`` turns a fraction of ``search()`` calls into traced
    requests (deterministic systematic sampling, no RNG): their spans carry
    trace/span/parent ids so ``repro.obs.Trace.reconstruct`` reassembles the
    per-request tree from the event log.  0.0 (default) keeps tracing off.
    ``events_max_bytes``/``events_backups`` bound the event log on disk via
    size-based rotation (``events.jsonl.1``..``.N`` kept, oldest dropped).
    """

    enabled: bool = True
    window: int = 2048  # histogram reservoir: exact percentiles up to this
    events_path: str | None = None  # JSONL event log destination
    trace_sample: float = 0.0  # fraction of searches traced (0 = off, 1 = all)
    events_max_bytes: int | None = None  # rotate event log past this size
    events_backups: int = 3  # rotated files kept (0 = truncate in place)

    def __post_init__(self) -> None:
        _require(
            self.window >= 1,
            f"ObsConfig.window={self.window} must be >= 1 (number of recent "
            "observations each histogram retains for percentiles)",
        )
        _require(
            self.events_path is None or len(str(self.events_path)) > 0,
            "ObsConfig.events_path must be a non-empty path or None (None "
            "defers to $REPRO_OBS_EVENTS, else JSONL events stay off)",
        )
        _require(
            0.0 <= self.trace_sample <= 1.0,
            f"ObsConfig.trace_sample={self.trace_sample} must lie in [0, 1] "
            "(fraction of search requests that emit linked trace spans)",
        )
        _require(
            self.events_max_bytes is None or self.events_max_bytes >= 1,
            f"ObsConfig.events_max_bytes={self.events_max_bytes} must be "
            ">= 1 or None (None never rotates the event log)",
        )
        _require(
            self.events_backups >= 0,
            f"ObsConfig.events_backups={self.events_backups} must be >= 0 "
            "(rotated event-log files kept; 0 truncates on rotation)",
        )


@dataclass(frozen=True)
class Config:
    """The whole lifecycle in one immutable tree.  ``dataclasses.replace``
    (or the ``.with_()`` convenience) derives variants."""

    index: IndexConfig = field(default_factory=IndexConfig)
    search: SearchConfig = field(default_factory=SearchConfig)
    stream: StreamConfig = field(default_factory=StreamConfig)
    layout: LayoutConfig = field(default_factory=LayoutConfig)
    obs: ObsConfig = field(default_factory=ObsConfig)

    def __post_init__(self) -> None:
        for name, want in (
            ("index", IndexConfig),
            ("search", SearchConfig),
            ("stream", StreamConfig),
            ("layout", LayoutConfig),
            ("obs", ObsConfig),
        ):
            got = getattr(self, name)
            if not isinstance(got, want):
                raise ConfigError(
                    f"Config.{name} must be a {want.__name__} "
                    f"(got {type(got).__name__}); construct it as "
                    f"Config({name}={want.__name__}(...))"
                )

    def with_(self, **index_fields) -> "Config":
        """Convenience: replace fields of the INDEX node, e.g.
        ``Config().with_(method='obm', eps=2.0)``."""
        from dataclasses import replace

        return replace(self, index=replace(self.index, **index_fields))


def as_index_config(cfg: _LegacyIndexConfig | IndexConfig) -> IndexConfig:
    """Validate a legacy flat ``core.pipeline.IndexConfig`` into the api
    subclass (no-op when already validated)."""
    if isinstance(cfg, IndexConfig):
        return cfg
    return IndexConfig(**{f.name: getattr(cfg, f.name) for f in fields(cfg)})
