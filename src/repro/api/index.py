"""``OverlapIndex`` — the one owner object for the paper's whole pipeline.

DBSCAN -> overlap estimation (registry heuristics) -> decision -> BCCF
forest -> routed kNN search -> streaming ingest -> overlap-driven online
maintenance -> persistence -> serving datastore, behind one facade:

    from repro.api import Config, IndexConfig, OverlapIndex

    ix = OverlapIndex.build(x, Config(index=IndexConfig(method="vbm", eps=2.0)))
    res = ix.search(q, k=10)          # SearchResult: dists / ids / stats
    ix.ingest(batch)                  # streaming writes (delta buffers)
    ix.maintain()                     # overlap-drift monitor + hot rebuilds
    ix.save("index.npz")              # rebuild-free restart ...
    ix2 = OverlapIndex.load("index.npz")  # ... bitwise-identical searches
    ds = ix.to_datastore(values)      # kNN-LM serving datastore

Internally the facade owns: the host ``ForestArrays`` (+ fresh tree
copies), the device ``DeviceForest`` upload (quantized per config), the
streaming ``DeltaBuffer`` (allocated lazily on first ingest), the overlap
drift monitor, and a ``PlanCache`` of compiled search executors — repeated
searches with stable options/shapes never re-trace.

Everything that used to be wired by hand across ``build_index`` /
``knn_search`` / ``StreamingForest`` / ``ForestDatastore`` hangs off this
object; those surfaces remain as deprecation shims.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import persist
from repro.api.config import (
    SEARCH_MODES,
    Config,
    ConfigError,
    IndexConfig,
    LayoutConfig,
    as_index_config,
)
from repro.api.executor import make_backend
from repro.api.plan import PlanCache, PlanKey, SearchResult, stats_to_host
from repro.core.forest import ForestArrays
from repro.core.knn import DeviceForest, SearchStats, route_points
from repro.core.overlap import get_overlap_method, overlap_matrix
from repro.core.pipeline import (
    BuildReport,
    IndexConfig as _LegacyIndexConfig,
    build_baseline_core,
    build_index_core,
    default_delta_capacity,
)
from repro.obs import (
    EventLog,
    Registry,
    TraceContext,
    TraceSampler,
    current_trace,
    events_path_from_env,
    use_trace,
)
from repro.obs.attribution import ExplainReport, attribute_visits
from repro.stream.ingest import (
    DeltaBuffer,
    alloc_delta,
    delta_view,
    pull_delta_meta,
)


def _as_config(cfg: Config | _LegacyIndexConfig | None) -> Config:
    if cfg is None:
        return Config()
    if isinstance(cfg, Config):
        return cfg
    if isinstance(cfg, _LegacyIndexConfig):  # incl. the validated subclass
        return Config(index=as_index_config(cfg))
    raise ConfigError(
        f"expected a repro.api.Config (or an IndexConfig for the index node), "
        f"got {type(cfg).__name__}"
    )


def _check_data(x) -> np.ndarray:
    x = np.asarray(x, np.float32)
    if x.ndim != 2 or len(x) == 0:
        raise ConfigError(
            f"dataset must be a non-empty (N, D) array, got shape {x.shape}"
        )
    return x


class OverlapIndex:
    """Lifecycle owner for one overlap-optimized forest (see module doc)."""

    # -- construction --------------------------------------------------------
    def __init__(self, *args, **kwargs):
        raise TypeError(
            "OverlapIndex is constructed via OverlapIndex.build(x, cfg), "
            ".baseline(x, cfg), or .load(path)"
        )

    @classmethod
    def _wire(
        cls,
        x: np.ndarray,
        forest: ForestArrays,
        cfg: Config,
        report: BuildReport,
        *,
        n_total: int | None = None,
        delta: DeltaBuffer | None = None,
        capacity: int | None = None,
        rebuild_log: list[dict[str, Any]] | None = None,
        monitor_baseline: np.ndarray | None = None,
        clamp_layout: bool = False,
    ) -> "OverlapIndex":
        self = object.__new__(cls)
        self.cfg = cfg
        self.forest = forest
        self.build_report = report
        self.backend = make_backend(cfg.layout, clamp=clamp_layout)
        self._x_parts: list[np.ndarray] = [x]
        self._x_cache: np.ndarray | None = x
        self.n_total = len(x) if n_total is None else n_total
        self._device: DeviceForest | None = None  # lazy (see .device)
        self.capacity = (
            capacity
            or cfg.stream.capacity
            or default_delta_capacity(self.n_total)
        )
        # backend-resident buffers (padded + sharded under the sharded
        # layout); every host-facing consumer reads the .delta property
        self._delta: DeltaBuffer | None = (
            None if delta is None else self.backend.place_delta(delta)
        )
        self._ingest_exec = None  # lazy jitted ingest (see _ingest_executor)
        self._ingest_traces = 0
        self._ingest_calls = 0
        self.monitor = None
        if delta is not None:
            self.monitor = self._make_monitor()
            if monitor_baseline is not None:
                # restore the baseline captured at save time: recomputing it
                # over the restart-time dataset would shift object-based
                # trigger decisions mid-stream
                self.monitor.rates_baseline = np.asarray(monitor_baseline)
        # one telemetry registry per index: every layer below (plan cache,
        # spans, ingest/maintenance counters, per-island node accesses)
        # registers here; ``metrics()`` is the single snapshot of it all
        events_path = cfg.obs.events_path or events_path_from_env()
        self.obs = Registry(
            enabled=cfg.obs.enabled,
            window=cfg.obs.window,
            events=None if events_path is None else EventLog(
                events_path,
                max_bytes=cfg.obs.events_max_bytes,
                backups=cfg.obs.events_backups,
            ),
        )
        # per-request tracing: self-sampled searches (cfg.obs.trace_sample)
        # get their own TraceContext; an ambient context installed by a
        # caller (ServeEngine) always wins
        self._tracer = TraceSampler(cfg.obs.trace_sample)
        self._searches_since_swap = 0  # maintenance.rebuild_age gauge
        self.plans = PlanCache(registry=self.obs)
        self.rebuild_log: list[dict[str, Any]] = rebuild_log or []
        return self

    @classmethod
    def build(
        cls, x, cfg: Config | _LegacyIndexConfig | None = None
    ) -> "OverlapIndex":
        """The paper's proposed pipeline (§4): overlap-optimized forest."""
        cfg = _as_config(cfg)
        x = _check_data(x)
        forest, report = build_index_core(x, cfg.index)
        return cls._wire(x, forest, cfg, report)

    @classmethod
    def baseline(
        cls, x, cfg: Config | _LegacyIndexConfig | None = None
    ) -> "OverlapIndex":
        """The BCCF-tree baseline: one tree over all data.  With no config
        this builds the paper's documented 2-means baseline; an explicit
        config is honored (see ``build_baseline_core``)."""
        x = _check_data(x)
        if cfg is None:
            forest, report = build_baseline_core(x, None)
            cfg = Config(index=as_index_config(report.config))
        else:
            cfg = _as_config(cfg)
            forest, report = build_baseline_core(x, cfg.index)
        return cls._wire(x, forest, cfg, report)

    # -- dataset bookkeeping -------------------------------------------------
    @property
    def x_all(self) -> np.ndarray:
        if self._x_cache is None or len(self._x_cache) != self.n_total:
            self._x_cache = np.concatenate(self._x_parts)
            self._x_parts = [self._x_cache]
        return self._x_cache

    @property
    def n_indexes(self) -> int:
        return self.forest.n_indexes

    @property
    def device(self) -> DeviceForest:
        """Device upload of the forest, quantized per ``cfg.search`` and
        placed per ``cfg.layout`` (sharded bucket rows under the sharded
        backend).

        Lazy: host-only consumers (build reports, structure rollups, the
        construction benchmarks) never pay the upload — and build wall time
        measures the build, not the transfer.  First search/ingest uploads.
        """
        if self._device is None:
            self._device = self.backend.upload_forest(
                self.forest, quantize=self.cfg.search.quantize
            )
        return self._device

    @property
    def delta(self) -> DeltaBuffer | None:
        """LOGICAL (unpadded) view of the streaming delta buffers — what the
        drift monitor, persistence, and introspection consume.  Identical to
        the device-resident buffers under the single layout; the sharded
        layout slices off the shard-alignment pad rows."""
        if self._delta is None:
            return None
        return self.backend.logical_delta(self._delta, self.forest.n_indexes)

    @property
    def device_delta(self) -> DeltaBuffer | None:
        """Backend-resident delta buffers exactly as the executors see them
        (padded + sharded under the sharded layout) — the serving datastore
        rides on these so its searches reuse the same placement."""
        return self._delta

    # -- read path: planner + cached executors -------------------------------
    def _plan_key(self, k, mode, beam, kernel) -> PlanKey:
        # per-call overrides get the SAME validation the config tree does —
        # a bad k/beam/mode must fail here with an actionable error, not
        # deep inside the jitted executor (and never poison the plan cache)
        sc = self.cfg.search
        key = PlanKey(
            k=sc.k if k is None else int(k),
            mode=sc.mode if mode is None else mode,
            beam=sc.beam if beam is None else int(beam),
            kernel=sc.kernel if kernel is None else bool(kernel),
            quantize=sc.quantize,
            delta_capacity=None if self._delta is None else self.capacity,
            shards=self.backend.shards,
            # routed layout: the dispatch policy is a static compile knob —
            # None elsewhere keeps single/sharded plan keys unchanged
            fanout=(
                self.cfg.layout.routing.fanout
                if self.backend.kind == "routed" else None
            ),
        )
        if key.k < 1:
            raise ConfigError(f"search k={key.k} must be >= 1 neighbors")
        if key.mode not in SEARCH_MODES:
            raise ConfigError(
                f"search mode {key.mode!r} is unknown; choose one of "
                f"{', '.join(SEARCH_MODES)}"
            )
        if key.beam < 1:
            raise ConfigError(f"search beam={key.beam} must be >= 1")
        return key

    def _search_device(
        self, q, *, k=None, mode=None, beam=None, kernel=None
    ) -> tuple[Any, Any, SearchStats]:
        """Raw device triple (dists, ids, SearchStats) through the plan
        cache — the serving/benchmark path that stays on device."""
        with self.obs.span("search"):
            d, i, s, *_ = self._search_planned(
                q, k=k, mode=mode, beam=beam, kernel=kernel
            )
        return d, i, s

    def _search_planned(self, q, *, k=None, mode=None, beam=None, kernel=None):
        # phase spans nest under whichever outer span is active ("search"
        # from both public entries), giving search/plan_lookup and
        # search/device_execute histograms.  NB: device_execute times the
        # DISPATCH — on an async accelerator completion lands in the
        # caller's host_transfer span (the first blocking read).
        with self.obs.span("plan_lookup"):
            key = self._plan_key(k, mode, beam, kernel)
            plan = self.plans.plan(key, self.backend)
            plan.calls += 1
            delta = None if self._delta is None else delta_view(self._delta)
        with self.obs.span("device_execute"):
            outs = plan.executor(
                self.backend.search_operands(self.device),
                jnp.asarray(q, jnp.float32), delta,
            )
        # routed executors append RouterStats; everything else is 4 long
        d, i, s, isl = outs[:4]
        router = outs[4] if len(outs) > 4 else None
        return d, i, s, isl, router, plan

    def _record_search(self, stats: dict[str, Any], isl, router=None) -> None:
        """Fold one search's host-side stats into the registry: fleet
        node-access counters plus the per-island breakdown the sharded
        executor reports (load balance across shards) — and, on the routed
        layout, the routing tier's dispatch telemetry."""
        obs = self.obs
        obs.counter("search.queries").inc(len(stats["buckets_visited"]))
        for name in ("buckets_visited", "distances", "bound_distances"):
            obs.counter(f"search.{name}").inc(int(stats[name].sum()))
        if router is not None:
            r = jax.device_get(router)
            mode = "targeted" if bool(r.targeted) else "all"
            obs.counter("router.queries").inc(len(r.eligible_hosts))
            obs.counter("router.eligible_hosts").inc(
                int(r.eligible_hosts.sum())
            )
            obs.counter("router.pruned_hosts").inc(int(r.pruned_hosts.sum()))
            obs.counter("router.fanout", mode=mode).inc(
                len(r.eligible_hosts)
            )
            obs.counter("router.est_bytes", mode="targeted").inc(
                int(r.wire_targeted)
            )
            obs.counter("router.est_bytes", mode="all").inc(
                int(r.wire_fanall)
            )
            obs.emit_event(
                {
                    "event": "router",
                    "fanout": mode,
                    "eligible_hosts": r.eligible_hosts.tolist(),
                    "pruned_hosts": int(r.pruned_hosts.sum()),
                    "est_bytes_targeted": float(r.wire_targeted),
                    "est_bytes_fanall": float(r.wire_fanall),
                },
                traced_only=True,
            )
        if isl is None:
            return
        isl = jax.device_get(isl)
        method = self.cfg.index.method
        for s_id in range(isl.buckets_visited.shape[0]):
            for name in ("buckets_visited", "distances", "bound_distances"):
                obs.counter(
                    f"search.island.{name}", island=s_id, method=method
                ).inc(int(getattr(isl, name)[s_id].sum()))
            # traced requests additionally get a per-island point event in
            # their span tree (dropped outside a sampled trace: per-request
            # annotations must not bloat steady-state logs)
            obs.emit_event(
                {
                    "event": "island",
                    "island": s_id,
                    "buckets_visited": int(isl.buckets_visited[s_id].sum()),
                    "distances": int(isl.distances[s_id].sum()),
                },
                traced_only=True,
            )

    def search(
        self, q, *, k: int | None = None, mode: str | None = None,
        beam: int | None = None, kernel: bool | None = None,
        trace: TraceContext | None = None,
    ) -> SearchResult:
        """kNN over forest + streaming delta.  Defaults come from
        ``cfg.search``; per-call overrides select (or create) the matching
        cached ``SearchPlan``.  Returns a host-side ``SearchResult``.

        ``trace`` joins this search to a caller-owned request trace; with
        no explicit context and no ambient one, ``cfg.obs.trace_sample``
        self-samples (the sampled search becomes its own trace root in the
        event log).  Tracing never touches the executors — traced and
        untraced searches return bitwise-identical results.
        """
        obs = self.obs
        ctx = trace
        if ctx is None and obs.enabled and current_trace() is None:
            ctx = self._tracer.maybe_trace()
        self._searches_since_swap += 1
        obs.gauge("maintenance.rebuild_age").set(self._searches_since_swap)
        with use_trace(ctx), obs.span("search"):
            d, i, s, isl, router, plan = self._search_planned(
                q, k=k, mode=mode, beam=beam, kernel=kernel
            )
            with obs.span("host_transfer"):
                d, i = np.asarray(d), np.asarray(i)
                stats = stats_to_host(s)
            if obs.enabled:
                self._record_search(stats, isl, router)
        kk = min(plan.key.k, self.n_total)  # Def. 4: |X| <= k -> whole set
        if d.shape[1] > kk:
            d, i = d[:, :kk], i[:, :kk]
        return SearchResult(dists=d, ids=i, stats=stats, plan=plan)

    def explain(
        self, q, *, k: int | None = None, mode: str | None = None,
        beam: int | None = None, kernel: bool | None = None,
        feed_monitor: bool = True,
    ) -> ExplainReport:
        """Search + overlap attribution: which bucket visits CONTRIBUTED a
        final top-k member, which were WASTED, and which (visited, home)
        partition pairs the waste charges to (``obs/attribution.py``).

        Runs the normal executor op sequence (a separate cached plan that
        additionally returns the visited-row evidence — the plain ``search``
        plan and its results are untouched, and ``report.result`` is
        bitwise-identical to ``search()``), then a host-side post-pass.
        Per query, contributing + wasted == ``stats['buckets_visited']``.
        Aggregates land in ``metrics()['overlap_health']`` and — with
        ``feed_monitor`` (default) — in the drift monitor's measured-waste
        accumulators (``StreamConfig.wasted_rebuild`` trigger).
        """
        obs = self.obs
        with obs.span("explain"):
            with obs.span("plan_lookup"):
                key = self._plan_key(k, mode, beam, kernel)._replace(
                    explain=True
                )
                plan = self.plans.plan(key, self.backend)
                plan.calls += 1
                delta = (
                    None if self._delta is None else delta_view(self._delta)
                )
            qj = jnp.asarray(q, jnp.float32)
            with obs.span("device_execute"):
                outs = plan.executor(
                    self.backend.search_operands(self.device), qj, delta
                )
                d, i, s, isl, rows = outs[:5]
                router = outs[5] if len(outs) > 5 else None
                # home = the routed index, computed with the DEVICE routing
                # op (same kernel flag) so tie-breaks match the executor
                _, home = route_points(
                    self.device.index_centers, qj, kernel=key.kernel
                )
            with obs.span("host_transfer"):
                d, i = np.asarray(d), np.asarray(i)
                stats = stats_to_host(s)
                rows = jax.device_get(rows)
                home = np.asarray(home)
            if obs.enabled:
                self._record_search(stats, isl, router)
            kk = min(key.k, self.n_total)
            if d.shape[1] > kk:
                d, i = d[:, :kk], i[:, :kk]
            with obs.span("attribute"):
                report = self._attribute(rows, i, home)
        report.result = SearchResult(dists=d, ids=i, stats=stats, plan=plan)
        if obs.enabled:
            obs.counter("explain.queries").inc(report.queries)
            obs.counter("explain.contributing").inc(
                int(report.contributing.sum())
            )
            obs.counter("explain.wasted").inc(int(report.wasted.sum()))
            jj, ii = np.nonzero(report.wasted_pair)
            for j_v, i_h in zip(jj.tolist(), ii.tolist()):
                obs.counter(
                    "explain.wasted_pair", visited=j_v, home=i_h
                ).inc(int(report.wasted_pair[j_v, i_h]))
        if feed_monitor and self.monitor is not None:
            self.monitor.note_wasted(report.wasted_pair, report.visited_pair)
        return report

    def _attribute(self, rows, result_ids, home) -> ExplainReport:
        """Host-side decode of one explain run's ``VisitRows`` (see
        ``obs.attribution.attribute_visits`` for the semantics)."""
        forest = self.forest
        S = self.backend.shards
        method = self.cfg.stream.monitor_method
        rates = None
        if self.monitor is not None:
            rates = self.monitor.rates_baseline
        elif not get_overlap_method(method).needs_objects:
            rates = np.asarray(overlap_matrix(
                method,
                jnp.asarray(forest.index_centers, jnp.float32),
                jnp.asarray(forest.index_radii, jnp.float32),
            ))
        delta_ids = delta_count = None
        if self._delta is not None:
            meta = pull_delta_meta(self.delta, ids=True)
            delta_ids, delta_count = meta["ids"], meta["count"]
        return attribute_visits(
            order=rows.order,
            visits=rows.visits,
            dorder=rows.dorder,
            dvisits=rows.dvisits,
            result_ids=result_ids,
            home=home,
            n_indexes=forest.n_indexes,
            bucket_index=forest.bucket_index,
            bucket_ids=forest.bucket_ids,
            bucket_mask=forest.bucket_mask,
            # global row = shard-local row + shard * PADDED per-shard rows
            main_rows_per_shard=-(-forest.n_buckets // S),
            delta_rows_per_shard=-(-forest.n_indexes // S),
            delta_ids=delta_ids,
            delta_count=delta_count,
            rates=rates,
            method=method,
        )

    # -- write path ----------------------------------------------------------
    def _ensure_delta(self) -> None:
        if self._delta is None:
            self._delta = self.backend.place_delta(
                alloc_delta(self.forest, self.capacity)
            )
            self.monitor = self._make_monitor()

    def _ingest_executor(self):
        """One jitted ingest program per index, wrapping the backend's body
        with a trace counter (the ingest twin of ``api.plan.SearchPlan``).
        The jit cache keys on (centers shape, delta shapes, batch shape) —
        all stable across rebuilds and, with ``_pad_batch``, across ragged
        tail chunks — so steady-state streaming never re-traces."""
        if self._ingest_exec is None:
            body = self.backend.ingest_body()

            def _impl(centers, delta, xb, ids, valid):
                self._ingest_traces += 1  # runs only while jax traces
                return body(centers, delta, xb, ids, valid)

            self._ingest_exec = jax.jit(_impl)
        return self._ingest_exec

    def _pad_batch(self, n: int) -> int:
        """Padded chunk length: next power of two, clamped to the chunk
        ceiling (the delta capacity).  Bounds the number of compiled ingest
        shapes at log2(capacity) while wasting < 2x lanes on ragged tails —
        pad rows ride the ``valid`` parking mechanism (accepted upfront,
        stored nowhere)."""
        p = 1
        while p < n:
            p <<= 1
        return min(p, self.capacity)

    def ingest_stats(self) -> dict[str, int]:
        """Observability for the write path: compiled-trace and call
        counters of the jitted ingest executor (tests assert no-retrace)."""
        return dict(traces=self._ingest_traces, calls=self._ingest_calls)

    def _make_monitor(self):
        from repro.stream.maintenance import OverlapMonitor

        needs_x = get_overlap_method(self.cfg.stream.monitor_method).needs_objects
        return OverlapMonitor(
            self.forest, self._maint_cfg(), x=self.x_all if needs_x else None
        )

    def _maint_cfg(self):
        from repro.stream.maintenance import MaintenanceConfig

        s = self.cfg.stream
        return MaintenanceConfig(
            method=s.monitor_method,
            xi_rebuild=s.xi_rebuild,
            drift_margin=s.drift_margin,
            fill_rebuild=s.fill_rebuild,
            wasted_rebuild=s.wasted_rebuild,
            pivot_method=s.pivot_method,
            c_max=s.c_max,
            seed=s.seed,
        )

    def ingest(self, xb) -> np.ndarray:
        """Insert a batch; returns the assigned global object ids.

        Chunks the batch to the per-index buffer capacity so a forced
        maintenance pass (emptying the destination buffers) always makes the
        retry succeed — ingestion cannot silently drop or livelock.
        """
        self._ensure_delta()
        xb = np.asarray(xb, np.float32)
        if xb.ndim != 2 or xb.shape[1] != self.forest.bucket_x.shape[2]:
            raise ConfigError(
                f"ingest batch must be (B, {self.forest.bucket_x.shape[2]}), "
                f"got shape {xb.shape}"
            )
        ids = np.arange(self.n_total, self.n_total + len(xb), dtype=np.int64)
        self._x_parts.append(xb)
        self.n_total += len(xb)
        self._x_cache = None
        with self.obs.span("ingest"):
            self.obs.counter("ingest.points").inc(len(xb))
            for lo in range(0, len(xb), self.capacity):
                self._ingest_chunk(
                    xb[lo : lo + self.capacity], ids[lo : lo + self.capacity]
                )
        return ids

    def _ingest_chunk(self, xc: np.ndarray, ic: np.ndarray) -> None:
        # Termination argument: a round that rejects any point force-rebuilds
        # every rejecting index, emptying its buffer into the main structure.
        # A retried point (chunk size <= buffer capacity) can only be
        # rejected again by re-routing to a DIFFERENT still-full buffer, and
        # each round empties at least one of those — so at most n_indexes
        # rounds before every point is accepted.  Retries flip the ``valid``
        # mask instead of slicing the batch, and ragged tail chunks pad up to
        # a power-of-two shape with rows parked invalid, so every round (and
        # every steady-state batch) reuses one compiled ingest program.
        b = len(xc)
        bp = self._pad_batch(b)
        if bp > b:
            xc = np.concatenate(
                [xc, np.zeros((bp - b, xc.shape[1]), xc.dtype)]
            )
            ic = np.concatenate([ic, np.full((bp - b,), -1, ic.dtype)])
        pending = np.zeros(bp, bool)
        pending[:b] = True
        xj, ij = jnp.asarray(xc), jnp.asarray(ic)
        run = self._ingest_executor()
        for _ in range(self.forest.n_indexes + 1):
            self._ingest_calls += 1
            with self.obs.span("device_execute"):
                self._delta, acc = run(
                    self.device.index_centers, self._delta, xj, ij,
                    jnp.asarray(pending),
                )
                pending &= ~np.asarray(acc)
            if not pending.any():
                return
            # capacity hit: force-rebuild the rejecting indexes, retry rest
            self.obs.counter("ingest.capacity_retries").inc()
            meta = pull_delta_meta(self.delta)
            full = [
                i for i in range(self.forest.n_indexes) if meta["dropped"][i] > 0
            ]
            self._rebuild(full)
        raise RuntimeError(
            "ingest chunk still rejected after rebuilding every full index — "
            "invariant violation, please report"
        )

    # -- maintenance ---------------------------------------------------------
    def check(self):
        """Overlap-drift evaluation only (no rebuild) -> DriftReport."""
        self._ensure_delta()
        with self.obs.span("check"):
            needs_x = get_overlap_method(
                self.cfg.stream.monitor_method
            ).needs_objects
            report = self.monitor.check(
                self.delta, x=self.x_all if needs_x else None
            )
        self.obs.counter("maintain.checks").inc()
        for i, f in enumerate(report.fill):
            self.obs.gauge("maintenance.delta_fill", index=i).set(float(f))
        for reasons in report.reasons.values():
            for why in reasons:
                self.obs.counter("maintain.triggers", reason=why).inc()
        return report

    def maintain(self):
        """Run the drift monitor; rebuild + hot-swap every triggered index.

        The swap is atomic: queries see the old (device, delta) pair or the
        new pair, never a partial state.  Returns the DriftReport.
        """
        with self.obs.span("maintain"):
            report = self.check()
            if report.triggers:
                self._rebuild(report.triggers, report)
        return report

    def _rebuild(self, triggers: list[int], report=None) -> None:
        if not triggers:
            return
        with self.obs.span("rebuild"):
            self._rebuild_impl(triggers, report)

    def _rebuild_impl(self, triggers: list[int], report) -> None:
        from repro.stream.maintenance import rebuild_indexes

        x_all = self.x_all
        new_forest, stats = rebuild_indexes(
            self.forest, self.delta, x_all, triggers, self._maint_cfg()
        )
        # Survivors — delta members of indexes NOT rebuilt — keep their
        # original buffers wholesale: a kept index keeps its center, so the
        # old buffer's pivot/radius bound is still valid verbatim.  A pure
        # device-side select (no host round-trip, no re-routing) that BY
        # CONSTRUCTION cannot overflow: each kept buffer moves into a fresh
        # buffer of the same capacity.  Rebuilt indexes start empty (their
        # members were absorbed into the new trees); ``dropped`` resets —
        # rejected points were never stored and their owners retry them.
        new_device = self.backend.upload_forest(
            new_forest, quantize=self.cfg.search.quantize
        )
        fresh = alloc_delta(new_forest, self.capacity)
        keep = np.ones(self.forest.n_indexes, bool)
        keep[list(triggers)] = False
        old = self.delta  # logical view: survivor select is index-aligned
        n_migrated = int(np.asarray(old.count)[keep].sum())
        kj = jnp.asarray(keep)
        new_delta = self.backend.place_delta(fresh._replace(
            x=jnp.where(kj[:, None, None], old.x, fresh.x),
            ids=jnp.where(kj[:, None], old.ids, fresh.ids),
            count=jnp.where(kj, old.count, fresh.count),
            pivot=jnp.where(kj[:, None], old.pivot, fresh.pivot),
            radius=jnp.where(kj, old.radius, fresh.radius),
            sum_x=jnp.where(kj[:, None], old.sum_x, fresh.sum_x),
        ))

        # ---- atomic swap: a query sees the old pair or the new pair --------
        # per-shard barrier first: under the sharded layout every shard's new
        # arrays must be materialized before the swap becomes visible, so the
        # hot swap stays atomic (single layout: no-op)
        self.backend.barrier(new_device, new_delta)
        self.forest, self._device, self._delta = new_forest, new_device, new_delta
        self.monitor = self._make_monitor()
        stats["triggers"] = list(triggers)
        stats["reasons"] = dict(report.reasons) if report is not None else {}
        stats["n_migrated"] = n_migrated
        self.rebuild_log.append(stats)
        self._searches_since_swap = 0
        self.obs.gauge("maintenance.rebuild_age").set(0)
        self.obs.counter("maintain.rebuilds").inc(len(triggers))
        self.obs.counter("maintain.migrated").inc(n_migrated)
        self.obs.histogram("maintain.rebuild_wall_s").observe(
            stats["wall_time_s"]
        )

    # -- persistence ---------------------------------------------------------
    def save(self, path) -> str:
        """Serialize the WHOLE index (forest + host trees + delta + config +
        dataset) to one .npz; returns the path written.  A ``load`` of that
        file serves bitwise-identical searches without rebuilding."""
        return persist.save_state(self, path)

    @classmethod
    def load(cls, path, *, layout: LayoutConfig | None = None) -> "OverlapIndex":
        """Rebuild-free restart from ``save`` output.

        Snapshots store LOGICAL (host, unpadded) state, so they are
        layout-independent: ``layout`` re-shards the loaded index onto a
        different device layout than it was saved under (searches stay
        bitwise-identical).  Without an override the saved layout is used,
        clamped to the devices this host actually has.
        """
        st = persist.load_state(path)
        cfg = st["cfg"]
        if layout is not None:
            from dataclasses import replace

            cfg = replace(cfg, layout=layout)
        return cls._wire(
            np.asarray(st["x_all"], np.float32),
            st["forest"],
            cfg,
            st["build_report"],
            n_total=st["n_total"],
            delta=st["delta"],
            capacity=st["capacity"],
            rebuild_log=st["rebuild_log"],
            monitor_baseline=st["monitor_baseline"],
            clamp_layout=layout is None,
        )

    # -- serving -------------------------------------------------------------
    def to_datastore(
        self, values, *, stream_capacity: int = 0, quantized: bool | None = None
    ):
        """Wrap this index as a kNN-LM serving ``ForestDatastore``.

        ``values[i]`` is the token paired with object id ``i`` — one value
        per object currently in the index (``n_total``).  A live streaming
        delta rides along (its members stay retrievable and serve-side
        ``ingest_keys`` appends into the same buffers).  ``stream_capacity``
        preallocates a values tail for that many FUTURE serve-side inserts;
        ``quantized`` overrides ``cfg.search.quantize`` for the datastore's
        bucket storage.
        """
        from repro.serve.retrieval import datastore_from_index

        return datastore_from_index(
            self, values, stream_capacity=stream_capacity, quantized=quantized
        )

    # -- introspection -------------------------------------------------------
    def metrics(self) -> dict[str, Any]:
        """ONE nested telemetry snapshot of this index (JSON-serializable).

        Sections:
          search       per-phase span histograms (``search``,
                       ``search/plan_lookup``, ``search/device_execute``,
                       ``search/host_transfer``) with p50/p95/p99 seconds;
          plan_cache   compiled-executor table counters (hits/misses/
                       evictions/lifetime traces);
          ingest       write-path counters (compiled traces, executor calls,
                       points ingested, capacity-retry rounds);
          maintenance  drift-monitor checks, per-reason trigger counts
                       (overlap/drift/fill/overflow), rebuild totals;
          islands      per-executor-island node-access counters — the
                       paper's cost currency (buckets_visited / distances /
                       bound_distances) per shard, one island on the single
                       layout;
          router       routing-tier dispatch telemetry (routed layout):
                       queries routed, eligible/pruned-host totals, per-mode
                       fanout counts (``router.fanout{mode=...}``),
                       estimated cross-host all-gather bytes for both
                       dispatch modes, and a host-side summary of the live
                       routing table (host member counts, worst inter-host
                       overlap rate);
          overlap_health  ``explain()`` attribution rollup: contributing vs
                       wasted visit totals, the wasted fraction, and the
                       per-(visited, home) wasted-pair counters — the live
                       evidence behind the paper's overlap argument;
          registry     the raw registry snapshot (every counter/gauge/
                       histogram, including span paths not listed above).

        ``Registry.to_prometheus()`` (or ``python -m repro.obs.export``)
        renders the registry section in Prometheus text format.

        With ``cfg.obs.enabled=False`` the structural sections (plan_cache,
        ingest traces/calls, rebuilds) remain — their counters predate the
        registry — and the registry-backed ones are empty.
        """
        obs = self.obs
        snap = obs.snapshot()
        counters = obs.counters()
        islands: dict[int, dict[str, int]] = {}
        triggers: dict[str, int] = {}
        wasted_pairs: dict[str, int] = {}
        for (name, labels), val in counters.items():
            if name.startswith("search.island."):
                lab = dict(labels)
                islands.setdefault(int(lab["island"]), {})[
                    name[len("search.island."):]
                ] = val
            elif name == "maintain.triggers":
                triggers[dict(labels).get("reason", "?")] = val
            elif name == "explain.wasted_pair":
                lab = dict(labels)
                wasted_pairs[f"{lab['visited']}->{lab['home']}"] = val
        contributing = obs.value("explain.contributing")
        wasted = obs.value("explain.wasted")
        table = getattr(self.backend, "table", None)
        router_table = None
        if table is not None:
            t = jax.device_get(table)
            router_table = {
                "hosts": int(t.host_counts.shape[0]),
                "host_counts": t.host_counts.tolist(),
                "max_rate": (
                    float(t.host_rates.max()) if t.host_rates.size else 0.0
                ),
            }
        return {
            "enabled": obs.enabled,
            "search": {
                "spans": {
                    k: v for k, v in snap["histograms"].items()
                    if k == "search" or k.startswith("search/")
                },
                "queries": obs.value("search.queries"),
                "buckets_visited": obs.value("search.buckets_visited"),
                "distances": obs.value("search.distances"),
                "bound_distances": obs.value("search.bound_distances"),
            },
            "plan_cache": self.plans.stats(),
            "ingest": {
                **self.ingest_stats(),
                "points": obs.value("ingest.points"),
                "capacity_retries": obs.value("ingest.capacity_retries"),
            },
            "maintenance": {
                "checks": obs.value("maintain.checks"),
                "triggers": triggers,
                "rebuilds": len(self.rebuild_log),
                "indexes_rebuilt": obs.value("maintain.rebuilds"),
                "migrated": obs.value("maintain.migrated"),
                # searches served since the last rebuild swap (gauge twin:
                # maintenance.rebuild_age); delta_fill gauges live in the
                # registry section under maintenance.delta_fill{index=i}
                "rebuild_age": self._searches_since_swap,
            },
            "islands": islands,
            "router": {
                "queries": obs.value("router.queries"),
                "eligible_hosts": obs.value("router.eligible_hosts"),
                "pruned_hosts": obs.value("router.pruned_hosts"),
                "fanout": {
                    m: obs.value("router.fanout", mode=m)
                    for m in ("targeted", "all")
                },
                "est_bytes": {
                    m: obs.value("router.est_bytes", mode=m)
                    for m in ("targeted", "all")
                },
                "table": router_table,
            },
            "overlap_health": {
                "explained_queries": obs.value("explain.queries"),
                "contributing": contributing,
                "wasted": wasted,
                "wasted_fraction": (
                    wasted / (contributing + wasted)
                    if (contributing + wasted) else 0.0
                ),
                "wasted_pairs": wasted_pairs,
                "monitor_wasted_share": (
                    None if self.monitor is None
                    else self.monitor.wasted_share().tolist()
                ),
            },
            "registry": snap,
        }

    def structure(self) -> dict[str, Any]:
        """aggregate_structure + live delta occupancy (always fresh)."""
        s = self.forest.aggregate_structure()
        if self.delta is not None:
            s["delta_fill"] = np.asarray(self.delta.count).tolist()
        else:
            s["delta_fill"] = [0] * self.forest.n_indexes
        s["delta_capacity"] = self.capacity
        s["n_objects"] = self.n_total
        s["rebuilds"] = self.forest.build_stats.get("rebuilds", 0)
        return s

    def __repr__(self) -> str:
        return (
            f"OverlapIndex(n={self.n_total}, indexes={self.forest.n_indexes}, "
            f"buckets={self.forest.n_buckets}, method={self.cfg.index.method!r}, "
            f"delta={'on' if self._delta is not None else 'off'}, "
            f"layout={self.backend.kind}"
            f"{f'x{self.backend.shards}' if self.backend.shards > 1 else ''}, "
            f"plans={len(self.plans)})"
        )
