"""``OverlapIndex`` — the one owner object for the paper's whole pipeline.

DBSCAN -> overlap estimation (registry heuristics) -> decision -> BCCF
forest -> routed kNN search -> streaming ingest -> overlap-driven online
maintenance -> persistence -> serving datastore, behind one facade:

    from repro.api import Config, IndexConfig, OverlapIndex

    ix = OverlapIndex.build(x, Config(index=IndexConfig(method="vbm", eps=2.0)))
    res = ix.search(q, k=10)          # SearchResult: dists / ids / stats
    ix.ingest(batch)                  # streaming writes (delta buffers)
    ix.maintain()                     # overlap-drift monitor + hot rebuilds
    ix.save("index.npz")              # rebuild-free restart ...
    ix2 = OverlapIndex.load("index.npz")  # ... bitwise-identical searches
    ds = ix.to_datastore(values)      # kNN-LM serving datastore

Internally the facade owns: the host ``ForestArrays`` (+ fresh tree
copies), the device ``DeviceForest`` upload (quantized per config), the
streaming ``DeltaBuffer`` (allocated lazily on first ingest), the overlap
drift monitor, and a ``PlanCache`` of compiled search executors — repeated
searches with stable options/shapes never re-trace.

Everything that used to be wired by hand across ``build_index`` /
``knn_search`` / ``StreamingForest`` / ``ForestDatastore`` hangs off this
object; those surfaces remain as deprecation shims.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.api import persist
from repro.api.config import (
    SEARCH_MODES,
    Config,
    ConfigError,
    IndexConfig,
    as_index_config,
)
from repro.api.plan import PlanCache, PlanKey, SearchResult, stats_to_host
from repro.core.forest import ForestArrays
from repro.core.knn import DeviceForest, SearchStats, device_forest
from repro.core.overlap import get_overlap_method
from repro.core.pipeline import (
    BuildReport,
    IndexConfig as _LegacyIndexConfig,
    build_baseline_core,
    build_index_core,
    default_delta_capacity,
)
from repro.stream.ingest import (
    DeltaBuffer,
    alloc_delta,
    delta_view,
    ingest,
    pull_delta_meta,
)


def _as_config(cfg: Config | _LegacyIndexConfig | None) -> Config:
    if cfg is None:
        return Config()
    if isinstance(cfg, Config):
        return cfg
    if isinstance(cfg, _LegacyIndexConfig):  # incl. the validated subclass
        return Config(index=as_index_config(cfg))
    raise ConfigError(
        f"expected a repro.api.Config (or an IndexConfig for the index node), "
        f"got {type(cfg).__name__}"
    )


def _check_data(x) -> np.ndarray:
    x = np.asarray(x, np.float32)
    if x.ndim != 2 or len(x) == 0:
        raise ConfigError(
            f"dataset must be a non-empty (N, D) array, got shape {x.shape}"
        )
    return x


class OverlapIndex:
    """Lifecycle owner for one overlap-optimized forest (see module doc)."""

    # -- construction --------------------------------------------------------
    def __init__(self, *args, **kwargs):
        raise TypeError(
            "OverlapIndex is constructed via OverlapIndex.build(x, cfg), "
            ".baseline(x, cfg), or .load(path)"
        )

    @classmethod
    def _wire(
        cls,
        x: np.ndarray,
        forest: ForestArrays,
        cfg: Config,
        report: BuildReport,
        *,
        n_total: int | None = None,
        delta: DeltaBuffer | None = None,
        capacity: int | None = None,
        rebuild_log: list[dict[str, Any]] | None = None,
        monitor_baseline: np.ndarray | None = None,
    ) -> "OverlapIndex":
        self = object.__new__(cls)
        self.cfg = cfg
        self.forest = forest
        self.build_report = report
        self._x_parts: list[np.ndarray] = [x]
        self._x_cache: np.ndarray | None = x
        self.n_total = len(x) if n_total is None else n_total
        self._device: DeviceForest | None = None  # lazy (see .device)
        self.capacity = (
            capacity
            or cfg.stream.capacity
            or default_delta_capacity(self.n_total)
        )
        self.delta: DeltaBuffer | None = delta
        self.monitor = None
        if delta is not None:
            self.monitor = self._make_monitor()
            if monitor_baseline is not None:
                # restore the baseline captured at save time: recomputing it
                # over the restart-time dataset would shift object-based
                # trigger decisions mid-stream
                self.monitor.rates_baseline = np.asarray(monitor_baseline)
        self.plans = PlanCache()
        self.rebuild_log: list[dict[str, Any]] = rebuild_log or []
        return self

    @classmethod
    def build(
        cls, x, cfg: Config | _LegacyIndexConfig | None = None
    ) -> "OverlapIndex":
        """The paper's proposed pipeline (§4): overlap-optimized forest."""
        cfg = _as_config(cfg)
        x = _check_data(x)
        forest, report = build_index_core(x, cfg.index)
        return cls._wire(x, forest, cfg, report)

    @classmethod
    def baseline(
        cls, x, cfg: Config | _LegacyIndexConfig | None = None
    ) -> "OverlapIndex":
        """The BCCF-tree baseline: one tree over all data.  With no config
        this builds the paper's documented 2-means baseline; an explicit
        config is honored (see ``build_baseline_core``)."""
        x = _check_data(x)
        if cfg is None:
            forest, report = build_baseline_core(x, None)
            cfg = Config(index=as_index_config(report.config))
        else:
            cfg = _as_config(cfg)
            forest, report = build_baseline_core(x, cfg.index)
        return cls._wire(x, forest, cfg, report)

    # -- dataset bookkeeping -------------------------------------------------
    @property
    def x_all(self) -> np.ndarray:
        if self._x_cache is None or len(self._x_cache) != self.n_total:
            self._x_cache = np.concatenate(self._x_parts)
            self._x_parts = [self._x_cache]
        return self._x_cache

    @property
    def n_indexes(self) -> int:
        return self.forest.n_indexes

    @property
    def device(self) -> DeviceForest:
        """Device upload of the forest, quantized per ``cfg.search``.

        Lazy: host-only consumers (build reports, structure rollups, the
        construction benchmarks) never pay the upload — and build wall time
        measures the build, not the transfer.  First search/ingest uploads.
        """
        if self._device is None:
            self._device = device_forest(
                self.forest, quantize=self.cfg.search.quantize
            )
        return self._device

    # -- read path: planner + cached executors -------------------------------
    def _plan_key(self, k, mode, beam, kernel) -> PlanKey:
        # per-call overrides get the SAME validation the config tree does —
        # a bad k/beam/mode must fail here with an actionable error, not
        # deep inside the jitted executor (and never poison the plan cache)
        sc = self.cfg.search
        key = PlanKey(
            k=sc.k if k is None else int(k),
            mode=sc.mode if mode is None else mode,
            beam=sc.beam if beam is None else int(beam),
            kernel=sc.kernel if kernel is None else bool(kernel),
            quantize=sc.quantize,
            delta_capacity=None if self.delta is None else self.capacity,
        )
        if key.k < 1:
            raise ConfigError(f"search k={key.k} must be >= 1 neighbors")
        if key.mode not in SEARCH_MODES:
            raise ConfigError(
                f"search mode {key.mode!r} is unknown; choose one of "
                f"{', '.join(SEARCH_MODES)}"
            )
        if key.beam < 1:
            raise ConfigError(f"search beam={key.beam} must be >= 1")
        return key

    def _search_device(
        self, q, *, k=None, mode=None, beam=None, kernel=None
    ) -> tuple[Any, Any, SearchStats]:
        """Raw device triple (dists, ids, SearchStats) through the plan
        cache — the serving/benchmark path that stays on device."""
        d, i, s, _ = self._search_planned(q, k=k, mode=mode, beam=beam, kernel=kernel)
        return d, i, s

    def _search_planned(self, q, *, k=None, mode=None, beam=None, kernel=None):
        key = self._plan_key(k, mode, beam, kernel)
        plan = self.plans.plan(key)
        plan.calls += 1
        delta = None if self.delta is None else delta_view(self.delta)
        d, i, s = plan.executor(self.device, jnp.asarray(q, jnp.float32), delta)
        return d, i, s, plan

    def search(
        self, q, *, k: int | None = None, mode: str | None = None,
        beam: int | None = None, kernel: bool | None = None,
    ) -> SearchResult:
        """kNN over forest + streaming delta.  Defaults come from
        ``cfg.search``; per-call overrides select (or create) the matching
        cached ``SearchPlan``.  Returns a host-side ``SearchResult``."""
        d, i, s, plan = self._search_planned(
            q, k=k, mode=mode, beam=beam, kernel=kernel
        )
        d, i = np.asarray(d), np.asarray(i)
        kk = min(plan.key.k, self.n_total)  # Def. 4: |X| <= k -> whole set
        if d.shape[1] > kk:
            d, i = d[:, :kk], i[:, :kk]
        return SearchResult(dists=d, ids=i, stats=stats_to_host(s), plan=plan)

    # -- write path ----------------------------------------------------------
    def _ensure_delta(self) -> None:
        if self.delta is None:
            self.delta = alloc_delta(self.forest, self.capacity)
            self.monitor = self._make_monitor()

    def _make_monitor(self):
        from repro.stream.maintenance import OverlapMonitor

        needs_x = get_overlap_method(self.cfg.stream.monitor_method).needs_objects
        return OverlapMonitor(
            self.forest, self._maint_cfg(), x=self.x_all if needs_x else None
        )

    def _maint_cfg(self):
        from repro.stream.maintenance import MaintenanceConfig

        s = self.cfg.stream
        return MaintenanceConfig(
            method=s.monitor_method,
            xi_rebuild=s.xi_rebuild,
            drift_margin=s.drift_margin,
            fill_rebuild=s.fill_rebuild,
            pivot_method=s.pivot_method,
            c_max=s.c_max,
            seed=s.seed,
        )

    def ingest(self, xb) -> np.ndarray:
        """Insert a batch; returns the assigned global object ids.

        Chunks the batch to the per-index buffer capacity so a forced
        maintenance pass (emptying the destination buffers) always makes the
        retry succeed — ingestion cannot silently drop or livelock.
        """
        self._ensure_delta()
        xb = np.asarray(xb, np.float32)
        if xb.ndim != 2 or xb.shape[1] != self.forest.bucket_x.shape[2]:
            raise ConfigError(
                f"ingest batch must be (B, {self.forest.bucket_x.shape[2]}), "
                f"got shape {xb.shape}"
            )
        ids = np.arange(self.n_total, self.n_total + len(xb), dtype=np.int64)
        self._x_parts.append(xb)
        self.n_total += len(xb)
        self._x_cache = None
        for lo in range(0, len(xb), self.capacity):
            self._ingest_chunk(
                xb[lo : lo + self.capacity], ids[lo : lo + self.capacity]
            )
        return ids

    def _ingest_chunk(self, xc: np.ndarray, ic: np.ndarray) -> None:
        # Termination argument: a round that rejects any point force-rebuilds
        # every rejecting index, emptying its buffer into the main structure.
        # A retried point (chunk size <= buffer capacity) can only be
        # rejected again by re-routing to a DIFFERENT still-full buffer, and
        # each round empties at least one of those — so at most n_indexes
        # rounds before every point is accepted.  Retries flip the ``valid``
        # mask instead of slicing the batch, so every round reuses one
        # compiled ingest program (shapes never depend on the reject count).
        xj, ij = jnp.asarray(xc), jnp.asarray(ic)
        pending = np.ones(len(xc), bool)
        for _ in range(self.forest.n_indexes + 1):
            self.delta, acc = ingest(
                self.device, self.delta, xj, ij, valid=jnp.asarray(pending)
            )
            pending &= ~np.asarray(acc)
            if not pending.any():
                return
            # capacity hit: force-rebuild the rejecting indexes, retry rest
            meta = pull_delta_meta(self.delta)
            full = [
                i for i in range(self.forest.n_indexes) if meta["dropped"][i] > 0
            ]
            self._rebuild(full)
        raise RuntimeError(
            "ingest chunk still rejected after rebuilding every full index — "
            "invariant violation, please report"
        )

    # -- maintenance ---------------------------------------------------------
    def check(self):
        """Overlap-drift evaluation only (no rebuild) -> DriftReport."""
        self._ensure_delta()
        needs_x = get_overlap_method(self.cfg.stream.monitor_method).needs_objects
        return self.monitor.check(self.delta, x=self.x_all if needs_x else None)

    def maintain(self):
        """Run the drift monitor; rebuild + hot-swap every triggered index.

        The swap is atomic: queries see the old (device, delta) pair or the
        new pair, never a partial state.  Returns the DriftReport.
        """
        report = self.check()
        if report.triggers:
            self._rebuild(report.triggers, report)
        return report

    def _rebuild(self, triggers: list[int], report=None) -> None:
        from repro.stream.maintenance import rebuild_indexes

        if not triggers:
            return
        x_all = self.x_all
        new_forest, stats = rebuild_indexes(
            self.forest, self.delta, x_all, triggers, self._maint_cfg()
        )
        # Survivors — delta members of indexes NOT rebuilt — keep their
        # original buffers wholesale: a kept index keeps its center, so the
        # old buffer's pivot/radius bound is still valid verbatim.  A pure
        # device-side select (no host round-trip, no re-routing) that BY
        # CONSTRUCTION cannot overflow: each kept buffer moves into a fresh
        # buffer of the same capacity.  Rebuilt indexes start empty (their
        # members were absorbed into the new trees); ``dropped`` resets —
        # rejected points were never stored and their owners retry them.
        new_device = device_forest(new_forest, quantize=self.cfg.search.quantize)
        fresh = alloc_delta(new_forest, self.capacity)
        keep = np.ones(self.forest.n_indexes, bool)
        keep[list(triggers)] = False
        n_migrated = int(np.asarray(self.delta.count)[keep].sum())
        kj = jnp.asarray(keep)
        old = self.delta
        new_delta = fresh._replace(
            x=jnp.where(kj[:, None, None], old.x, fresh.x),
            ids=jnp.where(kj[:, None], old.ids, fresh.ids),
            count=jnp.where(kj, old.count, fresh.count),
            pivot=jnp.where(kj[:, None], old.pivot, fresh.pivot),
            radius=jnp.where(kj, old.radius, fresh.radius),
            sum_x=jnp.where(kj[:, None], old.sum_x, fresh.sum_x),
        )

        # ---- atomic swap: a query sees the old pair or the new pair --------
        self.forest, self._device, self.delta = new_forest, new_device, new_delta
        self.monitor = self._make_monitor()
        stats["triggers"] = list(triggers)
        stats["reasons"] = dict(report.reasons) if report is not None else {}
        stats["n_migrated"] = n_migrated
        self.rebuild_log.append(stats)

    # -- persistence ---------------------------------------------------------
    def save(self, path) -> str:
        """Serialize the WHOLE index (forest + host trees + delta + config +
        dataset) to one .npz; returns the path written.  A ``load`` of that
        file serves bitwise-identical searches without rebuilding."""
        return persist.save_state(self, path)

    @classmethod
    def load(cls, path) -> "OverlapIndex":
        """Rebuild-free restart from ``save`` output."""
        st = persist.load_state(path)
        return cls._wire(
            np.asarray(st["x_all"], np.float32),
            st["forest"],
            st["cfg"],
            st["build_report"],
            n_total=st["n_total"],
            delta=st["delta"],
            capacity=st["capacity"],
            rebuild_log=st["rebuild_log"],
            monitor_baseline=st["monitor_baseline"],
        )

    # -- serving -------------------------------------------------------------
    def to_datastore(
        self, values, *, stream_capacity: int = 0, quantized: bool | None = None
    ):
        """Wrap this index as a kNN-LM serving ``ForestDatastore``.

        ``values[i]`` is the token paired with object id ``i`` — one value
        per object currently in the index (``n_total``).  A live streaming
        delta rides along (its members stay retrievable and serve-side
        ``ingest_keys`` appends into the same buffers).  ``stream_capacity``
        preallocates a values tail for that many FUTURE serve-side inserts;
        ``quantized`` overrides ``cfg.search.quantize`` for the datastore's
        bucket storage.
        """
        from repro.serve.retrieval import datastore_from_index

        return datastore_from_index(
            self, values, stream_capacity=stream_capacity, quantized=quantized
        )

    # -- introspection -------------------------------------------------------
    def structure(self) -> dict[str, Any]:
        """aggregate_structure + live delta occupancy (always fresh)."""
        s = self.forest.aggregate_structure()
        if self.delta is not None:
            s["delta_fill"] = np.asarray(self.delta.count).tolist()
        else:
            s["delta_fill"] = [0] * self.forest.n_indexes
        s["delta_capacity"] = self.capacity
        s["n_objects"] = self.n_total
        s["rebuilds"] = self.forest.build_stats.get("rebuilds", 0)
        return s

    def __repr__(self) -> str:
        return (
            f"OverlapIndex(n={self.n_total}, indexes={self.forest.n_indexes}, "
            f"buckets={self.forest.n_buckets}, method={self.cfg.index.method!r}, "
            f"delta={'on' if self.delta is not None else 'off'}, "
            f"plans={len(self.plans)})"
        )
