"""Search planning: one cached, jitted executor per static-option tuple.

``knn_search`` used to be one big ``jax.jit`` whose cache was invisible —
every caller paid tracing whenever *any* static knob or operand shape moved,
and nobody could observe it.  The facade splits that into

  * ``PlanKey``     — the static options a compiled executor is specialized
                      on: ``(k, mode, beam, kernel, quantize, delta
                      capacity)``;
  * ``SearchPlan``  — the key plus a ``jax.jit``-wrapped closure over
                      ``core.knn.knn_search_impl`` with those options baked
                      in, and a *trace counter* (incremented only while
                      tracing, so tests can assert "no re-trace");
  * ``PlanCache``   — the per-index table of plans with hit/miss counters.

Repeated ``OverlapIndex.search`` calls with stable options and shapes hit
the same plan and the same compiled executable: zero re-tracing.  A changed
query-batch shape re-specializes *within* the plan (jax's shape cache, the
trace counter records it); a changed option is a new plan.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple

import jax
import numpy as np

from repro.core.knn import DeltaView, DeviceForest, SearchStats, knn_search_impl


class PlanKey(NamedTuple):
    """Static options one compiled search executor is specialized on."""

    k: int
    mode: str
    beam: int
    kernel: bool
    quantize: bool
    delta_capacity: int | None  # None: no delta phase compiled in


@dataclass
class SearchPlan:
    """A compiled search program for one ``PlanKey``.

    ``executor(device_forest, q, delta)`` returns the raw device triple
    ``(dists, ids, SearchStats)``.  ``traces`` counts actual jax traces
    (option tuple is fixed, so a trace means a new operand shape/dtype);
    ``calls`` counts executions through this plan.
    """

    key: PlanKey
    executor: Callable[..., tuple[Any, Any, SearchStats]] = None  # set below
    traces: int = 0
    calls: int = 0


def _build_plan(key: PlanKey) -> SearchPlan:
    plan = SearchPlan(key=key)

    def _impl(forest: DeviceForest, q, delta: DeltaView | None):
        # Runs only while jax traces (compiled executions skip python):
        # the counter is exactly the number of specializations.
        plan.traces += 1
        return knn_search_impl(
            forest, q, k=key.k, mode=key.mode, beam=key.beam,
            kernel=key.kernel, delta=delta,
        )

    plan.executor = jax.jit(_impl)
    return plan


class PlanCache:
    """Per-``OverlapIndex`` table of search plans."""

    def __init__(self) -> None:
        self._plans: dict[PlanKey, SearchPlan] = {}
        self.hits = 0
        self.misses = 0

    def plan(self, key: PlanKey) -> SearchPlan:
        got = self._plans.get(key)
        if got is None:
            self.misses += 1
            got = self._plans[key] = _build_plan(key)
        else:
            self.hits += 1
        return got

    def __len__(self) -> int:
        return len(self._plans)

    def __contains__(self, key: PlanKey) -> bool:
        return key in self._plans

    def keys(self) -> tuple[PlanKey, ...]:
        return tuple(self._plans)

    def stats(self) -> dict[str, int]:
        return dict(
            plans=len(self._plans),
            hits=self.hits,
            misses=self.misses,
            traces=sum(p.traces for p in self._plans.values()),
        )


@dataclass(frozen=True)
class SearchResult:
    """Structured result of ``OverlapIndex.search``: true L2 distances,
    global object ids (-1 where fewer than k objects were reachable), and
    the paper's per-query cost instrumentation — as host numpy.

    Iterates as ``(dists, ids, stats)`` so legacy triple-unpacking keeps
    working.
    """

    dists: np.ndarray  # (Q, k')
    ids: np.ndarray  # (Q, k')
    stats: dict[str, Any]
    plan: SearchPlan = field(repr=False, compare=False, default=None)

    def __iter__(self):
        yield from (self.dists, self.ids, self.stats)

    @property
    def k(self) -> int:
        return int(self.dists.shape[1])


def stats_to_host(s: SearchStats) -> dict[str, Any]:
    """SearchStats device arrays -> the host dict shape the benchmarks and
    the legacy ``knn_search_host`` wrapper always reported."""
    return {
        "buckets_visited": np.asarray(s.buckets_visited),
        "distances": np.asarray(s.distances),
        "bound_distances": np.asarray(s.bound_distances),
        "padded_distances": np.asarray(s.padded_distances),
        "comparisons": np.asarray(s.comparisons),
        "steps": int(s.steps),
    }
