"""Search planning: one cached, jitted executor per static-option tuple.

``knn_search`` used to be one big ``jax.jit`` whose cache was invisible —
every caller paid tracing whenever *any* static knob or operand shape moved,
and nobody could observe it.  The facade splits that into

  * ``PlanKey``     — the static options a compiled executor is specialized
                      on: ``(k, mode, beam, kernel, quantize, delta
                      capacity, shards)``;
  * ``SearchPlan``  — the key plus a ``jax.jit``-wrapped closure over the
                      layout backend's executor body (the single-device
                      ``core.knn.knn_search_impl`` or the sharded
                      ``distributed/knn_island.sharded_search`` island) with
                      those options baked in, and a *trace counter*
                      (incremented only while tracing, so tests can assert
                      "no re-trace");
  * ``PlanCache``   — the per-index table of plans with hit/miss counters,
                      bounded by ``max_plans`` with LRU eviction (an
                      unbounded cache leaked one compiled executor per
                      distinct option tuple forever).

Repeated ``OverlapIndex.search`` calls with stable options and shapes hit
the same plan and the same compiled executable: zero re-tracing.  A changed
query-batch shape re-specializes *within* the plan (jax's shape cache, the
trace counter records it); a changed option is a new plan.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple

import jax
import numpy as np

from repro.core.knn import (
    DeltaView,
    DeviceForest,
    SearchStats,
    knn_search_explain_impl,
    knn_search_impl,
)


class PlanKey(NamedTuple):
    """Static options one compiled search executor is specialized on."""

    k: int
    mode: str
    beam: int
    kernel: bool
    quantize: bool
    delta_capacity: int | None  # None: no delta phase compiled in
    shards: int = 1  # device layout (1: single; >1: sharded island)
    # explain plans additionally return core.knn.VisitRows (the visited-row
    # evidence obs/attribution.py decodes); a separate plan keeps the
    # normal search executor's output contract — and its compiled
    # artifact — untouched
    explain: bool = False
    # routed layout only: the dispatch policy compiled into the executor
    # ('auto' | 'targeted' | 'all'); None on single/sharded layouts, so
    # their keys — and cached plans — are unchanged
    fanout: str | None = None


@dataclass
class SearchPlan:
    """A compiled search program for one ``PlanKey``.

    ``executor(device_forest, q, delta)`` returns the raw device 4-tuple
    ``(dists, ids, SearchStats, IslandStats | None)`` — the fourth element
    carries per-executor-island node-access counters (leading dim = shard
    count; the single layout reports one island) for the telemetry layer,
    or ``None`` on the legacy backend-less path.  Explain plans
    (``key.explain``) append a fifth element, ``core.knn.VisitRows`` — the
    per-query visited-row evidence the attribution layer decodes.  Routed
    executors (``key.fanout`` set) append one further trailing element,
    ``distributed.router.RouterStats`` — the facade unpacks by position
    from the front and treats any extra trailing element as router
    telemetry.  The first operand is whatever the backend's
    ``search_operands`` wraps (the bare forest, or (forest, table) on the
    routed layout).
    ``traces`` counts actual
    jax traces (option tuple is fixed, so a trace means a new operand
    shape/dtype); ``calls`` counts executions through this plan.
    """

    key: PlanKey
    executor: Callable[..., tuple[Any, ...]] = None  # set below
    traces: int = 0
    calls: int = 0


def _build_plan(key: PlanKey, backend=None) -> SearchPlan:
    plan = SearchPlan(key=key)
    if backend is None:
        # no layout backend (legacy/direct use): the single-device executor,
        # normalized to the 4-tuple contract (no island breakdown)
        if key.explain:
            def body(forest: DeviceForest, q, delta: DeltaView | None):
                d, i, s, rows = knn_search_explain_impl(
                    forest, q, k=key.k, mode=key.mode, beam=key.beam,
                    kernel=key.kernel, delta=delta,
                )
                return d, i, s, None, rows
        else:
            def body(forest: DeviceForest, q, delta: DeltaView | None):
                d, i, s = knn_search_impl(
                    forest, q, k=key.k, mode=key.mode, beam=key.beam,
                    kernel=key.kernel, delta=delta,
                )
                return d, i, s, None
    else:
        body = (
            backend.explain_body(key) if key.explain
            else backend.search_body(key)
        )

    def _impl(forest: DeviceForest, q, delta: DeltaView | None):
        # Runs only while jax traces (compiled executions skip python):
        # the counter is exactly the number of specializations.
        plan.traces += 1
        return body(forest, q, delta)

    plan.executor = jax.jit(_impl)
    return plan


class PlanCache:
    """Per-``OverlapIndex`` table of search plans, LRU-bounded.

    ``max_plans`` caps how many compiled executors stay alive; exceeding it
    evicts the least-recently-used plan (its executable is dropped for jax
    to GC — a re-request simply recompiles).  The default is far above any
    sane working set of option tuples, so eviction only fires on
    pathological churn (e.g. a distinct k per call)."""

    def __init__(self, max_plans: int = 64, *, registry=None) -> None:
        if max_plans < 1:
            raise ValueError(f"max_plans={max_plans} must be >= 1")
        self._plans: OrderedDict[PlanKey, SearchPlan] = OrderedDict()
        self.max_plans = max_plans
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.evicted_traces = 0  # lifetime traces of plans no longer cached
        # optional repro.obs.Registry: hit/miss/eviction counters register
        # into the owner's telemetry namespace alongside the local ints
        self._obs = registry

    def _count(self, name: str) -> None:
        if self._obs is not None:
            self._obs.counter(name).inc()

    def plan(self, key: PlanKey, backend=None) -> SearchPlan:
        got = self._plans.get(key)
        if got is None:
            self.misses += 1
            self._count("plan_cache.misses")
            got = self._plans[key] = _build_plan(key, backend)
            if len(self._plans) > self.max_plans:
                # evict least recently used — but fold its trace count into
                # the lifetime accumulator first: stats()["traces"] reports
                # compilations PAID, which eviction must not un-count
                _, evicted = self._plans.popitem(last=False)
                self.evicted_traces += evicted.traces
                self.evictions += 1
                self._count("plan_cache.evictions")
        else:
            self.hits += 1
            self._count("plan_cache.hits")
            self._plans.move_to_end(key)
        return got

    def __len__(self) -> int:
        return len(self._plans)

    def __contains__(self, key: PlanKey) -> bool:
        return key in self._plans

    def keys(self) -> tuple[PlanKey, ...]:
        return tuple(self._plans)

    def stats(self) -> dict[str, int]:
        return dict(
            plans=len(self._plans),
            max_plans=self.max_plans,
            hits=self.hits,
            misses=self.misses,
            evictions=self.evictions,
            # lifetime compilations: live plans + plans eviction dropped
            # (evicted_traces keeps the total monotone across LRU churn)
            traces=self.evicted_traces
            + sum(p.traces for p in self._plans.values()),
        )


@dataclass(frozen=True)
class SearchResult:
    """Structured result of ``OverlapIndex.search``: true L2 distances,
    global object ids (-1 where fewer than k objects were reachable), and
    the paper's per-query cost instrumentation — as host numpy.

    Iterates as ``(dists, ids, stats)`` so legacy triple-unpacking keeps
    working.
    """

    dists: np.ndarray  # (Q, k')
    ids: np.ndarray  # (Q, k')
    stats: dict[str, Any]
    plan: SearchPlan = field(repr=False, compare=False, default=None)

    def __iter__(self):
        yield from (self.dists, self.ids, self.stats)

    @property
    def k(self) -> int:
        return int(self.dists.shape[1])


def stats_to_host(s: SearchStats) -> dict[str, Any]:
    """SearchStats device arrays -> the host dict shape the benchmarks and
    the legacy ``knn_search_host`` wrapper always reported.

    ONE ``jax.device_get`` of the whole NamedTuple: per-field ``np.asarray``
    issued six blocking device->host transfers (each waiting on the same
    executor) where a single batched fetch does."""
    host = jax.device_get(s)
    return {
        "buckets_visited": host.buckets_visited,
        "distances": host.distances,
        "bound_distances": host.bound_distances,
        "padded_distances": host.padded_distances,
        "comparisons": host.comparisons,
        "steps": int(host.steps),
    }
