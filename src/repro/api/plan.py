"""Search planning: one cached, jitted executor per static-option tuple.

``knn_search`` used to be one big ``jax.jit`` whose cache was invisible —
every caller paid tracing whenever *any* static knob or operand shape moved,
and nobody could observe it.  The facade splits that into

  * ``PlanKey``     — the static options a compiled executor is specialized
                      on: ``(k, mode, beam, kernel, quantize, delta
                      capacity, shards)``;
  * ``SearchPlan``  — the key plus a ``jax.jit``-wrapped closure over the
                      layout backend's executor body (the single-device
                      ``core.knn.knn_search_impl`` or the sharded
                      ``distributed/knn_island.sharded_search`` island) with
                      those options baked in, and a *trace counter*
                      (incremented only while tracing, so tests can assert
                      "no re-trace");
  * ``PlanCache``   — the per-index table of plans with hit/miss counters,
                      bounded by ``max_plans`` with LRU eviction (an
                      unbounded cache leaked one compiled executor per
                      distinct option tuple forever).

Repeated ``OverlapIndex.search`` calls with stable options and shapes hit
the same plan and the same compiled executable: zero re-tracing.  A changed
query-batch shape re-specializes *within* the plan (jax's shape cache, the
trace counter records it); a changed option is a new plan.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple

import jax
import numpy as np

from repro.core.knn import DeltaView, DeviceForest, SearchStats, knn_search_impl


class PlanKey(NamedTuple):
    """Static options one compiled search executor is specialized on."""

    k: int
    mode: str
    beam: int
    kernel: bool
    quantize: bool
    delta_capacity: int | None  # None: no delta phase compiled in
    shards: int = 1  # device layout (1: single; >1: sharded island)


@dataclass
class SearchPlan:
    """A compiled search program for one ``PlanKey``.

    ``executor(device_forest, q, delta)`` returns the raw device triple
    ``(dists, ids, SearchStats)``.  ``traces`` counts actual jax traces
    (option tuple is fixed, so a trace means a new operand shape/dtype);
    ``calls`` counts executions through this plan.
    """

    key: PlanKey
    executor: Callable[..., tuple[Any, Any, SearchStats]] = None  # set below
    traces: int = 0
    calls: int = 0


def _build_plan(key: PlanKey, backend=None) -> SearchPlan:
    plan = SearchPlan(key=key)
    if backend is None:
        # no layout backend (legacy/direct use): the single-device executor
        def body(forest: DeviceForest, q, delta: DeltaView | None):
            return knn_search_impl(
                forest, q, k=key.k, mode=key.mode, beam=key.beam,
                kernel=key.kernel, delta=delta,
            )
    else:
        body = backend.search_body(key)

    def _impl(forest: DeviceForest, q, delta: DeltaView | None):
        # Runs only while jax traces (compiled executions skip python):
        # the counter is exactly the number of specializations.
        plan.traces += 1
        return body(forest, q, delta)

    plan.executor = jax.jit(_impl)
    return plan


class PlanCache:
    """Per-``OverlapIndex`` table of search plans, LRU-bounded.

    ``max_plans`` caps how many compiled executors stay alive; exceeding it
    evicts the least-recently-used plan (its executable is dropped for jax
    to GC — a re-request simply recompiles).  The default is far above any
    sane working set of option tuples, so eviction only fires on
    pathological churn (e.g. a distinct k per call)."""

    def __init__(self, max_plans: int = 64) -> None:
        if max_plans < 1:
            raise ValueError(f"max_plans={max_plans} must be >= 1")
        self._plans: OrderedDict[PlanKey, SearchPlan] = OrderedDict()
        self.max_plans = max_plans
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def plan(self, key: PlanKey, backend=None) -> SearchPlan:
        got = self._plans.get(key)
        if got is None:
            self.misses += 1
            got = self._plans[key] = _build_plan(key, backend)
            if len(self._plans) > self.max_plans:
                self._plans.popitem(last=False)  # evict least recently used
                self.evictions += 1
        else:
            self.hits += 1
            self._plans.move_to_end(key)
        return got

    def __len__(self) -> int:
        return len(self._plans)

    def __contains__(self, key: PlanKey) -> bool:
        return key in self._plans

    def keys(self) -> tuple[PlanKey, ...]:
        return tuple(self._plans)

    def stats(self) -> dict[str, int]:
        return dict(
            plans=len(self._plans),
            max_plans=self.max_plans,
            hits=self.hits,
            misses=self.misses,
            evictions=self.evictions,
            traces=sum(p.traces for p in self._plans.values()),
        )


@dataclass(frozen=True)
class SearchResult:
    """Structured result of ``OverlapIndex.search``: true L2 distances,
    global object ids (-1 where fewer than k objects were reachable), and
    the paper's per-query cost instrumentation — as host numpy.

    Iterates as ``(dists, ids, stats)`` so legacy triple-unpacking keeps
    working.
    """

    dists: np.ndarray  # (Q, k')
    ids: np.ndarray  # (Q, k')
    stats: dict[str, Any]
    plan: SearchPlan = field(repr=False, compare=False, default=None)

    def __iter__(self):
        yield from (self.dists, self.ids, self.stats)

    @property
    def k(self) -> int:
        return int(self.dists.shape[1])


def stats_to_host(s: SearchStats) -> dict[str, Any]:
    """SearchStats device arrays -> the host dict shape the benchmarks and
    the legacy ``knn_search_host`` wrapper always reported."""
    return {
        "buckets_visited": np.asarray(s.buckets_visited),
        "distances": np.asarray(s.distances),
        "bound_distances": np.asarray(s.bound_distances),
        "padded_distances": np.asarray(s.padded_distances),
        "comparisons": np.asarray(s.comparisons),
        "steps": int(s.steps),
    }
