"""Device-layout executor backends — the seam every layer shares.

``OverlapIndex`` does not talk to devices directly anymore: a *backend*
resolved from ``cfg.layout`` (``make_backend``) owns

  * forest upload    — ``upload_forest``: DeviceForest placement (quantized
                       per config; the sharded backend pads bucket rows to
                       a shard multiple and places them NB-sharded),
  * delta placement  — ``place_delta`` / ``logical_delta``: the facade's
                       monitor, persistence and introspection always see
                       the LOGICAL unpadded buffers, search/ingest the
                       device-resident (possibly padded + sharded) ones,
  * executor bodies  — ``search_body`` / ``ingest_body``: the un-jitted
                       callables the plan layer (api/plan.py) and the
                       facade wrap with trace counters + ``jax.jit``.  The
                       single backend wraps ``core.knn.knn_search_impl`` /
                       ``stream.ingest.ingest_impl``; the sharded backend
                       returns the ``distributed/knn_island.py`` islands.
                       Search bodies return ``(dists, ids, SearchStats,
                       IslandStats)`` — the fourth element is the telemetry
                       layer's per-island node-access breakdown (one row
                       per shard; a singleton row on the single layout),
  * swap barrier     — ``barrier``: the sharded layout blocks until every
                       shard's new arrays are materialized before a
                       maintenance rebuild swaps them in, keeping
                       ``swap_trees`` hot-swaps atomic under sharding.

Quantization order matters for exactness: the sharded upload quantizes the
UNPADDED members first (identical per-member int8 scales to the single
path) and only then pads — int8 searches stay bitwise-identical across
layouts.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api.config import ConfigError, LayoutConfig
from repro.core.forest import ForestArrays
from repro.core.knn import (
    DeviceForest,
    IslandStats,
    device_forest,
    knn_search_explain_impl,
    knn_search_impl,
)
from repro.kernels import ops as kops
from repro.stream.ingest import DeltaBuffer, ingest_impl


def _ceil_to(n: int, m: int) -> int:
    return -(-n // m) * m


class SingleDeviceBackend:
    """The default layout: whole forest + delta on one device.  Bodies are
    the core executors verbatim — zero overhead over the pre-layout code."""

    kind = "single"
    shards = 1

    def upload_forest(self, forest: ForestArrays, *, quantize: bool) -> DeviceForest:
        return device_forest(forest, quantize=quantize)

    def place_delta(self, delta: DeltaBuffer) -> DeltaBuffer:
        return delta

    def logical_delta(self, delta: DeltaBuffer, n_indexes: int) -> DeltaBuffer:
        return delta

    def search_body(self, key):
        def body(forest, q, delta):
            d, i, s = knn_search_impl(
                forest, q, k=key.k, mode=key.mode, beam=key.beam,
                kernel=key.kernel, delta=delta,
            )
            # one island: the per-island telemetry view is the fleet view
            # with a leading singleton dim (free — no extra device work)
            isl = IslandStats(
                buckets_visited=s.buckets_visited[None],
                distances=s.distances[None],
                bound_distances=s.bound_distances[None],
            )
            return d, i, s, isl

        return body

    def explain_body(self, key):
        def body(forest, q, delta):
            d, i, s, rows = knn_search_explain_impl(
                forest, q, k=key.k, mode=key.mode, beam=key.beam,
                kernel=key.kernel, delta=delta,
            )
            isl = IslandStats(
                buckets_visited=s.buckets_visited[None],
                distances=s.distances[None],
                bound_distances=s.bound_distances[None],
            )
            return d, i, s, isl, rows

        return body

    def ingest_body(self):
        return ingest_impl

    def search_operands(self, device_forest):
        """First operand the plan executor is called with.  The routed
        backend overrides this to bundle the routing table alongside the
        forest — as a traced OPERAND, so a rebuild-swapped table reaches
        already-compiled plans without retracing (a closure capture would
        bake the stale table into the executable)."""
        return device_forest

    def barrier(self, *trees) -> None:
        # single device: the facade's swap assignment is already atomic
        return None


class ShardedBackend:
    """Bucket rows + delta buffers sharded over ``shards`` devices along one
    mesh axis; executor bodies are the shard_map islands."""

    kind = "sharded"

    def __init__(self, shards: int, axis: str = "model") -> None:
        from repro.distributed import knn_island

        self.shards = int(shards)
        self.axis = axis
        self._island = knn_island
        self.mesh = knn_island.default_mesh(self.shards, axis)

    # -- placement -----------------------------------------------------------
    def _put(self, x, *, sharded: bool):
        spec = P(self.axis) if sharded else P()
        return jax.device_put(x, NamedSharding(self.mesh, spec))

    def upload_forest(self, forest: ForestArrays, *, quantize: bool) -> DeviceForest:
        nb, cap, dim = forest.bucket_x.shape
        n_idx = forest.n_indexes
        nb_pad = _ceil_to(nb, self.shards)

        # quantize BEFORE padding: per-member scales identical to the single
        # path's device_forest, so int8 results stay bitwise-identical
        bucket_x = jnp.asarray(forest.bucket_x)
        bucket_scale = None
        if quantize:
            xq, scale = kops.quantize_datastore(bucket_x.reshape(nb * cap, dim))
            bucket_x = xq.reshape(nb, cap, dim)
            bucket_scale = scale.reshape(nb, cap)

        pad = nb_pad - nb
        bucket_ids = np.asarray(forest.bucket_ids)
        bucket_mask = np.asarray(forest.bucket_mask)
        bucket_pivot = np.asarray(forest.bucket_pivot)
        bucket_radius = np.asarray(forest.bucket_radius)
        # pad buckets are owned by sentinel index I: the island extends the
        # selection table with an always-False column there, so they are
        # never eligible and never counted
        bucket_index = np.concatenate(
            [np.asarray(forest.bucket_index),
             np.full((pad,), n_idx, np.int32)]
        )
        if pad:
            bucket_x = jnp.concatenate(
                [bucket_x, jnp.zeros((pad, cap, dim), bucket_x.dtype)]
            )
            bucket_ids = np.concatenate(
                [bucket_ids, np.full((pad, cap), -1, bucket_ids.dtype)]
            )
            bucket_mask = np.concatenate(
                [bucket_mask, np.zeros((pad, cap), bool)]
            )
            bucket_pivot = np.concatenate(
                [bucket_pivot, np.zeros((pad, dim), bucket_pivot.dtype)]
            )
            bucket_radius = np.concatenate(
                [bucket_radius, np.zeros((pad,), bucket_radius.dtype)]
            )
            if bucket_scale is not None:
                bucket_scale = jnp.concatenate(
                    [bucket_scale, jnp.ones((pad, cap), bucket_scale.dtype)]
                )
        return DeviceForest(
            index_centers=self._put(
                np.asarray(forest.index_centers), sharded=False
            ),
            index_radii=self._put(np.asarray(forest.index_radii), sharded=False),
            neighbors=self._put(np.asarray(forest.neighbors), sharded=False),
            bucket_x=self._put(bucket_x, sharded=True),
            bucket_ids=self._put(bucket_ids, sharded=True),
            bucket_mask=self._put(bucket_mask, sharded=True),
            bucket_pivot=self._put(bucket_pivot, sharded=True),
            bucket_radius=self._put(bucket_radius, sharded=True),
            bucket_index=self._put(bucket_index, sharded=True),
            bucket_scale=(
                None if bucket_scale is None
                else self._put(bucket_scale, sharded=True)
            ),
        )

    def place_delta(self, delta: DeltaBuffer) -> DeltaBuffer:
        n_idx = delta.count.shape[0]
        pad = _ceil_to(n_idx, self.shards) - n_idx

        def leaf(x):
            x = jnp.asarray(x)
            if pad:
                # pad rows stay empty forever: count=0 makes them ineligible
                # for search and routing only emits real index ids
                x = jnp.concatenate(
                    [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)]
                )
            return self._put(x, sharded=True)

        return DeltaBuffer(*[leaf(x) for x in delta])

    def logical_delta(self, delta: DeltaBuffer, n_indexes: int) -> DeltaBuffer:
        return DeltaBuffer(*[x[:n_indexes] for x in delta])

    # -- executor bodies -----------------------------------------------------
    def search_body(self, key):
        def body(forest, q, delta):
            return self._island.sharded_search(
                self.mesh, self.axis, forest, q, delta,
                k=key.k, mode=key.mode, beam=key.beam, kernel=key.kernel,
                per_island=True,
            )

        return body

    def explain_body(self, key):
        def body(forest, q, delta):
            return self._island.sharded_search(
                self.mesh, self.axis, forest, q, delta,
                k=key.k, mode=key.mode, beam=key.beam, kernel=key.kernel,
                per_island=True, explain=True,
            )

        return body

    def ingest_body(self):
        def body(centers, delta, xb, ids, valid):
            return self._island.sharded_ingest(
                self.mesh, self.axis, centers, delta, xb, ids, valid
            )

        return body

    def search_operands(self, device_forest):
        return device_forest

    def barrier(self, *trees) -> None:
        """Block until every shard of the given trees is materialized —
        called right before a maintenance rebuild's hot swap, so a
        concurrent query can never observe a half-placed forest/delta."""
        jax.block_until_ready(trees)


class RoutedBackend(ShardedBackend):
    """The sharded layout plus the routing tier (distributed/router/):
    a replicated :class:`~repro.distributed.router.RoutingTable` rebuilt at
    every forest upload (build, load — including the host-count clamp —
    and maintenance rebuild swaps all funnel through ``upload_forest``),
    and executor bodies that run ``routed_search`` instead of the bare
    island.  Search bodies append ``RouterStats`` to the island tuple."""

    kind = "routed"

    def __init__(self, shards: int, axis: str = "model", *, routing=None):
        from repro.api.config import RoutingConfig
        from repro.distributed import router

        super().__init__(shards, axis)
        self.routing = routing if routing is not None else RoutingConfig()
        self._router = router
        self.table = None  # replicated device RoutingTable

    def upload_forest(self, forest: ForestArrays, *, quantize: bool) -> DeviceForest:
        dev = super().upload_forest(forest, quantize=quantize)
        self.refresh_table(forest, quantize=quantize)
        return dev

    def refresh_table(self, forest: ForestArrays, *, quantize: bool = False) -> None:
        """(Re)build the routing table from the LOGICAL forest and replicate
        it across the mesh.  Must run on every swap that can move bucket
        ownership — a stale table must never silently mis-route.  An int8
        layout (``quantize``) gets covers around the dequantized members,
        matching the distances its scans actually compute."""
        tab = self._router.build_routing_table(
            forest, self.shards, method=self.routing.overlap_method,
            quantize=quantize,
        )
        self.table = jax.device_put(tab, NamedSharding(self.mesh, P()))

    def search_operands(self, device_forest):
        return (device_forest, self.table)

    def search_body(self, key):
        fanout = key.fanout or self.routing.fanout

        def body(operands, q, delta):
            forest, table = operands
            return self._router.routed_search(
                self.mesh, self.axis, forest, q, delta, table,
                k=key.k, mode=key.mode, beam=key.beam, kernel=key.kernel,
                fanout=fanout, per_island=True,
            )

        return body

    def explain_body(self, key):
        fanout = key.fanout or self.routing.fanout

        def body(operands, q, delta):
            forest, table = operands
            return self._router.routed_search(
                self.mesh, self.axis, forest, q, delta, table,
                k=key.k, mode=key.mode, beam=key.beam, kernel=key.kernel,
                fanout=fanout, per_island=True, explain=True,
            )

        return body


def make_backend(layout: LayoutConfig, *, clamp: bool = False):
    """Resolve a ``cfg.layout`` into a backend.

    ``clamp=True`` (the ``load`` path) downgrades an unsatisfiable shard
    count to what the host has — with a warning — instead of failing: a
    snapshot saved on an 8-device host must still load on a laptop.
    Explicit builds stay strict and raise with the XLA override hint.
    """
    if layout.kind == "single":
        return SingleDeviceBackend()
    avail = jax.device_count()
    shards = layout.shards or avail
    if shards > avail:
        if not clamp:
            raise ConfigError(
                f"LayoutConfig.shards={shards} exceeds the {avail} visible "
                "device(s); on CPU force a host mesh with "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N (set "
                "before jax initializes) or lower shards"
            )
        warnings.warn(
            f"snapshot asked for {shards} shards but only {avail} device(s) "
            f"are visible; re-sharding to {avail}",
            stacklevel=2,
        )
        shards = avail
    if shards == 1:
        # one effective host: routing degenerates (every query has exactly
        # one eligible host), so both kinds collapse to the single layout
        return SingleDeviceBackend()
    if layout.kind == "routed":
        return RoutedBackend(shards, layout.axis, routing=layout.routing)
    return ShardedBackend(shards, layout.axis)
