"""npz round-trip of a whole ``OverlapIndex``: forest arrays, host tree
copies, streaming delta buffers, dataset, config, reports.

Everything a restart needs is in ONE ``np.savez`` file (``allow_pickle``
stays False — arrays plus JSON strings only), so a loaded index serves
bitwise-identical searches without rebuilding: the flattened device arrays
are restored exactly, the host-side ``FlatTree`` copies (which maintenance
rebuilds and the structure rollup need) are reassembled from concatenated
node arrays + offsets, and ``bucket_members`` is *derived* from the
flattened arrays — ``_flatten_trees`` writes buckets per tree in order, so
the (bucket_index, bucket_ids, bucket_mask) triple already encodes the
ragged member lists with no extra storage.
"""
from __future__ import annotations

import json
from typing import Any

import numpy as np

from repro.api.config import (
    Config,
    IndexConfig,
    LayoutConfig,
    RoutingConfig,
    SearchConfig,
    StreamConfig,
)
from repro.core.bccf import BuildCounters, FlatTree, TreeStructure
from repro.core.forest import ForestArrays
from repro.core.pipeline import BuildReport

FORMAT_VERSION = 1

# bucket_x is deliberately absent: every row is an exact copy of a dataset
# row (_flatten_trees does bucket_x[i, :m] = x[members], zero padding), so
# it is reconstructed bitwise from x_all + bucket_ids/bucket_mask on load —
# storing it would double the snapshot (the whole dataset again, plus pad).
_FOREST_ARRAYS = (
    "index_centers", "index_radii", "neighbors", "is_overlap_index",
    "bucket_ids", "bucket_mask", "bucket_pivot",
    "bucket_radius", "bucket_index",
)
_DELTA_ARRAYS = (
    "x", "ids", "count", "pivot", "radius", "sum_x",
    "main_count", "main_sum", "main_radius", "dropped",
)


def _to_py(obj: Any) -> Any:
    """JSON fallback for numpy scalars/arrays inside report dicts."""
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):
        return obj.item()
    raise TypeError(f"not JSON-serializable: {type(obj).__name__}")


def normalize_path(path) -> str:
    path = str(path)
    return path if path.endswith(".npz") else path + ".npz"


def save_state(ix, path) -> str:
    """Serialize an ``OverlapIndex`` (duck-typed) to ``path`` (.npz)."""
    from dataclasses import asdict

    forest: ForestArrays = ix.forest
    payload: dict[str, Any] = {
        "format_version": np.int64(FORMAT_VERSION),
        "config_json": np.array(json.dumps(asdict(ix.cfg))),
        "x_all": np.asarray(ix.x_all, np.float32),
        "n_total": np.int64(ix.n_total),
        "capacity": np.int64(ix.capacity),
        "forest_c_max": np.int64(forest.c_max),
        "build_stats_json": np.array(
            json.dumps(forest.build_stats, default=_to_py)
        ),
        "rebuild_log_json": np.array(json.dumps(ix.rebuild_log, default=_to_py)),
    }
    for name in _FOREST_ARRAYS:
        payload[f"forest_{name}"] = np.asarray(getattr(forest, name))

    # host tree copies: ragged per-tree node arrays -> concat + offsets
    trees = forest.trees
    offs = np.zeros(len(trees) + 1, np.int64)
    for i, t in enumerate(trees):
        offs[i + 1] = offs[i] + len(t.node_children)
    dim = forest.bucket_x.shape[2]
    payload["tree_node_offsets"] = offs
    payload["tree_node_pivots"] = (
        np.concatenate([t.node_pivots for t in trees])
        if trees else np.zeros((0, 2, dim), np.float32)
    )
    payload["tree_node_radii"] = (
        np.concatenate([t.node_radii for t in trees])
        if trees else np.zeros((0, 2), np.float32)
    )
    payload["tree_node_children"] = (
        np.concatenate([t.node_children for t in trees])
        if trees else np.zeros((0, 2), np.int32)
    )
    payload["tree_counters"] = np.array(
        [[t.counters.distances, t.counters.comparisons] for t in trees],
        np.int64,
    ).reshape(len(trees), 2)
    payload["tree_structure_json"] = np.array(json.dumps([
        dict(
            n_internal=t.structure.n_internal,
            n_leaves=t.structure.n_leaves,
            height=t.structure.height,
            bucket_sizes=list(t.structure.bucket_sizes),
            nodes_per_level={str(k): v for k, v in t.structure.nodes_per_level.items()},
        )
        for t in trees
    ]))

    rep: BuildReport = ix.build_report
    payload["build_report_json"] = np.array(json.dumps(
        {
            f: getattr(rep, f)
            for f in (
                "n_objects", "n_clusters", "n_indexes", "n_overlap_indexes",
                "dbscan_distances", "overlap_distances", "tree_distances",
                "tree_comparisons", "wall_time_s", "detail",
            )
        },
        default=_to_py,
    ))

    payload["has_delta"] = np.bool_(ix.delta is not None)
    if ix.delta is not None:
        for name in _DELTA_ARRAYS:
            payload[f"delta_{name}"] = np.asarray(getattr(ix.delta, name))
        # the drift monitor's baseline matrix was captured at a specific
        # moment (last swap / first ingest); recomputing it at load over the
        # grown dataset would shift object-based (e.g. OBM) trigger
        # decisions across a restart
        payload["monitor_baseline"] = np.asarray(ix.monitor.rates_baseline)

    path = normalize_path(path)
    with open(path, "wb") as f:
        # compressed: the preallocated delta buffers are mostly zero padding
        np.savez_compressed(f, **payload)
    return path


def load_state(path) -> dict[str, Any]:
    """Read ``path`` back into the components ``OverlapIndex.load`` wires
    up: config, dataset, forest (with host trees), delta, reports."""
    import jax.numpy as jnp

    from repro.stream.ingest import DeltaBuffer

    path = normalize_path(path)
    with np.load(path, allow_pickle=False) as z:
        version = int(z["format_version"])
        if version > FORMAT_VERSION:
            raise ValueError(
                f"{path} was written by a newer format (v{version}); this "
                f"build reads up to v{FORMAT_VERSION} — upgrade repro"
            )
        cfg_d = json.loads(str(z["config_json"]))
        # asdict flattened the nested RoutingConfig to a plain dict —
        # rebuild the dataclass (absent in pre-routing snapshots -> defaults)
        layout_d = dict(cfg_d.get("layout", {}))
        layout_d["routing"] = RoutingConfig(**layout_d.get("routing", {}))
        cfg = Config(
            index=IndexConfig(**cfg_d["index"]),
            search=SearchConfig(**cfg_d["search"]),
            stream=StreamConfig(**cfg_d["stream"]),
            # absent in pre-layout (v1 era) snapshots -> single-device
            layout=LayoutConfig(**layout_d),
        )

        forest_arrays = {n: z[f"forest_{n}"] for n in _FOREST_ARRAYS}
        bucket_index = forest_arrays["bucket_index"]
        bucket_ids = forest_arrays["bucket_ids"]
        bucket_mask = forest_arrays["bucket_mask"]
        x_all = np.asarray(z["x_all"], np.float32)
        bucket_x = x_all[np.clip(bucket_ids, 0, None)]
        bucket_x[~bucket_mask] = 0.0
        forest_arrays["bucket_x"] = bucket_x

        offs = z["tree_node_offsets"]
        piv, rad, chd = (
            z["tree_node_pivots"], z["tree_node_radii"], z["tree_node_children"]
        )
        counters = z["tree_counters"]
        structures = json.loads(str(z["tree_structure_json"]))
        trees: list[FlatTree] = []
        for gi, s in enumerate(structures):
            lo, hi = int(offs[gi]), int(offs[gi + 1])
            members = [
                bucket_ids[b][bucket_mask[b]].astype(np.int64)
                for b in np.flatnonzero(bucket_index == gi)
            ]
            trees.append(FlatTree(
                node_pivots=piv[lo:hi],
                node_radii=rad[lo:hi],
                node_children=chd[lo:hi],
                bucket_members=members,
                structure=TreeStructure(
                    n_internal=s["n_internal"],
                    n_leaves=s["n_leaves"],
                    height=s["height"],
                    bucket_sizes=list(s["bucket_sizes"]),
                    nodes_per_level={int(k): v for k, v in s["nodes_per_level"].items()},
                ),
                counters=BuildCounters(
                    distances=int(counters[gi, 0]),
                    comparisons=int(counters[gi, 1]),
                ),
            ))

        forest = ForestArrays(
            c_max=int(z["forest_c_max"]),
            trees=trees,
            build_stats=json.loads(str(z["build_stats_json"])),
            **forest_arrays,
        )

        delta = None
        monitor_baseline = None
        if bool(z["has_delta"]):
            delta = DeltaBuffer(
                **{n: jnp.asarray(z[f"delta_{n}"]) for n in _DELTA_ARRAYS}
            )
            monitor_baseline = z["monitor_baseline"]

        rep_d = json.loads(str(z["build_report_json"]))
        report = BuildReport(config=cfg.index, **rep_d)

        return dict(
            cfg=cfg,
            x_all=x_all,
            n_total=int(z["n_total"]),
            capacity=int(z["capacity"]),
            forest=forest,
            delta=delta,
            monitor_baseline=monitor_baseline,
            build_report=report,
            rebuild_log=json.loads(str(z["rebuild_log_json"])),
        )
