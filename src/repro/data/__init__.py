from repro.data.pipeline import DataConfig, TokenPipeline, make_batch_specs
from repro.data.synthetic import tracking_like, ward_like

__all__ = ["DataConfig", "TokenPipeline", "make_batch_specs", "tracking_like", "ward_like"]
