"""Synthetic IoT-style datasets mirroring the paper's two benchmarks.

* tracking_like — feature vectors of moving objects from an IoVT camera
  simulator [paper DB1]: 62,702 x 20, trajectory-clustered (objects move
  along smooth tracks -> dense elongated clusters + sensor noise).
* ward_like — Wearable Action Recognition Database [paper DB2]:
  1,000,000 x 5 motion-sensor windows; a small number of dense activity
  clusters with heavy within-class concentration.

Sizes are parameterized: tests/benches default to scaled-down versions,
``--full`` reproduces the paper's sizes.
"""
from __future__ import annotations

import numpy as np


def tracking_like(n: int = 62_702, dim: int = 20, seed: int = 0) -> np.ndarray:
    g = np.random.default_rng(seed)
    n_tracks = 24
    out = []
    remaining = n
    for t in range(n_tracks):
        m = remaining if t == n_tracks - 1 else max(1, int(n / n_tracks))
        remaining -= m
        start = g.normal(size=dim) * 40.0
        heading = g.normal(size=dim)
        heading /= np.linalg.norm(heading)
        ts = np.sort(g.uniform(0, 30.0, m))[:, None]
        pts = start + ts * heading * 2.0 + g.normal(size=(m, dim)) * 0.8
        out.append(pts)
    x = np.concatenate(out)[:n]
    # 3% uniform sensor-noise outliers
    k = max(1, int(0.03 * n))
    idx = g.choice(n, k, replace=False)
    x[idx] = g.uniform(x.min(), x.max(), size=(k, dim))
    return x.astype(np.float32)


def ward_like(n: int = 1_000_000, dim: int = 5, seed: int = 1) -> np.ndarray:
    g = np.random.default_rng(seed)
    n_classes = 13  # WARD's 13 activity classes
    centers = g.normal(size=(n_classes, dim)) * 25.0
    sizes = g.dirichlet(np.ones(n_classes) * 2.0)
    out = []
    for c, frac in zip(centers, sizes):
        m = max(1, int(n * frac))
        cov = g.uniform(0.5, 3.0, size=dim)
        out.append(c + g.normal(size=(m, dim)) * cov)
    x = np.concatenate(out)[:n]
    if len(x) < n:
        x = np.concatenate([x, g.normal(size=(n - len(x), dim)) * 25.0])
    return x.astype(np.float32)


def embedding_datastore(
    n: int, dim: int, *, n_clusters: int = 32, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """(keys, token_values) for the kNN-LM datastore: clustered hidden-state
    keys with associated next-token ids."""
    g = np.random.default_rng(seed)
    centers = g.normal(size=(n_clusters, dim)) * 4.0
    lab = g.integers(0, n_clusters, n)
    keys = centers[lab] + g.normal(size=(n, dim)) * 0.5
    tokens = (lab * 97 + g.integers(0, 13, n)) % 50_000
    return keys.astype(np.float32), tokens.astype(np.int32)
