"""Token data pipeline: deterministic, shardable, restart-safe.

Production properties implemented here:
* host-sharded streams — each data-parallel host draws a disjoint slice,
  indexed by (step, host) so a restart at step k reproduces the exact batch
  sequence (checkpoint stores only the step counter);
* packed LM batches (inputs/targets shifted by one);
* modality stubs (frames/patches) generated per assignment spec.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0


class TokenPipeline:
    """Synthetic-corpus LM stream (Zipfian unigram mix + ngram structure) —
    self-contained stand-in for a tokenized corpus reader with identical
    interface (``batch_at(step)``)."""

    def __init__(self, cfg: DataConfig, *, host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        self._probs = (1.0 / ranks**1.1)
        self._probs /= self._probs.sum()

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        b_local = cfg.global_batch // self.n_hosts
        g = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.host_id])
        )
        toks = g.choice(cfg.vocab_size, size=(b_local, cfg.seq_len + 1), p=self._probs)
        # inject local ngram structure so the loss is learnable
        rep = g.integers(0, cfg.seq_len // 4, size=(b_local,))
        for i, r in enumerate(rep):
            toks[i, r + 1 : r + 4] = toks[i, r]
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
        }


def make_batch_specs(model: ModelConfig, shape: ShapeConfig) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a (model, shape)
    cell — the dry-run contract (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    specs: dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["targets"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:  # decode: one new token against a seq_len cache
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    if model.family == "encdec" and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, model.encoder_seq, model.d_model), jnp.float32)
    if model.frontend == "vision_stub" and shape.kind != "decode":
        specs["patches"] = jax.ShapeDtypeStruct(
            (b, model.num_stub_patches, model.d_model), jnp.float32)
    return specs


def materialize_batch(model: ModelConfig, shape: ShapeConfig, seed: int = 0) -> dict:
    """Small-scale concrete batch matching make_batch_specs (for examples)."""
    g = np.random.default_rng(seed)
    out = {}
    for k, spec in make_batch_specs(model, shape).items():
        if spec.dtype == jnp.int32:
            out[k] = g.integers(0, model.vocab_size, spec.shape).astype(np.int32)
        else:
            out[k] = (g.normal(size=spec.shape) * 0.1).astype(np.float32)
    return out
