from repro.optim.optimizer import (
    Optimizer,
    adafactor,
    adamw,
    clip_by_global_norm,
    get_optimizer,
)
from repro.optim.schedule import cosine_with_warmup

__all__ = [
    "Optimizer", "adafactor", "adamw", "clip_by_global_norm", "get_optimizer",
    "cosine_with_warmup",
]
