"""Optimizers (pure-pytree, optax-style init/update pairs).

* adamw     — fp32 moments; small/medium models.
* adafactor — factored second moment (row/col statistics), no first moment:
              O(d) state instead of O(d^2)-ish, the standard choice for the
              200B+ configs where full Adam state would not fit 16 GB HBM
              even fully sharded.

State trees inherit the parameter shardings (ZeRO-style) — see
train/train_step.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, Array], tuple[PyTree, PyTree]]
    # update(grads, state, params, lr) -> (new_params, new_state)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1**t
        c2 = 1.0 - b2**t

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * gf * gf
            mh = m / c1
            vh = v / c2
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"mu": new_m, "nu": new_v, "step": step}

    return Optimizer(init, update)


def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8, weight_decay: float = 0.0) -> Optimizer:
    """Factored Adafactor (Shazeer & Stern 2018), no momentum."""

    def _factored(shape) -> bool:
        return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1

    def init(params):
        def leaf(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "v": jax.tree.map(leaf, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** -decay

        def upd(g, v, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if _factored(p.shape):
                vr = beta * v["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(axis=-2)
                rfac = (vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps))[..., None]
                u = gf * jax.lax.rsqrt(jnp.maximum(rfac * vc[..., None, :], eps))
                nv = {"vr": vr, "vc": vc}
            else:
                vv = beta * v["v"] + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(jnp.maximum(vv, eps))
                nv = {"v": vv}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            pf = p.astype(jnp.float32)
            if weight_decay:
                u = u + weight_decay * pf
            return (pf - lr * u).astype(p.dtype), nv

        flat, tdef = jax.tree.flatten(params)
        gflat = tdef.flatten_up_to(grads)
        vflat = tdef.flatten_up_to(state["v"])
        outs = [upd(g, v, p) for g, v, p in zip(gflat, vflat, flat)]
        new_p = tdef.unflatten([o[0] for o in outs])
        new_v = tdef.unflatten([o[1] for o in outs])
        return new_p, {"v": new_v, "step": step}

    return Optimizer(init, update)


def get_optimizer(name: str) -> Optimizer:
    if name == "adamw":
        return adamw()
    if name == "adafactor":
        return adafactor()
    raise ValueError(f"unknown optimizer {name!r}")
