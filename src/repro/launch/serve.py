"""Serving launcher: retrieval-augmented batched decoding.

    python -m repro.launch.serve --arch qwen2-0.5b --requests 16 [--no-retrieval]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import RetrievalConfig
from repro.data.synthetic import embedding_datastore
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine
from repro.serve.retrieval import build_flat_datastore


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--smoke-model", action="store_true", default=True)
    ap.add_argument("--full-size-model", dest="smoke_model", action="store_false")
    ap.add_argument("--no-retrieval", action="store_true")
    ap.add_argument("--quantized-datastore", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke_model else get_config(args.arch)
    if not args.no_retrieval:
        cfg = cfg.replace(retrieval=RetrievalConfig(
            enabled=True, k=8, lam=0.25, datastore_size=8192,
            quantized=args.quantized_datastore))
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    ds = None
    if not args.no_retrieval:
        keys, values = embedding_datastore(8192, cfg.d_model)
        ds = build_flat_datastore(keys, values % cfg.vocab_size,
                                  quantized=args.quantized_datastore)

    engine = ServeEngine(model, params, num_slots=args.slots,
                         max_len=args.max_len, datastore=ds)
    g = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        engine.submit(Request(
            rid=rid,
            prompt=g.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32),
            max_new_tokens=args.new_tokens))
    finished = engine.run()
    dt = time.perf_counter() - t0
    tok = sum(len(r.out_tokens) for r in finished)
    lat = [r.latency_s for r in finished]
    print(f"{len(finished)} requests, {tok} tokens, {dt:.1f}s "
          f"({tok/dt:.1f} tok/s incl. compile), "
          f"p50 latency {np.median(lat):.2f}s, retrieval={'off' if args.no_retrieval else 'on'}")


if __name__ == "__main__":
    main()
