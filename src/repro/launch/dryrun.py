import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first initialization) — do not move them.

"""Multi-pod dry-run: prove the distribution config is coherent.

(No ``from __future__ import annotations`` here: the XLA_FLAGS lines above
must stay the first statements of the module.)

For every (architecture x input-shape) cell and mesh:
  jit(step).lower(**abstract inputs).compile()
succeeds, and we record memory_analysis / cost_analysis / collective traffic
into experiments/dryrun/<arch>_<shape>_<mesh>.json — the roofline analysis
(benchmarks/roofline.py, EXPERIMENTS.md) reads these artifacts.

Usage:
  python -m repro.launch.dryrun --arch qwen3-moe-235b-a22b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import make_batch_specs
from repro.distributed import context as dctx
from repro.distributed import sharding as shd
from repro.distributed.estimator import _local_bytes, estimate_memory_bytes
from repro.distributed.hlo_analysis import roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.optim.optimizer import get_optimizer
from repro.optim.schedule import cosine_with_warmup
from repro.serve.retrieval import Datastore
from repro.train.train_step import make_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _struct(tree, shardings):
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        tree, shardings,
    )


def _abstract_datastore(cfg: ModelConfig, mesh) -> tuple[Datastore, Datastore]:
    """Retrieval datastore stand-in, sharded over 'model' (struct, shardings)."""
    r = cfg.retrieval
    tp = dctx.model_axis_size(mesh)
    n = r.datastore_size * tp
    kd = r.key_dim or cfg.d_model
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    row = NamedSharding(mesh, P("model"))
    row2 = NamedSharding(mesh, P("model", None))
    rep = NamedSharding(mesh, P())
    key_dtype = jnp.int8 if r.quantized else jnp.float32
    ds = Datastore(
        keys=jax.ShapeDtypeStruct((n, kd), key_dtype, sharding=row2),
        values=jax.ShapeDtypeStruct((n,), jnp.int32, sharding=row),
        scale=(jax.ShapeDtypeStruct((n,), jnp.float32, sharding=row)
               if r.quantized else None),
        proj=jax.ShapeDtypeStruct((cfg.d_model, kd), jnp.float32, sharding=rep)
        if kd != cfg.d_model else None,
    )
    return ds


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Returns (fn, arg_structs, meta) for one cell."""
    model = Model(cfg)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    pshard = shd.param_shardings(params_shape, mesh)
    meta = {"params_local": _local_bytes(params_shape, pshard),
            "opt_local": 0, "cache_local": 0, "datastore_local": 0}
    batch_specs = make_batch_specs(cfg, shape)
    batch_structs = {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=shd.batch_spec(mesh, v.shape))
        for k, v in batch_specs.items()
    }

    if shape.kind == "train":
        opt = get_optimizer(cfg.optimizer)
        step_fn = make_train_step(
            model, opt, cosine_with_warmup(3e-4, 100, 10_000),
            grad_dtype="bfloat16" if cfg.param_dtype == "bfloat16" else "float32",
        )
        opt_shape = jax.eval_shape(opt.init, params_shape)
        oshard = shd.param_shardings(opt_shape, mesh)
        state_struct = {
            "params": _struct(params_shape, pshard),
            "opt": _struct(opt_shape, oshard),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        fn = jax.jit(step_fn, donate_argnums=(0,))
        meta["opt_local"] = _local_bytes(opt_shape, oshard)
        return fn, (state_struct, batch_structs), meta

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            logits, cache = model.prefill(params, batch, max_len=shape.seq_len)
            return logits[:, -1, :], cache

        fn = jax.jit(prefill_fn)
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        meta["cache_local"] = _local_bytes(cache_shape, shd.cache_shardings(cache_shape, mesh))
        return fn, (_struct(params_shape, pshard), batch_structs), meta

    # decode: one token against a full-length cache, retrieval enabled
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    cshard = shd.cache_shardings(cache_shape, mesh)
    cache_struct = _struct(cache_shape, cshard)
    ds = _abstract_datastore(cfg, mesh)

    def decode_fn(params, tokens, cache, pos, datastore):
        return model.decode_step(params, tokens, cache, pos, datastore=datastore)

    fn = jax.jit(decode_fn, donate_argnums=(2,))
    pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
    meta["cache_local"] = _local_bytes(cache_shape, cshard)
    ds_leaves = [x for x in (ds.keys, ds.values, ds.scale, ds.proj) if x is not None]
    meta["datastore_local"] = sum(
        x.size * x.dtype.itemsize for x in ds_leaves) // dctx.model_axis_size(mesh)
    return fn, (_struct(params_shape, pshard), batch_structs["tokens"],
                cache_struct, pos_struct, ds), meta


# ---------------------------------------------------------------------------
# Perf-iteration variants (§Perf hillclimbing): named config/rule mutations.
# 'baseline' is the paper-faithful / naive-TP configuration.
# ---------------------------------------------------------------------------

def _v_baseline(cfg):
    return cfg


def _v_seqtp(cfg):
    """Sequence-parallel TP: pin sub-layer outputs seq-sharded so the TP
    combine lowers as reduce-scatter instead of all-reduce."""
    return cfg.replace(constrain_sublayer_outputs=True, seq_shard_activations=True)


def _v_zero3(cfg):
    """Pure ZeRO-3: no tensor parallelism; params FSDP over every mesh axis.
    Collectives become per-layer weight all-gathers + grad reduce-scatters."""
    shd.set_rule("heads", ())
    shd.set_rule("mlp", ())
    shd.set_rule("vocab", ())
    shd.set_rule("tensor", ())
    shd.set_rule("fsdp", ("pod", "data", "model"))
    return cfg.replace(seq_shard_activations=False, constrain_sublayer_outputs=False)


def _v_zero3_seqtp(cfg):
    """ZeRO-3 weights + seq-sharded activation residuals."""
    cfg = _v_zero3(cfg)
    return cfg.replace(seq_shard_activations=True, constrain_sublayer_outputs=True)


def _v_ga16(cfg):
    return cfg.replace(grad_accum=16)


def _v_noremat(cfg):
    return cfg.replace(remat="none")


def _v_quantized_ds(cfg):
    r = cfg.retrieval
    return cfg.replace(retrieval=r.__class__(
        enabled=r.enabled, k=r.k, lam=r.lam, temperature=r.temperature,
        datastore_size=r.datastore_size, key_dim=r.key_dim, quantized=True))


def _v_servetp(cfg):
    """Inference sharding: weights replicated over the batch axes (no FSDP
    gathers on the decode path), TP kept.  Weights-fit precondition checked
    by the memory model in the record."""
    shd.set_rule("fsdp", ())
    return cfg


def _v_servetp_int8(cfg):
    return _v_quantized_ds(_v_servetp(cfg))


def _v_zero3v(cfg):
    """ZeRO-3 + seq-sharded residuals, but vocab/logits stay TP-sharded
    (unsharded logits at 102k vocab re-introduce huge replicated tensors)."""
    cfg = _v_zero3_seqtp(cfg).replace(grad_accum=1)
    shd.set_rule("vocab", ("model",))
    return cfg


def _v_a2amoe(cfg):
    """All-to-all EP dispatch: tokens travel to expert shards instead of
    replicating compute over 'model' + psumming full (T_loc, D)."""
    return cfg.replace(moe_a2a=True, grad_accum=1,
                       seq_shard_activations=True, constrain_sublayer_outputs=True)


VARIANTS = {
    "baseline": _v_baseline,
    "zero3v-ga1": _v_zero3v,
    "a2amoe-ga1": _v_a2amoe,
    "seqtp": _v_seqtp,
    "zero3": _v_zero3,
    "zero3-seqtp": _v_zero3_seqtp,
    "zero3-seqtp-ga1": lambda c: _v_zero3_seqtp(c).replace(grad_accum=1),
    "seqtp-ga2": lambda c: _v_seqtp(c).replace(grad_accum=2),
    "seqtp-ga1": lambda c: _v_seqtp(c).replace(grad_accum=1),
    "ga16": _v_ga16,
    "ga2": lambda c: c.replace(grad_accum=2),
    "ga1": lambda c: c.replace(grad_accum=1),
    "noremat": _v_noremat,
    "int8ds": _v_quantized_ds,
    "servetp": _v_servetp,
    "servetp-int8ds": _v_servetp_int8,
}


def _reset_rules() -> None:
    shd.set_rule("heads", ("model",))
    shd.set_rule("mlp", ("model",))
    shd.set_rule("vocab", ("model",))
    shd.set_rule("tensor", ("model",))
    shd.set_rule("fsdp", ("pod", "data"))
    shd.set_rule("seq", ())


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             variant: str = "baseline") -> dict:
    _reset_rules()  # variants mutate the rule table
    cfg = get_config(arch)
    if shape_name in ("decode_32k", "long_500k"):
        cfg = cfg.replace(retrieval=cfg.retrieval.__class__(
            enabled=True, k=8, datastore_size=16384, key_dim=512))
    cfg = VARIANTS[variant](cfg)
    shape = SHAPES[shape_name]
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "variant": variant}
    if not shape_applicable(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = "long_500k requires sub-quadratic attention (DESIGN.md §5)"
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    with dctx.use_mesh(mesh):
        if cfg.seq_shard_activations:
            shd.set_rule("seq", ("model",))
        else:
            shd.set_rule("seq", ())
        fn, args, meta = build_cell(cfg, shape, mesh)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0) or
                              getattr(mem, "temp_size_in_bytes", 0)),
            "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        }
    except Exception as e:  # CPU backend may not implement it
        rec["memory"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float)) and
                       k in ("flops", "bytes accessed", "transcendentals",
                             "bytes accessed output", "optimal_seconds")}
    except Exception as e:
        rec["cost"] = {"error": str(e)}
    # Trip-count-aware whole-program costs parsed from the partitioned HLO
    # (XLA CPU cost_analysis counts while bodies once — hlo_cost re-folds the
    # call graph with scan trip counts; see distributed/hlo_cost.py).
    from repro.distributed.hlo_cost import analyze_module

    mc = analyze_module(compiled.as_text())
    rec["hlo_cost"] = {
        "flops": mc.flops,
        "bytes": mc.bytes,
        "collective_bytes": mc.coll_bytes,
        "collective_by_op": mc.coll_by_op,
        "n_while": mc.n_while,
        "trip_counts": mc.trip_counts,
    }
    mem_model = estimate_memory_bytes(
        cfg, shape, mesh,
        params_local=meta["params_local"], opt_local=meta["opt_local"],
        cache_local=meta["cache_local"], datastore_local=meta["datastore_local"])
    rec["memory_model"] = mem_model
    rec["local_bytes"] = dict(meta)
    # memory term: analytic HBM-traffic model (the HLO byte count measures
    # CPU-module fusion boundaries — a pessimistic bound; both recorded).
    rec["roofline"] = roofline_terms(mc.flops, mem_model["total"], mc.coll_bytes)
    rec["roofline_hlo_bytes"] = roofline_terms(mc.flops, mc.bytes, mc.coll_bytes)
    rec["status"] = "ok"
    rec["devices"] = n_dev
    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)
    # analytic parameter counts + MODEL_FLOPS (6 N D) for the useful-compute ratio
    cfg_model = Model(cfg)
    pshape = jax.eval_shape(lambda: cfg_model.init(jax.random.key(0)))
    total_param_bytes = 0
    n_total = 0
    n_expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(pshape)[0]:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        total_param_bytes += leaf.size * leaf.dtype.itemsize
        n_total += leaf.size
        if "/moe/w_" in pstr and "/shared/" not in pstr:
            n_expert += leaf.size
    if cfg.moe is not None:
        n_active = (n_total - n_expert) + n_expert * cfg.moe.top_k / cfg.moe.num_experts
    else:
        n_active = n_total
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    model_flops_global = mult * n_active * tokens
    rec["params_total"] = int(n_total)
    rec["params_active"] = int(n_active)
    rec["param_bytes_total"] = int(total_param_bytes)
    rec["param_bytes_per_device_fsdp"] = int(total_param_bytes // n_dev)
    rec["model_flops_global"] = model_flops_global
    rec["model_flops_per_device"] = model_flops_global / n_dev
    hlo_flops = rec.get("hlo_cost", {}).get("flops", 0.0)
    rec["useful_compute_ratio"] = (
        rec["model_flops_per_device"] / hlo_flops if hlo_flops else None
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--variant", choices=list(VARIANTS), default="baseline")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                cells.append((arch, shape, mesh_kind))

    failures = 0
    for arch, shape, mesh_kind in cells:
        tag = f"{arch}_{shape}_{mesh_kind}"
        if args.variant != "baseline":
            tag += f"__{args.variant}"
        out_file = out_dir / f"{tag}.json"
        try:
            rec = run_cell(arch, shape, mesh_kind, args.variant)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                   "variant": args.variant,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-4000:]}
            failures += 1
        out_file.write_text(json.dumps(rec, indent=2))
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" compute={r['compute_s']:.3e}s mem={r['memory_s']:.3e}s "
                     f"coll={r['collective_s']:.3e}s dom={r['dominant']}"
                     f" compile={rec['compile_s']}s")
        elif status == "error":
            extra = " " + rec["error"][:160]
        print(f"[{status:7s}] {tag}{extra}", flush=True)
    print(f"done: {len(cells)} cells, {failures} failures", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
