"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the 'pod' axis carries
pure data parallelism across the pod-interconnect (DCN), 'data' is
intra-pod FSDP, 'model' is tensor/expert parallelism on ICI.

A FUNCTION, not a module constant: importing this module never touches JAX
device state (the dry-run must set XLA_FLAGS before any device query).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (tests / examples): data-parallel only."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
