"""Production training launcher.

    python -m repro.launch.train --arch smollm-135m [--steps N] [--mesh host]

Mesh selection:
  host  — whatever devices exist locally (tests / CPU examples);
  prod  — the production (16, 16) mesh (requires 256 devices);
  auto  — elastic plan for the current device count (elastic.py), the
          restart-after-rescale path: checkpoints are logical, so resuming
          on a different fleet size re-shards automatically.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import SHAPES, get_config, get_smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed import context as dctx
from repro.distributed.elastic import plan_mesh
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import Model
from repro.optim.optimizer import get_optimizer
from repro.optim.schedule import cosine_with_warmup
from repro.train.train_step import init_train_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--mesh", default="host", choices=["host", "prod", "auto"])
    ap.add_argument("--smoke-model", action="store_true")
    ap.add_argument("--batch", type=int, default=0, help="override global batch")
    ap.add_argument("--seq", type=int, default=0, help="override seq len")
    ap.add_argument("--ckpt-dir", default="checkpoints/launch")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke_model else get_config(args.arch)
    shape = SHAPES[args.shape]
    b = args.batch or shape.global_batch
    s = args.seq or shape.seq_len

    if args.mesh == "prod":
        mesh = make_production_mesh()
    elif args.mesh == "auto":
        mesh = plan_mesh(len(jax.devices())).build()
    else:
        mesh = make_host_mesh()
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name}  batch={b} seq={s}")

    with dctx.use_mesh(mesh if args.mesh != "host" or len(jax.devices()) > 1 else None):
        model = Model(cfg)
        opt = get_optimizer(cfg.optimizer)
        step_fn = jax.jit(
            make_train_step(model, opt, cosine_with_warmup(3e-4, 100, args.steps)),
            donate_argnums=(0,),
        )
        pipeline = TokenPipeline(DataConfig(seq_len=s, global_batch=b,
                                            vocab_size=cfg.vocab_size))
        state = init_train_state(model, opt, jax.random.key(0))
        trainer = Trainer(step_fn, pipeline, TrainerConfig(
            total_steps=args.steps, ckpt_every=max(args.steps // 5, 10),
            ckpt_dir=args.ckpt_dir))
        _, report = trainer.run(state)
        print(f"finished: {len(report.losses)} steps, "
              f"final loss {report.losses[-1]:.4f}")


if __name__ == "__main__":
    main()
