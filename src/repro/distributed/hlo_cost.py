"""Trip-count-aware cost extraction from compiled HLO text.

XLA's ``compiled.cost_analysis()`` (CPU backend) counts while-loop bodies
ONCE — useless for scan-over-layers programs where >95% of work lives in
loop bodies.  This module re-derives whole-program costs from the
post-optimization HLO text itself:

1. parse the module into computations; per computation build an SSA
   name -> shape table (every instruction line defines ``%name = shape op``);
2. per computation, accumulate
     * dot/convolution FLOPs (2 * prod(result dims) * prod(contracting dims),
       contracting sizes resolved through the SSA table),
     * bytes accessed (operands + result of every instruction — an upper-ish
       proxy for HBM traffic consistent with XLA's own definition),
     * collective bytes (result-shape based, comm-factor per op kind);
3. build the call graph (while body/condition, fusion calls, conditionals),
   extract static trip counts from loop-condition constants, and fold costs
   bottom-up:  total(entry) = own + sum(child_total * trips).

All quantities are for the PER-DEVICE SPMD program (the mesh-partitioned
module), which is exactly what the per-chip roofline terms need.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

COLLECTIVE_FACTORS = {
    # traffic per device ~ factor * result_bytes (ring algorithms, large N)
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,   # input-side traffic ~ result * (N-1); we use
                             # result bytes * N from the operand instead (below)
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
# computation headers sit at column 0: "%name (args...) -> result {"
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^=]*?\))|(?:[a-z][a-z0-9]*\[[0-9,]*\](?:{[^}]*})?))\s*([\w\-]+)\((.*)$"
)


def _parse_shapes(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            shape = tuple(int(d) for d in dims.split(",")) if dims.strip() else ()
            out.append((dt, shape))
    return out


def _bytes_of(shapes: list[tuple[str, tuple[int, ...]]]) -> int:
    total = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: dict[str, float] = field(default_factory=dict)
    children: list[tuple[str, str]] = field(default_factory=list)  # (comp, kind)
    trip_hint: float = 1.0  # for while bodies, set on the WHILE edge instead

# ops whose operand/result bytes are NOT HBM traffic at this level: control
# flow passes tuples through; fusion internals stay in registers/VMEM (the
# fusion INSTRUCTION's operands+result are the materialization boundary).
_NO_BYTES_OPS = (
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "while", "conditional", "call",
)


@dataclass
class ModuleCost:
    flops: float
    bytes: float
    coll_bytes: float
    coll_by_op: dict[str, float]
    n_while: int
    trip_counts: dict[str, float]


def _split_computations(hlo: str) -> dict[str, list[str]]:
    hlo = re.sub(r"/\*.*?\*/", "", hlo)  # strip /*index=N*/ tuple comments
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for line in hlo.splitlines():
        if cur is None:
            if line[:1].isspace():
                continue
            m = _COMP_HDR.match(line.rstrip())
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        comps[cur].append(line)
    return comps


_CALL_REFS = re.compile(
    r"(?:calls=|body=|condition=|to_apply=|branch_computations={)%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)"
)


def analyze_module(hlo: str, *, default_trips: float = 1.0) -> ModuleCost:
    comps = _split_computations(hlo)
    # SSA shape tables + constants per computation
    shapes: dict[str, dict[str, list[tuple[str, tuple[int, ...]]]]] = {}
    consts: dict[str, dict[str, float]] = {}
    for cname, lines in comps.items():
        tab: dict[str, list[tuple[str, tuple[int, ...]]]] = {}
        ctab: dict[str, float] = {}
        for line in lines:
            m = _INSTR.match(line)
            if not m:
                # parameter lines: "%p = f32[..]{..} parameter(0)"
                continue
            name, shape_txt, op, _rest = m.groups()
            tab[name] = _parse_shapes(shape_txt)
            if op == "constant":
                mm = re.search(r"constant\((-?[\d\.]+)\)", line)
                if mm:
                    try:
                        ctab[name] = float(mm.group(1))
                    except ValueError:
                        pass
        shapes[cname] = tab
        consts[cname] = ctab

    costs: dict[str, CompCost] = {}
    while_edges: dict[str, list[tuple[str, str]]] = {}  # comp -> [(body, cond)]
    for cname, lines in comps.items():
        cc = CompCost()
        tab = shapes[cname]
        for line in lines:
            m = _INSTR.match(line)
            if not m:
                continue
            name, shape_txt, op, rest = m.groups()
            result_shapes = tab.get(name, [])
            result_bytes = _bytes_of(result_shapes)
            # operand shapes via SSA refs
            opnd_names = re.findall(r"%([\w\.\-]+)", rest.split(")")[0])
            opnd_bytes = sum(_bytes_of(tab.get(o, [])) for o in opnd_names)
            if op not in _NO_BYTES_OPS:
                cc.bytes += result_bytes + opnd_bytes
            if op in ("dot", "convolution"):
                cdims = re.search(r"lhs_contracting_dims={([0-9,]*)}", rest)
                lhs = tab.get(opnd_names[0], []) if opnd_names else []
                k = 1
                if cdims and lhs:
                    dims = lhs[0][1]
                    for ci in cdims.group(1).split(","):
                        if ci.strip() and int(ci) < len(dims):
                            k *= dims[int(ci)]
                elif lhs and lhs[0][1]:
                    k = lhs[0][1][-1]
                n_out = 1
                for _, sh in result_shapes:
                    for d in sh:
                        n_out *= d
                cc.flops += 2.0 * n_out * max(k, 1)
            base_op = op[:-6] if op.endswith("-start") else op
            if base_op in COLLECTIVE_FACTORS and not op.endswith("-done"):
                f = COLLECTIVE_FACTORS[base_op]
                vol = result_bytes * f
                if base_op == "reduce-scatter":
                    vol = opnd_bytes  # ~ input bytes
                cc.coll_bytes += vol
                cc.coll_by_op[base_op] = cc.coll_by_op.get(base_op, 0.0) + vol
            if op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", rest)
                if mb:
                    while_edges.setdefault(cname, []).append(
                        (mb.group(1), mc.group(1) if mc else ""))
            else:
                kind = "fusion" if op == "fusion" else "call"
                for mref in _CALL_REFS.finditer(rest):
                    for ref in re.split(r",\s*%?", mref.group(1)):
                        cc.children.append((ref.strip().lstrip("%"), kind))
        costs[cname] = cc

    def trip_count(cond: str) -> float:
        """Largest constant in the loop condition — the scan bound."""
        vals = [v for v in consts.get(cond, {}).values() if 1 <= v <= 1e7]
        return max(vals) if vals else default_trips

    trips_used: dict[str, float] = {}

    memo: dict[str, tuple[float, float, float, dict]] = {}

    def fold(cname: str, depth: int = 0) -> tuple[float, float, float, dict]:
        if cname in memo:
            return memo[cname]
        if cname not in costs or depth > 64:
            return (0.0, 0.0, 0.0, {})
        cc = costs[cname]
        fl, by, co = cc.flops, cc.bytes, cc.coll_bytes
        cop = dict(cc.coll_by_op)
        for child, kind in cc.children:
            cfl, cby, cco, ccop = fold(child, depth + 1)
            fl += cfl
            # fusion internals live in registers/VMEM: their bytes are not
            # HBM traffic (the fusion op's own operands/result were counted)
            if kind != "fusion":
                by += cby
            co += cco
            for k, v in ccop.items():
                cop[k] = cop.get(k, 0.0) + v
        for body, cond in while_edges.get(cname, []):
            t = trip_count(cond)
            trips_used[body] = t
            bfl, bby, bco, bcop = fold(body, depth + 1)
            fl += bfl * t
            by += bby * t
            co += bco * t
            for k, v in bcop.items():
                cop[k] = cop.get(k, 0.0) + v * t
        memo[cname] = (fl, by, co, cop)
        return memo[cname]

    # entry = the computation not referenced by anyone (or named 'main')
    referenced = set()
    for cc in costs.values():
        referenced.update(c for c, _ in cc.children)
    for edges in while_edges.values():
        for b, c in edges:
            referenced.update((b, c))
    entries = [c for c in costs if c not in referenced]
    entry = next((c for c in entries if "main" in c), entries[0] if entries else None)
    if entry is None:
        return ModuleCost(0, 0, 0, {}, 0, {})
    fl, by, co, cop = fold(entry)
    return ModuleCost(
        flops=fl, bytes=by, coll_bytes=co, coll_by_op=cop,
        n_while=sum(len(v) for v in while_edges.values()),
        trip_counts=trips_used,
    )
