"""Post-compile HLO analysis: collective-traffic accounting + roofline terms.

``cost_analysis`` gives per-device FLOPs and bytes but NOT collective
traffic; we parse the partitioned HLO text and sum operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * b


@dataclass
class CollectiveStats:
    bytes_by_op: dict[str, int] = field(default_factory=dict)
    count_by_op: dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    def summary(self) -> dict:
        return {
            "total_bytes": self.total_bytes,
            "bytes_by_op": dict(self.bytes_by_op),
            "count_by_op": dict(self.count_by_op),
        }


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective instruction (per-device view).

    Matches both sync ops and -start/-done async pairs (counted once at
    -start / plain form).
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = re.search(
            r"=\s*(?:\([^=]*\)|\S+)\s+(" + "|".join(COLLECTIVE_OPS) + r")(-start)?\(",
            line,
        )
        if not m:
            continue
        op = m.group(1)
        # operand shapes: shape literals appearing after the op-name '('
        tail = line[m.end():]
        nbytes = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(tail))
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + nbytes
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
    return stats


def roofline_terms(
    flops_per_device: float,
    hbm_bytes_per_device: float,
    collective_bytes_per_device: float,
) -> dict:
    """The three per-step roofline terms, in seconds (per-device program)."""
    t_compute = flops_per_device / PEAK_FLOPS
    t_memory = hbm_bytes_per_device / HBM_BW
    t_collective = collective_bytes_per_device / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_collective}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["dominant"] = dom
    terms["roofline_fraction"] = (t_compute / bound) if bound > 0 else 0.0
    return terms


def model_flops_per_token(n_params_active: int) -> float:
    """6 N D rule: returns 6 * N (multiply by tokens for the step total)."""
    return 6.0 * n_params_active
