"""Elastic scaling: re-mesh on changed device count.

Checkpoints store logical (unsharded) arrays (checkpoint/), so scaling is:
pick the best mesh for the surviving device count, recompute shardings from
the same logical rules, reload.  ``plan_mesh`` chooses the (data, model)
factorization: model parallelism keeps its degree as long as the device
count allows (TP degree is dictated by model size, not fleet size); data
parallelism absorbs the change.  Used by launch/train.py on restart.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax


@dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    def build(self):
        return jax.make_mesh(self.shape, self.axes)


def plan_mesh(n_devices: int, *, preferred_model: int = 16) -> MeshPlan:
    """Largest power-of-two model axis <= preferred that divides n_devices."""
    model = 1
    m = preferred_model
    while m > 1:
        if n_devices % m == 0:
            model = m
            break
        m //= 2
    data = n_devices // model
    if model == 1:
        return MeshPlan((data,), ("data",))
    return MeshPlan((data, model), ("data", "model"))


def rescale_batch(global_batch: int, old_devices: int, new_devices: int) -> int:
    """Keep per-device batch constant under rescale (linear-scaling rule);
    round to keep divisibility."""
    per_dev = max(global_batch // old_devices, 1)
    return per_dev * new_devices
