"""Distribution substrate: ambient mesh context, logical-axis sharding
rules, collective helpers, HLO analysis, elasticity."""
from repro.distributed import context
from repro.distributed.sharding import (
    batch_spec,
    logical_constraint,
    param_shardings,
    set_rule,
    spec_for_param,
)

__all__ = [
    "context", "batch_spec", "logical_constraint", "param_shardings",
    "set_rule", "spec_for_param",
]
