"""Ambient mesh context.

Model code stays mesh-agnostic: layers that need explicit SPMD (the MoE
expert-parallel island) look the active mesh up here.  The launcher /
dry-run sets it; unit tests run with no mesh (single-device dense fallback).
"""
from __future__ import annotations

import contextlib
from typing import Iterator

import jax
from jax.sharding import Mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across jax versions.

    Covers all three API generations: experimental-only (pre-promotion,
    ``check_rep``), top-level with ``check_rep`` (transition window), and
    top-level with ``check_vma``.
    """
    import inspect

    if hasattr(jax, "shard_map"):
        impl = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as impl
    params = inspect.signature(impl).parameters
    kw = {"check_vma" if "check_vma" in params else "check_rep": check_vma}
    return impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

_ACTIVE: list[Mesh | None] = [None]

# Logical -> physical axis mapping (see distributed/sharding.py).
BATCH_AXES = ("pod", "data")  # batch / fsdp axes present in the mesh
MODEL_AXIS = "model"


def current_mesh() -> Mesh | None:
    return _ACTIVE[0]


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None) -> Iterator[None]:
    prev = _ACTIVE[0]
    _ACTIVE[0] = mesh
    try:
        yield
    finally:
        _ACTIVE[0] = prev


def batch_axes(mesh: Mesh | None = None) -> tuple[str, ...]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in BATCH_AXES if a in mesh.axis_names)


def model_axis_size(mesh: Mesh | None = None) -> int:
    mesh = mesh or current_mesh()
    if mesh is None or MODEL_AXIS not in mesh.axis_names:
        return 1
    return mesh.shape[MODEL_AXIS]
