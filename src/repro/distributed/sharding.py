"""Logical-axis sharding rules (MaxText-style) for params and activations.

A single rule table maps *logical* axis names onto mesh axes; every rule is
guarded by a divisibility check so small architectures (9 heads, 14 heads,
kv=1, ...) degrade gracefully to replication on that dimension instead of
failing to lower.  Parameter specs are resolved from the parameter tree by
path-pattern matching and left-padded with None for scan-stacked leading
axes, so the same table serves all ten architectures.

Physical axes:
  'pod'   — inter-pod data parallelism (multi-pod mesh only)
  'data'  — intra-pod data parallel / FSDP
  'model' — tensor / expert / vocab parallelism
"""
from __future__ import annotations

import math
import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed import context as dctx

Array = jax.Array

# logical axis -> tuple of physical mesh axes
LOGICAL_AXES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "seq": (),           # optionally ('model',) via seq_shard_activations
    "seq_kv": ("model",),  # decode KV caches: shard context length
    "embed": (),
    "vocab": ("model",),
    "heads": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "tensor": ("model",),
    "none": (),
}

# parameter path pattern -> logical spec (rightmost dims; left-padded w/ None)
_PARAM_RULES: list[tuple[str, tuple[str, ...]]] = [
    (r"embed$", ("vocab", "fsdp")),
    (r"pos_embed$", ("none", "fsdp")),
    (r"lm_head$", ("fsdp", "vocab")),
    (r"patch_proj$", ("none", "fsdp")),
    (r"frame_proj$", ("none", "fsdp")),
    # attention (gqa + whisper)
    (r"(attn|cross)/wq$", ("fsdp", "heads", "none")),
    (r"(attn|cross)/w[kv]$", ("fsdp", "none", "none")),
    (r"(attn|cross)/wo$", ("heads", "none", "fsdp")),
    (r"(attn|cross)/b[qkv]$", ("none", "none")),
    # MLA
    (r"attn/wdq$", ("fsdp", "none")),
    (r"attn/wuq$", ("none", "heads", "none")),
    (r"attn/wdkv$", ("fsdp", "none")),
    (r"attn/wk_rope$", ("fsdp", "none")),
    (r"attn/wu[kv]$", ("none", "heads", "none")),
    # dense MLPs (swiglu + gelu)
    (r"mlp/w_(in|gate)$", ("fsdp", "mlp")),
    (r"mlp/w_out$", ("mlp", "fsdp")),
    (r"mlp/b_in$", ("mlp",)),
    (r"mlp/b_out$", ("none",)),
    # MoE
    (r"moe/router$", ("fsdp", "none")),
    (r"moe/w_(in|gate)$", ("expert", "fsdp", "none")),
    (r"moe/w_out$", ("expert", "none", "fsdp")),
    (r"moe/shared/w_(in|gate)$", ("fsdp", "mlp")),
    (r"moe/shared/w_out$", ("mlp", "fsdp")),
    # Mamba
    (r"mamba/in_proj$", ("fsdp", "mlp")),
    (r"mamba/conv_w$", ("none", "mlp")),
    (r"mamba/conv_b$", ("mlp",)),
    (r"mamba/x_proj$", ("mlp", "none")),
    (r"mamba/dt_proj$", ("none", "mlp")),
    (r"mamba/dt_bias$", ("mlp",)),
    (r"mamba/a_log$", ("mlp", "none")),
    (r"mamba/d_skip$", ("mlp",)),
    (r"mamba/out_proj$", ("mlp", "fsdp")),
    # RWKV time-mix: per-head state ops -> no TP on the head structure
    (r"tm/w[rkvgo]$", ("fsdp", "none")),
    (r"tm/lora_a$", ("fsdp", "none")),
    (r"tm/wd_a$", ("fsdp", "none")),
    # RWKV channel-mix: plain MLP -> TP fine
    (r"cm/wk$", ("fsdp", "mlp")),
    (r"cm/wv$", ("mlp", "fsdp")),
    (r"cm/wr$", ("fsdp", "none")),
]


def _axes_for(logical: str, mesh) -> tuple[str, ...]:
    return tuple(a for a in LOGICAL_AXES[logical] if a in mesh.axis_names)


def _fit(axes: tuple[str, ...], dim: int, mesh) -> tuple[str, ...] | None:
    """Divisibility guard: only shard if the dim divides evenly."""
    if not axes:
        return None
    total = math.prod(mesh.shape[a] for a in axes)
    if total <= 1 or dim % total != 0:
        return None
    return axes if len(axes) > 1 else axes


def _raw_spec(path: str, ndim: int) -> list[str]:
    """Logical names per dim (left-padded for scan-stacked leading axes)."""
    # adafactor factored stats: inherit the parent rule minus the reduced dim
    if path.endswith("/vr"):
        return _raw_spec(path[:-3], ndim + 1)[:-1]
    if path.endswith("/vc"):
        parent = _raw_spec(path[:-3], ndim + 1)
        return parent[:-2] + parent[-1:]
    for pat, logical in _PARAM_RULES:
        if re.search(pat, path):
            spec = list(logical)
            break
    else:
        spec = []
    spec = spec[-ndim:] if len(spec) > ndim else spec
    return ["none"] * (ndim - len(spec)) + spec


def spec_for_param(path: str, shape: tuple[int, ...], mesh) -> P:
    out = []
    used: set[str] = set()
    for dim, name in zip(shape, _raw_spec(path, len(shape))):
        # a mesh axis may shard at most one dim; later dims drop the
        # already-used axes (rule overlays like zero3+vocab need this)
        cand = tuple(a for a in _axes_for(name, mesh) if a not in used)
        axes = _fit(cand, dim, mesh)
        if axes is None:
            out.append(None)
        else:
            used.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


# decode-cache path pattern -> logical spec (rightmost dims)
_CACHE_RULES: list[tuple[str, tuple[str, ...]]] = [
    (r"/(k|v)$", ("batch", "seq_kv", "none", "none")),
    (r"/(ck|cv)$", ("batch", "none", "none", "none")),  # whisper cross (S=1500)
    (r"/c_kv$", ("batch", "seq_kv", "none")),
    (r"/k_rope$", ("batch", "seq_kv", "none")),
    (r"/conv$", ("batch", "none", "mlp")),
    (r"/ssm$", ("batch", "mlp", "none")),
    (r"/wkv$", ("batch", "none", "none", "none")),
    (r"/shift$", ("batch", "none", "none")),
]


def spec_for_cache(path: str, shape: tuple[int, ...], mesh) -> P:
    for pat, logical in _CACHE_RULES:
        if re.search(pat, path):
            spec = list(logical)
            break
    else:
        spec = []
    spec = spec[-len(shape):] if len(spec) > len(shape) else spec
    spec = ["none"] * (len(shape) - len(spec)) + spec
    out = []
    for dim, name in zip(shape, spec):
        axes = _fit(_axes_for(name, mesh), dim, mesh)
        out.append(axes if axes is None else (axes if len(axes) > 1 else axes[0]))
    return P(*out)


def cache_shardings(cache_shape: Any, mesh) -> Any:
    def leaf(path, x):
        return NamedSharding(mesh, spec_for_cache(_path_str(path), x.shape, mesh))

    return jax.tree_util.tree_map_with_path(leaf, cache_shape)


def _path_str(path) -> str:
    parts = []
    for p_ in path:
        if hasattr(p_, "key"):
            parts.append(str(p_.key))
        elif hasattr(p_, "idx"):
            parts.append(str(p_.idx))
        else:
            parts.append(str(p_))
    return "/".join(parts)


def param_shardings(params_shape: Any, mesh) -> Any:
    """NamedSharding tree for an eval_shape'd parameter tree."""
    def leaf(path, x):
        return NamedSharding(mesh, spec_for_param(_path_str(path), x.shape, mesh))

    return jax.tree_util.tree_map_with_path(leaf, params_shape)


def logical_constraint(x: Array, logical: tuple[str | None, ...]) -> Array:
    """with_sharding_constraint via logical names; no-op without a mesh."""
    mesh = dctx.current_mesh()
    if mesh is None:
        return x
    out = []
    used: set[str] = set()
    for dim, name in zip(x.shape, logical):
        if name is None:
            out.append(None)
            continue
        axes = _fit(_axes_for(name, mesh), dim, mesh)
        # a mesh axis may shard at most one dim (first-come-first-served)
        if axes is not None and any(a in used for a in axes):
            axes = None
        if axes is None:
            out.append(None)
        else:
            used.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*out)))


def batch_spec(mesh, shape: tuple[int, ...]) -> NamedSharding:
    """Inputs: shard dim 0 over the batch axes (when divisible)."""
    axes = _fit(dctx.batch_axes(mesh), shape[0], mesh) if shape else None
    spec = [axes if axes is None or len(axes) > 1 else axes[0]]
    spec += [None] * (len(shape) - 1)
    return NamedSharding(mesh, P(*spec))


def set_rule(logical: str, axes: tuple[str, ...]) -> None:
    """Override a logical-axis rule (e.g. sequence-sharded activations)."""
    LOGICAL_AXES[logical] = axes
