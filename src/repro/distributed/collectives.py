"""Collective helpers for explicit-SPMD islands.

GSPMD inserts most collectives automatically; these helpers exist for the
shard_map islands (MoE, retrieval) and for the distributed-optimization
knobs that need explicit control:

* ``compressed_psum`` — cast-to-bf16 before the wire, restore after
  (gradient compression for cross-pod reductions);
* ``ring_allgather_pipelined`` — chunked all-gather exposing overlap
  opportunities to the scheduler (compute can interleave between chunks);
* ``topk_allgather_merge`` — the k-per-shard merge pattern used by
  distributed kNN (Alg. 2 step 3): O(k * shards) wire bytes instead of
  gathering the candidate pools.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def compressed_psum(x: Array, axis_name, *, wire_dtype=jnp.bfloat16) -> Array:
    """psum with reduced wire precision (halves DP/pod all-reduce bytes)."""
    orig = x.dtype
    return jax.lax.psum(x.astype(wire_dtype), axis_name).astype(orig)


def ring_allgather_pipelined(x: Array, axis_name, *, chunks: int = 4) -> Array:
    """All-gather split into ``chunks`` sequential slices along axis 0.

    Each slice is an independent collective: XLA's latency-hiding scheduler
    can overlap slice k+1's communication with compute consuming slice k.
    Requires x.shape[0] % chunks == 0.
    """
    if x.shape[0] % chunks:
        return jax.lax.all_gather(x, axis_name, axis=0, tiled=True)
    parts = jnp.split(x, chunks, axis=0)
    gathered = [jax.lax.all_gather(p, axis_name, axis=0, tiled=True) for p in parts]
    n = jax.lax.psum(1, axis_name)
    # re-interleave: gathered[c] holds rows [c*chunk : (c+1)*chunk) per shard
    chunk = x.shape[0] // chunks
    out = jnp.concatenate(
        [g.reshape(n, chunk, *x.shape[1:]) for g in gathered], axis=1
    )
    return out.reshape(n * x.shape[0], *x.shape[1:])


def topk_allgather_merge(
    vals: Array, payload: Array, axis_name, *, k: int
) -> tuple[Array, Array]:
    """Merge per-shard top-k (ascending ``vals`` (B,k) + aligned payload)
    into the global top-k without gathering candidate pools."""
    v_all = jax.lax.all_gather(vals, axis_name, axis=1, tiled=True)  # (B, n*k)
    p_all = jax.lax.all_gather(payload, axis_name, axis=1, tiled=True)
    neg, pos = jax.lax.top_k(-v_all, k)
    return -neg, jnp.take_along_axis(p_all, pos, axis=1)
