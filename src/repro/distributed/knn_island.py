"""The sharded forest island: ONE ``shard_map`` for search and ingest.

The forest's bucket rows and the per-index delta buffers are sharded over
the ``'model'`` mesh axis (leading dimension, NB and I respectively); the
routing state — index centers, radii, neighbor lists, queries — is
replicated.  Inside the island every shard runs the SAME code the
single-device executor runs (``core.knn.route_select`` + ``local_scan``,
``stream.ingest.append_routed``) over its local rows only; the collectives
are confined to the very end:

  search:  per-shard top-kk carry  -> all_gather + global top-k
           (``core.knn.merge_shard_topk`` — the identical merge
           ``serve/retrieval.knn_logits`` runs for the flat datastore),
           cost counters ``psum``-reduced;
  ingest:  per-shard accept masks  -> ``psum`` (a point is accepted iff its
           OWNING shard accepted it; capacity rejects therefore aggregate
           across shards).

Exactness contract (tests/test_sharded_exec.py): the island returns
bitwise-identical (distance, id) results to the single-device executor on
the same data — per-member distance arithmetic is shard-local and
identical, and k-per-shard candidates make the merged global top-k exact.

Padding convention (repro.api.executor pads before placement):
  * bucket rows NB -> ceil(NB/S)*S; pad buckets carry ``bucket_index = I``
    (one past the real index count) and the island extends the selection
    table with one always-False sentinel column, so pad buckets are never
    eligible and the instrumented eligible/bound counts match the single
    path exactly (pad members are additionally id=-1/mask=False).
  * delta rows I -> ceil(I/S)*S; pad rows keep count=0 (never eligible,
    never routed to — routing only emits real index ids).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import knn as cknn
from repro.distributed import context as dctx
from repro.stream.ingest import DeltaBuffer, append_routed

Array = jax.Array


@functools.lru_cache(maxsize=None)
def default_mesh(shards: int, axis: str = dctx.MODEL_AXIS) -> Mesh:
    """One-axis mesh over the first ``shards`` local devices.

    Cached so every consumer (executor backend, serving datastore path)
    that asks for the same (shards, axis) gets the SAME mesh object —
    placements line up and jit caches key consistently.
    """
    return Mesh(np.asarray(jax.devices()[:shards]), (axis,))


def forest_specs(forest: cknn.DeviceForest, axis: str) -> cknn.DeviceForest:
    """Partition specs for a DeviceForest: bucket rows sharded, routing
    state replicated (the spec tree ``shard_map``/``NamedSharding`` take)."""
    row = P(axis)
    return cknn.DeviceForest(
        index_centers=P(),
        index_radii=P(),
        neighbors=P(),
        bucket_x=row,
        bucket_ids=row,
        bucket_mask=row,
        bucket_pivot=row,
        bucket_radius=row,
        bucket_index=row,
        bucket_scale=None if forest.bucket_scale is None else row,
    )


def delta_view_specs(axis: str) -> cknn.DeltaView:
    row = P(axis)
    return cknn.DeltaView(x=row, ids=row, mask=row, pivot=row, radius=row)


def delta_buffer_specs(axis: str) -> DeltaBuffer:
    row = P(axis)
    return DeltaBuffer(
        x=row, ids=row, count=row, pivot=row, radius=row, sum_x=row,
        main_count=row, main_sum=row, main_radius=row, dropped=row,
    )


def sharded_search(
    mesh: Mesh,
    axis: str,
    forest: cknn.DeviceForest,
    q: Array,
    delta: cknn.DeltaView | None,
    *,
    k: int,
    mode: str = "forest",
    beam: int = 1,
    kernel: bool = True,
    per_island: bool = False,
    explain: bool = False,
    host_sel: Array | None = None,
) -> tuple[Array, ...]:
    """Sharded twin of ``core.knn.knn_search_impl`` — same signature shape,
    same return triple, bitwise-identical results.  ``per_island=True``
    appends a fourth element, ``core.knn.IslandStats`` with one row per
    shard, exposing which island paid which node accesses (the telemetry
    layer's load-balance view; the summed ``SearchStats`` is unchanged).
    ``explain=True`` (implies ``per_island``) appends a fifth,
    ``core.knn.VisitRows``: the col-stacked per-shard sorted visit orders
    (shard-LOCAL row ids — the bounds island's order tables verbatim) plus
    (S, Q) main/delta visited counts, the attribution layer's evidence.

    TWO ``shard_map`` regions, not one: the bounds island (routing +
    eligibility + pivot lower bounds + the SORTED visit order) and the scan
    island (the bounded ``while_loop`` scan + cross-shard merge).  They must
    be separate because XLA's SPMD partitioner miscompiles a sort whose
    result feeds a ``while_loop`` inside the same manually-sharded region
    under an outer ``jit`` (shards silently read other queries' visit
    orders; jax 0.4.x CPU).  Crossing an island boundary turns the sorted
    order into an ordinary sharded operand, which partitions correctly —
    and the split costs nothing: both islands fuse into the same jitted
    executable, and the eager path runs the same ops ``local_scan`` runs.

    Each shard routes the (replicated) queries, scans its local bucket rows
    and local delta rows with the shared ``scan_sorted`` body, then the
    k-per-shard carries merge via ``merge_shard_topk``.  Per-query cost
    counters leave the island as stacked per-shard rows and are summed
    outside it (an int32 sum — the same fleet totals the old in-island
    ``psum`` produced) so the per-island breakdown stays available;
    ``steps`` sums per-shard trip counts (each shard's bounded scan
    terminates on its local bound ordering, so the total can legally
    exceed the single-device count even though the RESULTS are identical).

    ``host_sel`` ((Q, S) bool, replicated math upstream) is the routing
    tier's per-query host-eligibility table (distributed/router/): a False
    (query, shard) pair masks that shard's bucket/delta selection for the
    query AND kills its scan loop (``scan_sorted``'s ``qmask``), so a
    pruned host does ZERO bound evaluations and ZERO member scans for the
    query and its carry stays (+inf, -1) — which contributes nothing to
    ``merge_shard_topk``.  Soundness (hosts are only pruned when their
    metric lower bound exceeds a valid upper bound on the merged kth-best)
    is the router's contract; under it results stay bitwise-identical to
    ``host_sel=None`` (tests/test_routed_exec.py gates this).
    """
    S = mesh.shape[axis]
    qn = q.shape[0]
    nb_pad, cap, _ = forest.bucket_x.shape  # global padded row count
    n_cap = nb_pad * cap  # >= real capacity (pad rows are empty)
    if delta is not None:
        n_cap += delta.x.shape[0] * delta.x.shape[1]
    kk = min(k, n_cap)
    have_delta = delta is not None

    def bounds_island(forest_l, q_l, delta_l, hs_l):
        n_idx = forest_l.index_centers.shape[0]
        sel, route_d, route_c = cknn.route_select(
            forest_l, q_l, mode=mode, kernel=kernel
        )
        if hs_l is not None:
            # routing tier: this shard bounds/scans only the queries that
            # elected it — (Q, 1) local column broadcast over the I indexes.
            # Routing counters above stay untouched (every host still routes
            # the replicated queries; the saving is in bounds + scans).
            sel = sel & hs_l
        # sentinel column: pad buckets own index I -> always ineligible
        bucket_sel = jnp.pad(sel, ((0, 0), (0, 1)))
        mb = cknn.bucket_bounds(
            forest_l, q_l, bucket_sel, beam=beam, kernel=kernel
        )
        # replicated values leave as an explicit (1, Q) shard slice — the
        # caller reads shard 0 — rather than a P() output, so correctness
        # never leans on the partitioner's replication bookkeeping
        outs = (route_d[None], route_c[None], mb.order, mb.lb_sorted,
                mb.n_elig[None])
        if delta_l is not None:
            i_l = delta_l.x.shape[0]
            # local slice of the global per-index selection table (padded to
            # the sharded row count; pad rows select False)
            sel_pad = jnp.pad(sel, ((0, 0), (0, S * i_l - n_idx)))
            off = jax.lax.axis_index(axis) * i_l
            dsel = jax.lax.dynamic_slice_in_dim(sel_pad, off, i_l, axis=1)
            db = cknn.delta_bounds(delta_l, q_l, dsel, beam=beam, kernel=kernel)
            outs += (db.order, db.lb_sorted, db.n_elig[None])
        return outs

    def scan_island(forest_l, q_l, delta_l, order_l, lbs_l, dorder_l, dlbs_l,
                    hs_l):
        mb = cknn.PhaseBounds(
            order=order_l, lb_sorted=lbs_l,
            n_elig=jnp.zeros((qn,), jnp.int32),  # summed outside the island
        )
        db = None
        if delta_l is not None:
            db = cknn.PhaseBounds(
                order=dorder_l, lb_sorted=dlbs_l,
                n_elig=jnp.zeros((qn,), jnp.int32),
            )
        out = cknn.scan_sorted(
            forest_l, q_l, mb, kk=kk, beam=beam, kernel=kernel,
            delta=delta_l, dbounds=db,
            qmask=None if hs_l is None else hs_l[:, 0],
        )
        top_d, top_i = cknn.merge_shard_topk(
            out.top_d, out.top_i, k=kk, axis_name=axis
        )
        # counters leave as explicit (1, Q) shard rows (stacked to (S, Q)
        # by the out_spec) instead of psum-replicated totals: the caller
        # sums them for SearchStats AND keeps the per-island breakdown
        outs = (top_d, top_i, out.visits[None], out.ndist[None],
                out.npad[None], out.steps[None])
        if explain:
            outs += (out.visits_main[None],)
        return outs

    fspec = forest_specs(forest, axis)
    dspec = None if delta is None else delta_view_specs(axis)
    col = P(None, axis)  # (Q, NB) tables sharded along the bucket axis
    row = P(axis, None)  # per-shard (1, Q) vectors stacked to (S, Q)
    hspec = None if host_sel is None else col  # (Q, S) -> (Q, 1) per shard
    bounds_out = (row, row, col, col, row)
    if have_delta:
        bounds_out += (col, col, row)
    bounds_fn = dctx.shard_map(
        bounds_island,
        mesh=mesh,
        in_specs=(fspec, P(), dspec, hspec),
        out_specs=bounds_out,
        check_vma=False,
    )
    scan_out = (P(), P(), row, row, row, P(axis))
    if explain:
        per_island = True
        scan_out += (row,)
    scan_fn = dctx.shard_map(
        scan_island,
        mesh=mesh,
        in_specs=(fspec, P(), dspec, col, col,
                  col if have_delta else None, col if have_delta else None,
                  hspec),
        out_specs=scan_out,
        check_vma=False,
    )

    bout = bounds_fn(forest, q, delta, host_sel)
    route_d, route_c, order, lbs, n_elig = bout[:5]
    dorder = dlbs = None
    n_elig_d_s = jnp.zeros((S, qn), jnp.int32)
    if have_delta:
        dorder, dlbs, n_elig_d_s = bout[5:]
    sout = scan_fn(forest, q, delta, order, lbs, dorder, dlbs, host_sel)
    top_d, top_i, visits_s, ndist_s, npad_s, steps_s = sout[:6]
    merged = cknn.ScanOut(
        top_d=top_d,
        top_i=top_i,
        visits=jnp.sum(visits_s, axis=0, dtype=jnp.int32),
        ndist=jnp.sum(ndist_s, axis=0, dtype=jnp.int32),
        npad=jnp.sum(npad_s, axis=0, dtype=jnp.int32),
        steps=jnp.sum(steps_s, dtype=jnp.int32),
        n_elig=jnp.sum(n_elig, axis=0, dtype=jnp.int32),
        n_elig_d=jnp.sum(n_elig_d_s, axis=0, dtype=jnp.int32),
    )
    stats = cknn.scan_stats(route_d[0], route_c[0], merged, kk=kk)
    if not per_island:
        return jnp.sqrt(top_d), top_i, stats
    # per-shard bound work: every shard routes the replicated queries itself
    # (route_d rows) and bounds its own eligible bucket/delta rows
    island = cknn.IslandStats(
        buckets_visited=visits_s,
        distances=ndist_s,
        bound_distances=route_d + n_elig + n_elig_d_s,
    )
    if not explain:
        return jnp.sqrt(top_d), top_i, stats, island
    visits_main_s = sout[6]
    rows = cknn.VisitRows(
        order=order,
        visits=visits_main_s,
        dorder=dorder,
        dvisits=None if not have_delta else visits_s - visits_main_s,
    )
    return jnp.sqrt(top_d), top_i, stats, island, rows


def sharded_ingest(
    mesh: Mesh,
    axis: str,
    centers: Array,
    delta: DeltaBuffer,
    xb: Array,
    ids: Array,
    valid: Array,
) -> tuple[DeltaBuffer, Array]:
    """Sharded twin of ``stream.ingest.ingest_impl``: collective scatter.

    The batch is replicated; every shard routes it against the (replicated)
    index centers, claims the rows whose destination buffer it owns, and
    appends them with the shared ``append_routed`` body — rows owned by
    other shards arrive parked, so they consume no slots and count nowhere
    on this shard.  The per-shard accept masks are disjoint by construction
    (one owner per destination row), so a ``psum`` aggregates capacity
    accepts/rejects across shards exactly.
    """

    def island(centers_r, delta_l, xb_r, ids_r, valid_r):
        xb_f = xb_r.astype(jnp.float32)
        ids_i = ids_r.astype(jnp.int32)
        _, idx = cknn.route_points(centers_r, xb_f, kernel=True)  # (B,) global
        i_l = delta_l.count.shape[0]
        off = jax.lax.axis_index(axis) * i_l
        local = idx - off
        mine = valid_r & (local >= 0) & (local < i_l)
        new_delta, acc = append_routed(
            delta_l, xb_f, ids_i, jnp.where(mine, local, i_l), mine
        )
        acc_any = jax.lax.psum(acc.astype(jnp.int32), axis_name=axis) > 0
        return new_delta, acc_any

    dspec = delta_buffer_specs(axis)
    fn = dctx.shard_map(
        island,
        mesh=mesh,
        in_specs=(P(), dspec, P(), P(), P()),
        out_specs=(dspec, P()),
        check_vma=False,
    )
    return fn(centers, delta, xb, ids, valid)
