"""``routed_search`` — the routing tier composed over the shard islands.

Same contract as ``knn_island.sharded_search`` with one extra trailing
element, :class:`RouterStats`.  The routing math (eligibility + pricing)
runs REPLICATED outside the islands — at fleet scale every host holds the
same table and derives the same eligibility independently; here that is one
untraced prefix of the same jitted program — and the decision flows into
the islands as ``sharded_search``'s ``host_sel`` operand.

Fanout semantics (RoutingConfig.fanout):
  'all'       homogeneous: ``host_sel=None`` — literally the plain sharded
              program (the router only reports its would-be eligibility).
  'targeted'  heterogeneous: always mask to the eligible set.
  'auto'      DIMS's cost-model choice, decided per query batch INSIDE the
              compiled program (a traced bool): targeted iff its priced
              cost undercuts fan-all.  The fan-all branch resolves to an
              all-True mask, which is arithmetically identity — results are
              bitwise identical to 'all' either way.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import knn as cknn
from repro.core.metric import pairwise
from repro.distributed import knn_island
from repro.distributed.router.cost import price_dispatch
from repro.distributed.router.table import RoutingTable, host_eligibility

Array = jax.Array


class RouterStats(NamedTuple):
    """Per-batch routing telemetry (device; fetched with SearchStats)."""

    eligible_hosts: Array  # (Q,) i32 hosts the lower bounds could not prune
    pruned_hosts: Array  # (Q,) i32 hosts actually skipped post-decision
    targeted: Array  # () bool: heterogeneous dispatch chosen
    wire_targeted: Array  # () f32 est. cross-host bytes, eligible subset
    wire_fanall: Array  # () f32 est. cross-host bytes, whole fleet
    cost_targeted: Array  # () f32 full targeted price (wire+bounds+overhead)
    cost_fanall: Array  # () f32 full fan-all price


def routed_search(
    mesh,
    axis: str,
    forest: cknn.DeviceForest,
    q: Array,
    delta: cknn.DeltaView | None,
    table: RoutingTable,
    *,
    k: int,
    mode: str = "forest",
    beam: int = 1,
    kernel: bool = True,
    fanout: str = "auto",
    per_island: bool = False,
    explain: bool = False,
) -> tuple[Array, ...]:
    """Routing tier + sharded islands; appends RouterStats to the island
    tuple.  Exactness: bitwise-identical (distances, ids) to
    ``sharded_search`` fan-all and to the single-device executor — the
    eligibility rule only prunes hosts whose metric lower bound strictly
    clears a valid upper bound on the merged kth-best (table.py)."""
    s_hosts = mesh.shape[axis]
    qn, n_dim = q.shape
    n_idx = forest.index_centers.shape[0]
    nb_pad, cap, _ = forest.bucket_x.shape
    n_cap = nb_pad * cap
    if delta is not None:
        n_cap += delta.x.shape[0] * delta.x.shape[1]
    kk = min(k, n_cap)

    d_sq, _ = cknn.route_points(forest.index_centers, q, kernel=kernel)
    d_center = jnp.sqrt(d_sq)
    sel, _, _ = cknn.route_select(forest, q, mode=mode, kernel=kernel)
    d_host = pairwise(q, table.host_centers, metric="l2", use_kernel=False)
    dkw = {}
    if delta is not None:
        # live buffer state for the LOGICAL rows (operand-padded to a shard
        # multiple; pad rows never carry members)
        dkw = dict(
            d_delta=pairwise(
                q, delta.pivot[:n_idx], metric="l2", use_kernel=False
            ),
            delta_radius=delta.radius[:n_idx],
            delta_count=jnp.sum(
                delta.mask[:n_idx], axis=1, dtype=jnp.int32
            ),
        )
    elig, _ = host_eligibility(table, d_center, d_host, sel, kk, **dkw)
    cost = price_dispatch(table, elig, sel, kk, n_dim=n_dim)

    if fanout == "all":
        host_sel = None
        targeted = jnp.asarray(False)
    elif fanout == "targeted":
        host_sel = elig
        targeted = jnp.asarray(True)
    elif fanout == "auto":
        targeted = cost.cost_targeted < cost.cost_fanall
        host_sel = elig | ~targeted
    else:
        raise ValueError(f"fanout {fanout!r}")

    outs = knn_island.sharded_search(
        mesh, axis, forest, q, delta,
        k=k, mode=mode, beam=beam, kernel=kernel,
        per_island=per_island, explain=explain, host_sel=host_sel,
    )
    pruned = (
        jnp.zeros((qn,), jnp.int32) if host_sel is None
        else jnp.sum(~host_sel, axis=1, dtype=jnp.int32)
    )
    router = RouterStats(
        eligible_hosts=jnp.sum(elig, axis=1, dtype=jnp.int32),
        pruned_hosts=pruned,
        targeted=targeted,
        wire_targeted=cost.wire_targeted,
        wire_fanall=cost.wire_fanall,
        cost_targeted=cost.cost_targeted,
        cost_fanall=cost.cost_fanall,
    )
    return (*outs, router)
