"""Dispatch pricing: targeted (heterogeneous) vs fan-all (homogeneous).

DIMS's split, priced with the repo's own currencies and FLEET semantics —
a pruned host receives nothing, so it skips its whole per-query pipeline,
not just the merge:

  wire     the ring all-gather rule the HLO analyzer applies to measured
           collectives (``estimator.estimate_allgather_bytes``): the kNN
           merge gathers each participating host's (distance, id) top-k.
  route    every participating host routes the query against all I index
           centers (one D-dim read per center).
  bounds   each participating host bounds its non-empty buckets of the
           query's selected indexes (one D-dim pivot read per bound —
           the paper's ``bound_distances`` counter, in bytes).
  scan     expected member distances: the selected members the host owns —
           floored at min(kk, host size), because a participating host's
           bounded scan spills until its carry holds kk candidates even
           when the query selected nothing it owns.
  router   targeted dispatch additionally pays the routing tier itself
           (distance rows to S host centers and I delta pivots), which the
           homogeneous path never computes — so when pruning saves
           nothing, fan-all wins and the program degenerates to the plain
           sharded search.

All terms are traced scalars: the ``fanout='auto'`` decision happens INSIDE
the compiled search program, per query batch.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.estimator import estimate_allgather_bytes
from repro.distributed.router.table import RoutingTable

Array = jax.Array

# one merged candidate on the wire: (f32 distance, i32 id)
_PAIR_BYTES = 8.0


class DispatchCost(NamedTuple):
    """Traced pricing of one query batch (all scalars, f32 bytes)."""

    cost_targeted: Array  # wire + per-host work + routing-tier overhead
    cost_fanall: Array  # wire + per-host work at full fan-out
    wire_targeted: Array  # est. cross-host all-gather bytes, eligible subset
    wire_fanall: Array  # est. cross-host all-gather bytes, whole fleet


def price_dispatch(
    table: RoutingTable, elig: Array, sel: Array, kk: int, *, n_dim: int
) -> DispatchCost:
    """Price both dispatch modes for a batch with eligibility ``elig``
    (Q, S) and scan selection ``sel`` (Q, I)."""
    qn, s_hosts = elig.shape
    n_idx = table.count_hi.shape[1]
    payload = kk * _PAIR_BYTES
    wire_t = jnp.sum(
        estimate_allgather_bytes(payload, jnp.sum(elig, axis=1))
    )
    wire_a = qn * estimate_allgather_bytes(payload, s_hosts)

    vec_bytes = 4.0 * n_dim  # one D-dim f32 row read
    sel_f = sel.astype(jnp.float32)
    # per-(query, host) work if the host participates
    b_qh = sel_f @ table.nbuckets_hi.T.astype(jnp.float32)  # bound evals
    m_qh = sel_f @ table.count_hi.T.astype(jnp.float32)  # selected members
    spill = jnp.minimum(
        jnp.float32(kk), table.host_counts.astype(jnp.float32)
    )  # (S,) scan floor: a participating host fills its kk-carry regardless
    work_qh = (n_idx + b_qh + jnp.maximum(m_qh, spill[None])) * vec_bytes
    work_t = jnp.sum(jnp.where(elig, work_qh, 0.0))
    work_a = jnp.sum(work_qh)

    # routing-tier overhead the homogeneous path skips: per query, distance
    # rows to S host centers and I delta pivots (index-center distances are
    # paid by the route step either way and cancel)
    overhead = qn * (s_hosts + n_idx) * vec_bytes
    return DispatchCost(
        cost_targeted=wire_t + work_t + overhead,
        cost_fanall=wire_a + work_a,
        wire_targeted=wire_t,
        wire_fanall=wire_a,
    )
