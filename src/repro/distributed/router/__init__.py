"""Multi-host routing tier over the shard islands (DIMS-style).

A small replicated-per-host :class:`RoutingTable` (host-region centers,
radii, member counts, per-(host, index) covers and the registered overlap
rates between host regions) lets every host answer, per query, *which hosts
can contain a top-k member* from metric lower bounds alone — and a cost
model prices the targeted dispatch against full fan-out with the same
collectives rule the HLO analyzer uses (``estimator.estimate_allgather_bytes``).

Layering: ``table.py`` builds the table and does the pure eligibility math;
``cost.py`` prices targeted vs fan-all; ``exec.py`` composes both with the
existing ``knn_island.sharded_search`` (its ``host_sel`` operand) into
``routed_search`` — same exactness contract, fewer hosts doing work.
"""
from repro.distributed.router.cost import DispatchCost, price_dispatch
from repro.distributed.router.exec import RouterStats, routed_search
from repro.distributed.router.table import (
    RoutingTable,
    build_routing_table,
    host_eligibility,
)

__all__ = [
    "DispatchCost",
    "RouterStats",
    "RoutingTable",
    "build_routing_table",
    "host_eligibility",
    "price_dispatch",
    "routed_search",
]
