"""The replicated global routing table + the per-query host-pruning rule.

``build_routing_table`` runs on the HOST (numpy, f64 accumulation) against
the logical (unpadded, unquantized) forest at build/load/rebuild-swap time
and mirrors the executor's placement arithmetic exactly: bucket rows pad to
``ceil(NB/S)*S`` and shard ``s`` owns the contiguous slice
``[s*W, (s+1)*W)``; delta rows pad to ``ceil(I/S)*S`` likewise.  A table
built for the wrong shard count would silently mis-describe ownership, so
the backend rebuilds it whenever the forest or the shard count changes
(including the ``load(..., layout=...)`` host-count clamp).

``host_eligibility`` is the pure device-side pruning rule (DIMS-style
metric lower bounds, adapted to forest-mode selection):

  upper bound   Sort every *selected* region cover — per-(host, index)
                bucket covers ``d(q, c_i) + radius_hi[h, i]`` and per-index
                delta covers ``d(q, delta_pivot_i) + delta_radius_i`` — by
                ascending bound and take the bound at which the cumulative
                member count first reaches ``kk``: at least ``kk`` selected
                members lie within ``ub_sel``, so the merged kth-best
                distance cannot exceed it.  Fewer than ``kk`` selected
                members total -> ``+inf`` (nothing is pruned; the scan's
                underfill spill may reach anything).
  lower bound   Per host, the selection-INDEPENDENT floor over everything
                the host could ever contribute — ``d(q, host_center) -
                host_radius`` for its forest members and ``d(q,
                delta_pivot_i) - delta_radius_i`` over its owned non-empty
                delta rows (delta radii are dynamic, so they fold in here
                rather than being baked into the table).  Selection
                independence matters: an underfilled scan spills into
                non-selected buckets, and those members must still be
                covered by the bound.

A host is pruned iff its lower bound strictly exceeds ``ub_sel`` plus a
small relative margin that absorbs f32 rounding; every candidate a pruned
host could produce then sits strictly beyond the merged kth-best, so
masking the host changes nothing — results stay bitwise identical
(tests/test_routed_exec.py gates this against fan-all and single-device).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# relative inflation applied to host-side covers before the f64 -> f32 cast:
# keeps every table radius a true upper bound after rounding (loosens
# pruning by ~1e-5, never tightens it)
_COVER_SLACK = 1e-5
# relative slack on the eligibility comparison itself — absorbs f32
# rounding in the device-side distance arithmetic (bounds are |q| + |r|
# magnitudes; 1e-4 is orders above f32 ulp noise)
_ELIG_MARGIN = 1e-4


class RoutingTable(NamedTuple):
    """Replicated per-host routing state (everything f32/i32, all small:
    O(S*I) — broadcast once, read by every query batch)."""

    host_centers: Array  # (S, D) f32 member-weighted pivot centroid per host
    host_radii: Array  # (S,) f32 cover of ALL owned forest members
    host_counts: Array  # (S,) i32 owned forest member counts
    radius_hi: Array  # (S, I) f32 cover of host s's index-i members around c_i
    count_hi: Array  # (S, I) i32 members of index i living on host s
    nbuckets_hi: Array  # (S, I) i32 non-empty buckets of index i on host s
    delta_owned: Array  # (S, I) bool: host s owns index i's delta buffer
    host_rates: Array  # (S, S) f32 registered overlap rates between regions


def _ceil_to(n: int, m: int) -> int:
    return -(-n // m) * m


def shard_owners(nb: int, shards: int) -> np.ndarray:
    """(NB,) owner shard per REAL bucket row under the executor's padding
    (rows pad to a shard multiple; shard s owns one contiguous slice)."""
    w = _ceil_to(max(nb, 1), shards) // shards
    return (np.arange(nb) // w).astype(np.int32)


def _conservative_f32(a: np.ndarray) -> np.ndarray:
    return ((1.0 + _COVER_SLACK) * a + _COVER_SLACK).astype(np.float32)


def _dequantized_members(xs: np.ndarray) -> np.ndarray:
    """Replicate kernels/ops.quantize_datastore's int8 round trip bitwise
    (same f32 IEEE ops, np.rint == jnp.round half-to-even): the positions
    a ``quantize=True`` scan actually measures distances to."""
    nb, cap, dim = xs.shape
    flat = xs.reshape(nb * cap, dim).astype(np.float32)
    scale = np.maximum(np.max(np.abs(flat), axis=1), 1e-8) / 127.0
    xq = np.clip(np.rint(flat / scale[:, None]), -127, 127)
    return (xq.astype(np.float32) * scale[:, None].astype(np.float32)).reshape(
        nb, cap, dim
    )


def build_routing_table(
    f, shards: int, *, method: str = "dbm", quantize: bool = False
) -> RoutingTable:
    """Host-side table build from the logical forest ``f`` (ForestArrays,
    f32 coordinates).  ``method`` resolves through the overlap-method
    registry, so VBM/DBM/OBM — or anything registered at runtime — rates
    the host regions; object-based methods see the real members with their
    owner-host assignment.

    ``quantize=True`` mirrors an int8 device layout: the scan measures
    distances to the DEQUANTIZED member positions, so every cover is
    recomputed around those (a true-member cover can undercut a quantized
    distance by up to a quantization step — far beyond the f32 margin —
    and silently prune a host that still holds a top-k candidate)."""
    from repro.core.overlap import get_overlap_method

    pivots = np.asarray(f.bucket_pivot, np.float64)  # (NB, D)
    radii = np.asarray(f.bucket_radius, np.float64)  # (NB,)
    mask = np.asarray(f.bucket_mask)  # (NB, C)
    bidx = np.asarray(f.bucket_index, np.int64)  # (NB,)
    centers = np.asarray(f.index_centers, np.float64)  # (I, D)
    nb, n_idx = pivots.shape[0], centers.shape[0]
    counts = mask.sum(axis=1).astype(np.int64)  # (NB,)
    owner = shard_owners(nb, shards)
    members = np.asarray(f.bucket_x, np.float32)  # (NB, C, D)
    if quantize:
        members = _dequantized_members(members)
        # per-bucket cover of the dequantized members around the pivot
        d_pm = np.linalg.norm(
            members.astype(np.float64) - pivots[:, None, :], axis=2
        )
        radii = np.where(mask, d_pm, 0.0).max(axis=1)

    host_centers = np.zeros((shards, pivots.shape[1]), np.float64)
    host_radii = np.zeros((shards,), np.float64)
    host_counts = np.zeros((shards,), np.int64)
    radius_hi = np.zeros((shards, n_idx), np.float64)
    count_hi = np.zeros((shards, n_idx), np.int64)
    nbuckets_hi = np.zeros((shards, n_idx), np.int64)
    # cover of index i's members around c_i, per bucket: d(c_i, pivot_b) + r_b
    d_cb = np.linalg.norm(
        centers[bidx.clip(0, n_idx - 1)] - pivots, axis=1
    ) + radii  # (NB,)
    for s in range(shards):
        rows = (owner == s) & (counts > 0)
        host_counts[s] = counts[rows].sum()
        if host_counts[s] == 0:
            continue
        host_centers[s] = (
            (pivots[rows] * counts[rows, None]).sum(axis=0) / host_counts[s]
        )
        host_radii[s] = (
            np.linalg.norm(pivots[rows] - host_centers[s], axis=1)
            + radii[rows]
        ).max()
        np.add.at(count_hi[s], bidx[rows], counts[rows])
        np.add.at(nbuckets_hi[s], bidx[rows], 1)
        np.maximum.at(radius_hi[s], bidx[rows], d_cb[rows])

    # delta-buffer ownership mirrors executor.place_delta's row padding
    wd = _ceil_to(max(n_idx, 1), shards) // shards
    delta_owned = (np.arange(n_idx) // wd)[None, :] == np.arange(shards)[:, None]

    entry = get_overlap_method(method)
    x_m = assign_m = None
    if entry.needs_objects:
        x_m = jnp.asarray(members[mask])
        assign_m = jnp.asarray(
            np.broadcast_to(owner[:, None], mask.shape)[mask]
        )
    rates = entry.matrix_fn(
        jnp.asarray(host_centers, jnp.float32),
        jnp.asarray(host_radii, jnp.float32),
        x=x_m,
        assign=assign_m,
    )

    return RoutingTable(
        host_centers=jnp.asarray(host_centers, jnp.float32),
        host_radii=jnp.asarray(_conservative_f32(host_radii)),
        host_counts=jnp.asarray(host_counts, jnp.int32),
        radius_hi=jnp.asarray(_conservative_f32(radius_hi)),
        count_hi=jnp.asarray(count_hi, jnp.int32),
        nbuckets_hi=jnp.asarray(nbuckets_hi, jnp.int32),
        delta_owned=jnp.asarray(delta_owned),
        host_rates=jnp.asarray(rates, jnp.float32),
    )


def host_eligibility(
    table: RoutingTable,
    d_center: Array,
    d_host: Array,
    sel: Array,
    kk: int,
    *,
    d_delta: Array | None = None,
    delta_radius: Array | None = None,
    delta_count: Array | None = None,
) -> tuple[Array, Array]:
    """(elig (Q, S) bool, ub_sel (Q,) f32) — the pruning rule.

    ``d_center`` (Q, I) and ``d_host`` (Q, S) are TRUE L2 distances to the
    index centers / host-region centers; ``sel`` (Q, I) is the same
    selection table the scan will use (pre host-masking).  The delta
    keywords carry the LIVE buffer state (pivot distances, radii, member
    counts for the logical I rows) — dynamic operands, never table state.
    """
    s_hosts, n_idx = table.count_hi.shape
    qn = d_center.shape[0]
    inf = jnp.float32(jnp.inf)

    # --- upper bound on the merged kth-best from SELECTED region covers ---
    valid_hi = sel[:, None, :] & (table.count_hi > 0)[None]  # (Q, S, I)
    vals = jnp.where(
        valid_hi, d_center[:, None, :] + table.radius_hi[None], inf
    ).reshape(qn, s_hosts * n_idx)
    cnts = jnp.where(valid_hi, table.count_hi[None], 0).reshape(
        qn, s_hosts * n_idx
    )
    if d_delta is not None:
        dvalid = sel & (delta_count > 0)[None]  # (Q, I)
        vals = jnp.concatenate(
            [vals, jnp.where(dvalid, d_delta + delta_radius[None], inf)],
            axis=1,
        )
        cnts = jnp.concatenate(
            [cnts, jnp.where(dvalid, delta_count[None], 0)], axis=1
        )
    order = jnp.argsort(vals, axis=1)
    vals_s = jnp.take_along_axis(vals, order, axis=1)
    cum = jnp.cumsum(jnp.take_along_axis(cnts, order, axis=1), axis=1)
    pos = jnp.argmax(cum >= kk, axis=1)
    filled = cum[:, -1] >= kk
    ub_sel = jnp.where(
        filled, jnp.take_along_axis(vals_s, pos[:, None], axis=1)[:, 0], inf
    )

    # --- per-host lower bound over EVERYTHING the host could contribute ---
    # Two valid covers of the host's forest members; take the tighter (max):
    #   * the single host ball (center + radius) — loose whenever contiguous
    #     row ownership straddles cluster boundaries (one far-away bucket
    #     inflates the ball over everything);
    #   * the per-(host, index) region covers — every owned member lies in
    #     some non-empty (h, i) region, so the min over regions of
    #     d(q, c_i) - radius_hi[h, i] lower-bounds all of them.
    lb_ball = jnp.where(
        (table.host_counts > 0)[None],
        jnp.maximum(d_host - table.host_radii[None], 0.0),
        inf,
    )  # (Q, S)
    lb_region = jnp.min(
        jnp.where(
            (table.count_hi > 0)[None],
            jnp.maximum(d_center[:, None, :] - table.radius_hi[None], 0.0),
            inf,
        ),
        axis=2,
    )  # (Q, S); +inf for empty hosts, matching lb_ball
    lb = jnp.maximum(lb_ball, lb_region)
    if d_delta is not None:
        lb_d_i = jnp.maximum(d_delta - delta_radius[None], 0.0)  # (Q, I)
        own_ne = table.delta_owned & (delta_count > 0)[None]  # (S, I)
        lb_d = jnp.min(
            jnp.where(own_ne[None], lb_d_i[:, None, :], inf), axis=2
        )  # (Q, S)
        lb = jnp.minimum(lb, lb_d)

    margin = _ELIG_MARGIN * (1.0 + jnp.where(jnp.isinf(ub_sel), 0.0, ub_sel))
    # empty hosts (lb == +inf) stay ineligible even when ub_sel == +inf —
    # they have nothing to contribute either way
    elig = (lb <= ub_sel[:, None] + margin[:, None]) & ~jnp.isinf(lb)
    return elig, ub_sel
