"""Analytic per-device HBM-traffic model for the roofline memory term.

Why this exists: the dry-run's HLO byte count (hlo_cost.py) measures tensor
traffic at the *CPU module's* fusion boundaries.  The CPU backend
materializes attention-score blocks that the TPU backend (or the Pallas
flash kernel) keeps in VMEM, so that number is a pessimistic upper bound —
up to ~100x for attention-heavy cells.  The roofline memory term instead
uses this explicit traffic model (every term is a real, nameable transfer),
and EXPERIMENTS.md reports the HLO number alongside as the bound.

Model (per device, per step):
  train:   3x local param reads (fwd + remat-fwd + bwd) + grad write/read
           + 2x optimizer-state read/write + scan-boundary activation
           save/restore + K_ACT passes over the per-layer activation
           working set + logits/loss traffic
  prefill: 1x params + K_ACT/3 activation passes + cache write
  decode:  1x params (every weight read per token!) + cache read + write
           + datastore scan (the paper's retrieval feature)
"""
from __future__ import annotations

import math
from typing import Any

import jax

from repro.configs.base import ModelConfig, ShapeConfig

K_ACT_TRAIN = 12.0  # activation passes per layer (fwd+remat+bwd, incl. norms)
K_ACT_FWD = 4.0


def estimate_allgather_bytes(
    payload_bytes: float, participants, *, factor: float | None = None
):
    """Cross-host wire bytes of a ring all-gather of ``payload_bytes`` per
    participant over ``participants`` hosts.

    This is the routing tier's pricing currency (distributed/router/cost.py):
    the kNN merge gathers each participating host's top-k (distance, id)
    pairs (core.knn.merge_shard_topk), so a query that fans to H hosts moves
    ``factor * payload * (H - 1)`` bytes — the same per-device traffic rule
    hlo_cost.py applies to measured all-gather ops.  ``participants`` may be
    a traced array (the router prices inside the compiled search program).
    """
    if factor is None:
        from repro.distributed.hlo_cost import COLLECTIVE_FACTORS

        factor = COLLECTIVE_FACTORS["all-gather"]
    import jax.numpy as jnp

    return factor * payload_bytes * jnp.maximum(
        jnp.asarray(participants, jnp.float32) - 1.0, 0.0
    )


def _local_bytes(tree_shape: Any, shardings: Any) -> int:
    """Exact per-device bytes of a sharded pytree (leaf size / shard count)."""
    total = 0
    for leaf, sh in zip(jax.tree.leaves(tree_shape), jax.tree.leaves(shardings)):
        n_shards = 1
        spec = sh.spec
        mesh = sh.mesh
        for axes in spec:
            if axes is None:
                continue
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                n_shards *= mesh.shape[a]
        total += leaf.size * leaf.dtype.itemsize // max(n_shards, 1)
    return total


def estimate_memory_bytes(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    *,
    params_local: int,
    opt_local: int = 0,
    cache_local: int = 0,
    datastore_local: int = 0,
) -> dict[str, float]:
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    batch_shards = math.prod(mesh.shape[a] for a in axes) if axes else 1
    model_shards = mesh.shape.get("model", 1)
    act_dt = 2  # bf16 activations
    b_loc = max(shape.global_batch // batch_shards, 1)
    seq_div = model_shards if cfg.seq_shard_activations else 1

    if shape.kind == "train":
        t_loc = b_loc * shape.seq_len
        n_units = cfg.num_layers if cfg.family != "hybrid" else cfg.num_layers
        boundary = n_units * (t_loc // seq_div) * cfg.d_model * act_dt * 2
        layer_ws = cfg.num_layers * (t_loc // max(cfg.grad_accum, 1)) \
            * cfg.d_model * act_dt * K_ACT_TRAIN
        logits = 3 * (t_loc // max(cfg.grad_accum, 1)) * (cfg.padded_vocab // model_shards) * 4
        params_traffic = 3 * params_local + 2 * params_local  # + grads w/r
        opt_traffic = 2 * opt_local
        total = params_traffic + opt_traffic + boundary + layer_ws + logits
        parts = {
            "params": params_traffic, "optimizer": opt_traffic,
            "scan_boundaries": boundary, "layer_working_set": layer_ws,
            "logits": logits,
        }
    elif shape.kind == "prefill":
        t_loc = b_loc * shape.seq_len
        layer_ws = cfg.num_layers * t_loc * cfg.d_model * act_dt * K_ACT_FWD
        cache_w = cache_local
        total = params_local + layer_ws + cache_w
        parts = {"params": params_local, "layer_working_set": layer_ws,
                 "cache_write": cache_w}
    else:  # decode
        total = params_local + cache_local + datastore_local \
            + cfg.num_layers * b_loc * cfg.d_model * act_dt * K_ACT_FWD
        parts = {
            "params": params_local, "cache": cache_local,
            "datastore": datastore_local,
            "activations": cfg.num_layers * b_loc * cfg.d_model * act_dt * K_ACT_FWD,
        }
    parts["total"] = float(total)
    return parts
