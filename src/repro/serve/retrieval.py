"""kNN-LM retrieval at the LM head — the paper's technique as a first-class
serving feature.

The datastore holds (key, next-token) pairs organized by the paper's
overlap-optimized forest (core/).  At each decode step the hidden state
queries the datastore; the neighbor distribution is interpolated with the
model distribution:

    p(y) = lam * p_knn(y) + (1 - lam) * p_lm(y)
    p_knn(y)  proportional to  sum_{(k_i, v_i) in topK, v_i = y} exp(-d_i / T)

Distributed layout: the datastore is sharded over the 'model' axis inside a
shard_map island — each shard scans its local rows with the fused Pallas
distance+top-k kernel, then a k-per-shard all_gather + global top-k merges
(collective volume: k * (1 + 1) floats per query per shard, NOT the
datastore).  Alg. 2's "run kNN on the selected indexes in parallel" maps
exactly onto this island (DESIGN.md §3).

Datastore variants:
  * flat      — brute-force shard scan (fused kernel), exact;
  * forest    — the paper's overlap-optimized forest, pruned scan (host
                builds the forest; device search via core.knn);
  * quantized — int8 rows (beyond-paper memory-roofline lever, kernels/).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import context as dctx
from repro.kernels import ops as kops

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclass
class Datastore:
    keys: Array  # (N, Dk) f32 or int8 (quantized)
    values: Array  # (N,) i32 token ids
    scale: Array | None = None  # (N,) per-row int8 scales
    proj: Array | None = None  # (D, Dk) optional query down-projection


def build_flat_datastore(
    keys: np.ndarray, values: np.ndarray, *, quantized: bool = False
) -> Datastore:
    k = jnp.asarray(keys, jnp.float32)
    if quantized:
        kq, scale = kops.quantize_datastore(k)
        return Datastore(keys=kq, values=jnp.asarray(values, jnp.int32), scale=scale)
    return Datastore(keys=k, values=jnp.asarray(values, jnp.int32))


@jax.tree_util.register_dataclass
@dataclass
class ForestDatastore:
    """The paper's overlap-optimized forest as a kNN-LM datastore: queries
    run the pruned masked-bucket scan (core/knn.py) instead of the flat
    shard scan — the fraction of rows touched is the paper's whole point
    (benchmarks/bench_retrieval.py measures it).

    ``delta`` (a stream.ingest.DeltaBuffer, present when the datastore was
    built with ``stream_capacity > 0``) holds streamed (key, token) pairs
    appended at serve time (engine IngestRequest); the search scans it as
    the second phase of the same fused bucket scan.  ``n_main`` is the
    frozen build-time row count; streamed rows take global ids from
    ``n_main`` upward, indexing the preallocated tail of ``values``.
    ``next_id`` is the id high-water mark — it lives ON the datastore (not
    in any engine) so every ingest path shares one id space and an id can
    never be issued twice or past the values tail."""

    forest: Any  # core.knn.DeviceForest
    values: Array  # (N_objects + stream capacity,) i32, by global object id
    delta: Any = None  # stream.ingest.DeltaBuffer | None
    n_main: int = 0
    next_id: int = 0
    # device layout (static: search/ingest branch on it at trace time).
    # 1 = single device; >1 = forest bucket rows + delta buffers sharded over
    # that many devices on the 'model' axis, searches run the
    # distributed/knn_island.py islands.
    shards: int = dataclasses.field(default=1, metadata=dict(static=True))
    # routing tier (routed layout): the replicated RoutingTable rides as a
    # TRACED pytree leaf — a rebuild-swapped table reaches compiled decode
    # steps as a fresh operand — while the dispatch policy is static
    router_table: Any = None  # distributed.router.RoutingTable | None
    fanout: str | None = dataclasses.field(
        default=None, metadata=dict(static=True)
    )


def datastore_from_index(
    ix,
    values: np.ndarray,
    *,
    stream_capacity: int = 0,
    quantized: bool | None = None,
) -> ForestDatastore:
    """Wrap a built ``repro.api.OverlapIndex`` as a serving datastore — the
    implementation behind ``OverlapIndex.to_datastore``.

    ``values[i]`` pairs with object id ``i`` (one per ``ix.n_total``
    object, streamed members included).  The index's live delta buffers (if
    any) ride along unchanged, so already-streamed pairs stay retrievable;
    ``stream_capacity > 0`` preallocates a values tail for that many FUTURE
    serve-side inserts (``ingest_keys`` stops issuing ids at the tail end,
    so an accepted key can never index past it) and — when the index has no
    delta yet — per-index buffers sized ``2 * stream_capacity / n_indexes``
    (floor 32): 2x headroom for routing skew without multiplying memory by
    the index count; a pathologically skewed stream hits the reported
    capacity-reject path instead.

    The index's device layout rides along: forest upload and delta placement
    go through ``ix.backend``, so a sharded index serves a sharded datastore
    (``shards`` recorded on the result) and searches keep running the same
    islands — bitwise-identical to serving the single-device layout."""
    from repro.stream.ingest import alloc_delta

    values = np.asarray(values)
    if len(values) != ix.n_total:
        raise ValueError(
            f"need one value per indexed object: got {len(values)} values "
            f"for {ix.n_total} objects"
        )
    device = (
        ix.device if quantized is None
        else ix.backend.upload_forest(ix.forest, quantize=quantized)
    )
    delta = ix.device_delta  # placed (padded + sharded under that layout)
    vals = jnp.asarray(values, jnp.int32)
    if stream_capacity > 0:
        if delta is None:
            capd = min(
                stream_capacity, -(-2 * stream_capacity // ix.forest.n_indexes)
            )
            delta = ix.backend.place_delta(
                alloc_delta(ix.forest, max(32, capd))
            )
        vals = jnp.concatenate([vals, jnp.zeros((stream_capacity,), jnp.int32)])
    return ForestDatastore(
        forest=device,
        values=vals,
        delta=delta,
        n_main=ix.n_total,
        next_id=ix.n_total,
        shards=ix.backend.shards,
        # routed layout: the backend's table is live after the device upload
        # above; non-routed backends have no table attribute
        router_table=getattr(ix.backend, "table", None),
        fanout=(
            ix.cfg.layout.routing.fanout
            if ix.backend.kind == "routed" else None
        ),
    )


def build_forest_datastore(
    keys: np.ndarray,
    values: np.ndarray,
    *,
    method: str = "vbm",
    eps: float | None = None,
    min_pts: int = 16,
    quantized: bool = False,
    stream_capacity: int = 0,
) -> ForestDatastore:
    """Build the paper's index over the datastore keys and wrap it for
    serving — ``OverlapIndex.build(keys, ...).to_datastore(values, ...)``
    with an eps default derived from the keys (k-dist style heuristic)."""
    from repro.api import Config, IndexConfig, OverlapIndex, SearchConfig

    keys = np.asarray(keys, np.float32)
    if eps is None:
        # k-dist style heuristic: median NN distance of a sample x 2
        g = np.random.default_rng(0)
        sample = keys[g.choice(len(keys), min(2048, len(keys)), replace=False)]
        d2 = ((sample[:, None, :] - sample[None, :, :]) ** 2).sum(-1)
        np.fill_diagonal(d2, np.inf)
        eps = 2.0 * float(np.sqrt(np.median(d2.min(axis=1))))
    ix = OverlapIndex.build(keys, Config(
        index=IndexConfig(method=method, eps=eps, min_pts=min_pts, dbscan_block=2048),
        search=SearchConfig(quantize=quantized),
    ))
    return ix.to_datastore(values, stream_capacity=stream_capacity)


def ingest_keys(
    ds: ForestDatastore, keys: Array, values: Array
) -> tuple[ForestDatastore, int]:
    """Stream (key, token) pairs into a forest datastore's delta buffers.

    Routes + appends via stream.ingest (Alg. 2 STEP-1 routing on device),
    writes token values at the assigned global ids.  Two-phase so the id
    space never leaks: a PROBE ingest (result discarded) learns which pairs
    the buffers will accept, then ids from ``ds.next_id`` are issued to
    exactly those pairs (clamped to the values-tail room) and committed.
    Ids are therefore only ever consumed by pairs that are actually stored
    — a capacity-rejected or tail-refused pair burns nothing and can be
    re-submitted later.  Returns the updated datastore and the number of
    ACCEPTED pairs (the serving tier reports rejects back to the client
    rather than blocking the decode loop on a rebuild; the offline
    StreamingForest wrapper is the no-loss path).
    """
    if ds.delta is None:
        raise ValueError("datastore built without stream_capacity")
    next_id = int(ds.next_id)
    room = ds.values.shape[0] - next_id
    if room <= 0:
        return ds, 0
    keys_j = jnp.asarray(keys, jnp.float32)
    _, acc = _run_ingest(  # probe: same state + same routing => same acceptance
        ds, keys_j, jnp.full((keys_j.shape[0],), -1, jnp.int32)
    )
    # Dropping rejected rows cannot demote an accepted one: within each
    # destination run the kept rows' slot ranks only shrink.
    take = np.flatnonzero(np.asarray(acc))[:room]
    if take.size == 0:
        return ds, 0
    ids = jnp.arange(next_id, next_id + take.size, dtype=jnp.int32)
    new_delta, _ = _run_ingest(ds, keys_j[take], ids)
    new_values = ds.values.at[ids].set(
        jnp.asarray(np.asarray(values)[take], jnp.int32)
    )
    return (
        dataclasses.replace(
            ds, values=new_values, delta=new_delta,
            next_id=next_id + int(take.size),
        ),
        int(take.size),
    )


def _run_ingest(ds: ForestDatastore, keys_j: Array, ids: Array):
    """Route + append one batch under the datastore's device layout: the
    single-device ``stream.ingest`` executor, or the collective-scatter
    island when the buffers are sharded."""
    from repro.stream.ingest import ingest

    if ds.shards > 1:
        from repro.distributed import knn_island

        return knn_island.sharded_ingest(
            knn_island.default_mesh(ds.shards), dctx.MODEL_AXIS,
            ds.forest.index_centers, ds.delta, keys_j, ids,
            jnp.ones((keys_j.shape[0],), jnp.bool_),
        )
    return ingest(ds.forest, ds.delta, keys_j, ids)


def forest_knn(
    hidden: Array, ds: ForestDatastore, k: int, *, kernel: bool = True
) -> tuple[Array, Array]:
    """(distances (B,k), token values (B,k)) via the paper's Alg. 2 search.

    ``kernel`` selects the kernels/ops dispatch path (fused Pallas bucket
    scan on TPU) vs the pure-jnp reference — see core.knn.knn_search_impl.
    Streaming deltas, when present, are scanned as the second phase.
    (Executor, not the legacy jitted entry: this runs INSIDE the engine's
    jitted decode step, which is the compilation boundary.)
    """
    from repro.core.knn import knn_search_impl
    from repro.stream.ingest import delta_view

    delta = None if ds.delta is None else delta_view(ds.delta)
    if ds.shards > 1 and ds.router_table is not None:
        from repro.distributed import router as drouter
        from repro.distributed import knn_island

        d, ids, *_ = drouter.routed_search(
            knn_island.default_mesh(ds.shards), dctx.MODEL_AXIS,
            ds.forest, hidden.astype(jnp.float32), delta, ds.router_table,
            k=k, mode="forest", kernel=kernel,
            fanout=ds.fanout or "auto",
        )
    elif ds.shards > 1:
        from repro.distributed import knn_island

        d, ids, _ = knn_island.sharded_search(
            knn_island.default_mesh(ds.shards), dctx.MODEL_AXIS,
            ds.forest, hidden.astype(jnp.float32), delta,
            k=k, mode="forest", kernel=kernel,
        )
    else:
        d, ids, _ = knn_search_impl(
            ds.forest, hidden.astype(jnp.float32), k=k, mode="forest",
            kernel=kernel, delta=delta,
        )
    vals = ds.values[jnp.clip(ids, 0, ds.values.shape[0] - 1)]
    vals = jnp.where(ids >= 0, vals, 0)
    d = jnp.where(ids >= 0, d, jnp.inf)
    return d * d, vals  # squared distances, matching the flat path


def _local_topk(q: Array, ds: Datastore, k: int) -> tuple[Array, Array]:
    if ds.scale is not None:
        d2 = kops.pairwise_sq_l2_int8(q, ds.keys, ds.scale)
        neg, idx = jax.lax.top_k(-d2, k)
        return -neg, idx
    return kops.knn_topk(q, ds.keys, k=k)


def knn_logits(
    hidden: Array, ds: Datastore, cfg: ModelConfig
) -> Array:
    """p_knn over the padded vocab from datastore neighbors of ``hidden``.

    hidden: (B, D). Runs the sharded scan when a mesh with a 'model' axis is
    active, single-shard otherwise.
    """
    r = cfg.retrieval
    if isinstance(ds, ForestDatastore):
        d2, vals = forest_knn(hidden, ds, r.k, kernel=r.kernel)
        w = jax.nn.softmax(-jnp.sqrt(jnp.maximum(d2, 0.0)) / r.temperature, axis=-1)
        p_knn = jnp.zeros((hidden.shape[0], cfg.padded_vocab), jnp.float32)
        return p_knn.at[jnp.arange(hidden.shape[0])[:, None], vals].add(w)
    q = hidden.astype(jnp.float32)
    if ds.proj is not None:
        q = q @ ds.proj.astype(jnp.float32)

    mesh = dctx.current_mesh()
    tp = dctx.model_axis_size(mesh)
    if mesh is None or tp == 1:
        d2, idx = _local_topk(q, ds, r.k)
        vals = ds.values[idx]  # (B, k)
    else:
        def island(q_l, keys, values, scale):
            from repro.core.knn import merge_shard_topk

            ds_l = Datastore(keys=keys, values=values, scale=scale)
            d2_l, idx_l = _local_topk(q_l, ds_l, r.k)
            # k candidates per shard -> exact global top-k; the identical
            # merge the forest island runs (collective volume is k pairs per
            # query per shard, never the datastore)
            return merge_shard_topk(
                d2_l, values[idx_l], k=r.k, axis_name=dctx.MODEL_AXIS
            )

        scale_spec = P(dctx.MODEL_AXIS) if ds.scale is not None else None
        d2, vals = dctx.shard_map(
            island,
            mesh=mesh,
            in_specs=(P(), P(dctx.MODEL_AXIS, None), P(dctx.MODEL_AXIS), scale_spec),
            out_specs=(P(), P()),
            check_vma=False,
        )(q, ds.keys, ds.values, ds.scale)

    w = jax.nn.softmax(-jnp.sqrt(jnp.maximum(d2, 0.0)) / r.temperature, axis=-1)  # (B, k)
    vocab = cfg.padded_vocab
    p_knn = jnp.zeros((hidden.shape[0], vocab), jnp.float32)
    p_knn = p_knn.at[jnp.arange(hidden.shape[0])[:, None], vals].add(w)
    return p_knn


def knn_interpolate(logits: Array, hidden: Array, ds: Datastore, cfg: ModelConfig) -> Array:
    """log of lam * p_knn + (1 - lam) * softmax(logits)."""
    lam = cfg.retrieval.lam
    p_lm = jax.nn.softmax(logits, axis=-1)
    p_knn = knn_logits(hidden, ds, cfg)
    return jnp.log(jnp.maximum((1.0 - lam) * p_lm + lam * p_knn, 1e-20))
