"""kNN-LM retrieval at the LM head — the paper's technique as a first-class
serving feature.

The datastore holds (key, next-token) pairs organized by the paper's
overlap-optimized forest (core/).  At each decode step the hidden state
queries the datastore; the neighbor distribution is interpolated with the
model distribution:

    p(y) = lam * p_knn(y) + (1 - lam) * p_lm(y)
    p_knn(y)  proportional to  sum_{(k_i, v_i) in topK, v_i = y} exp(-d_i / T)

Distributed layout: the datastore is sharded over the 'model' axis inside a
shard_map island — each shard scans its local rows with the fused Pallas
distance+top-k kernel, then a k-per-shard all_gather + global top-k merges
(collective volume: k * (1 + 1) floats per query per shard, NOT the
datastore).  Alg. 2's "run kNN on the selected indexes in parallel" maps
exactly onto this island (DESIGN.md §3).

Datastore variants:
  * flat      — brute-force shard scan (fused kernel), exact;
  * forest    — the paper's overlap-optimized forest, pruned scan (host
                builds the forest; device search via core.knn);
  * quantized — int8 rows (beyond-paper memory-roofline lever, kernels/).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import context as dctx
from repro.kernels import ops as kops

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclass
class Datastore:
    keys: Array  # (N, Dk) f32 or int8 (quantized)
    values: Array  # (N,) i32 token ids
    scale: Array | None = None  # (N,) per-row int8 scales
    proj: Array | None = None  # (D, Dk) optional query down-projection


def build_flat_datastore(
    keys: np.ndarray, values: np.ndarray, *, quantized: bool = False
) -> Datastore:
    k = jnp.asarray(keys, jnp.float32)
    if quantized:
        kq, scale = kops.quantize_datastore(k)
        return Datastore(keys=kq, values=jnp.asarray(values, jnp.int32), scale=scale)
    return Datastore(keys=k, values=jnp.asarray(values, jnp.int32))


@jax.tree_util.register_dataclass
@dataclass
class ForestDatastore:
    """The paper's overlap-optimized forest as a kNN-LM datastore: queries
    run the pruned masked-bucket scan (core/knn.py) instead of the flat
    shard scan — the fraction of rows touched is the paper's whole point
    (benchmarks/bench_retrieval.py measures it)."""

    forest: Any  # core.knn.DeviceForest
    values: Array  # (N_objects,) i32, indexed by global object id


def build_forest_datastore(
    keys: np.ndarray,
    values: np.ndarray,
    *,
    method: str = "vbm",
    eps: float | None = None,
    min_pts: int = 16,
    quantized: bool = False,
) -> ForestDatastore:
    """Build the paper's index over the datastore keys (host-side, like any
    vector store's build path).  ``quantized`` stores bucket members int8
    (device_forest's storage knob) — bounds stay f32, only the member scan
    dequantizes in-register."""
    from repro.core import IndexConfig, build_index
    from repro.core.knn import device_forest

    if eps is None:
        # k-dist style heuristic: median NN distance of a sample x 2
        g = np.random.default_rng(0)
        sample = keys[g.choice(len(keys), min(2048, len(keys)), replace=False)]
        d2 = ((sample[:, None, :] - sample[None, :, :]) ** 2).sum(-1)
        np.fill_diagonal(d2, np.inf)
        eps = 2.0 * float(np.sqrt(np.median(d2.min(axis=1))))
    cfg = IndexConfig(method=method, eps=eps, min_pts=min_pts, dbscan_block=2048)
    forest, _ = build_index(np.asarray(keys, np.float32), cfg)
    return ForestDatastore(
        forest=device_forest(forest, quantize=quantized),
        values=jnp.asarray(values, jnp.int32),
    )


def forest_knn(
    hidden: Array, ds: ForestDatastore, k: int, *, kernel: bool = True
) -> tuple[Array, Array]:
    """(distances (B,k), token values (B,k)) via the paper's Alg. 2 search.

    ``kernel`` selects the kernels/ops dispatch path (fused Pallas bucket
    scan on TPU) vs the pure-jnp reference — see core.knn.knn_search.
    """
    from repro.core.knn import knn_search

    d, ids, _ = knn_search(
        ds.forest, hidden.astype(jnp.float32), k=k, mode="forest", kernel=kernel
    )
    vals = ds.values[jnp.clip(ids, 0, ds.values.shape[0] - 1)]
    vals = jnp.where(ids >= 0, vals, 0)
    d = jnp.where(ids >= 0, d, jnp.inf)
    return d * d, vals  # squared distances, matching the flat path


def _local_topk(q: Array, ds: Datastore, k: int) -> tuple[Array, Array]:
    if ds.scale is not None:
        d2 = kops.pairwise_sq_l2_int8(q, ds.keys, ds.scale)
        neg, idx = jax.lax.top_k(-d2, k)
        return -neg, idx
    return kops.knn_topk(q, ds.keys, k=k)


def knn_logits(
    hidden: Array, ds: Datastore, cfg: ModelConfig
) -> Array:
    """p_knn over the padded vocab from datastore neighbors of ``hidden``.

    hidden: (B, D). Runs the sharded scan when a mesh with a 'model' axis is
    active, single-shard otherwise.
    """
    r = cfg.retrieval
    if isinstance(ds, ForestDatastore):
        d2, vals = forest_knn(hidden, ds, r.k, kernel=r.kernel)
        w = jax.nn.softmax(-jnp.sqrt(jnp.maximum(d2, 0.0)) / r.temperature, axis=-1)
        p_knn = jnp.zeros((hidden.shape[0], cfg.padded_vocab), jnp.float32)
        return p_knn.at[jnp.arange(hidden.shape[0])[:, None], vals].add(w)
    q = hidden.astype(jnp.float32)
    if ds.proj is not None:
        q = q @ ds.proj.astype(jnp.float32)

    mesh = dctx.current_mesh()
    tp = dctx.model_axis_size(mesh)
    if mesh is None or tp == 1:
        d2, idx = _local_topk(q, ds, r.k)
        vals = ds.values[idx]  # (B, k)
    else:
        def island(q_l, keys, values, scale):
            ds_l = Datastore(keys=keys, values=values, scale=scale)
            d2_l, idx_l = _local_topk(q_l, ds_l, r.k)
            v_l = values[idx_l]
            # gather k candidates per shard -> (B, tp * k), merge exactly
            d2_all = jax.lax.all_gather(d2_l, dctx.MODEL_AXIS, axis=1, tiled=True)
            v_all = jax.lax.all_gather(v_l, dctx.MODEL_AXIS, axis=1, tiled=True)
            neg, pos = jax.lax.top_k(-d2_all, r.k)
            return -neg, jnp.take_along_axis(v_all, pos, axis=1)

        scale_spec = P(dctx.MODEL_AXIS) if ds.scale is not None else None
        d2, vals = dctx.shard_map(
            island,
            mesh=mesh,
            in_specs=(P(), P(dctx.MODEL_AXIS, None), P(dctx.MODEL_AXIS), scale_spec),
            out_specs=(P(), P()),
            check_vma=False,
        )(q, ds.keys, ds.values, ds.scale)

    w = jax.nn.softmax(-jnp.sqrt(jnp.maximum(d2, 0.0)) / r.temperature, axis=-1)  # (B, k)
    vocab = cfg.padded_vocab
    p_knn = jnp.zeros((hidden.shape[0], vocab), jnp.float32)
    p_knn = p_knn.at[jnp.arange(hidden.shape[0])[:, None], vals].add(w)
    return p_knn


def knn_interpolate(logits: Array, hidden: Array, ds: Datastore, cfg: ModelConfig) -> Array:
    """log of lam * p_knn + (1 - lam) * softmax(logits)."""
    lam = cfg.retrieval.lam
    p_lm = jax.nn.softmax(logits, axis=-1)
    p_knn = knn_logits(hidden, ds, cfg)
    return jnp.log(jnp.maximum((1.0 - lam) * p_lm + lam * p_knn, 1e-20))
