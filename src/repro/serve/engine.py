"""Production serving front: continuous batching, per-request deadlines
with admission control + load shedding, and query/ingest fairness — with
kNN-LM retrieval (the paper's datastore) fused into every decode step.

The traffic model (see serve/README.md for the full lifecycle):

* **continuous batching** — a fixed decode batch of ``num_slots``;
  finished/expired/empty slots are refilled from the request queue between
  steps.  The jitted decode step never recompiles because shapes are
  static, and per-slot cache positions make mid-flight refill *safe*: one
  step advances every slot at ITS own position (position-masked attention;
  see layers.decode_attention), so a freshly admitted request decodes from
  its own prompt length while its neighbors are deep into generation;
* **deadlines + load shedding** — ``Request.deadline_s`` is a latency
  budget relative to submit.  Admission control rejects at ``submit()``
  when the *projected* queue wait (measured decode-step time x backlog
  work / slots) already exceeds the budget; queued requests whose budget
  expires are shed before they waste a prefill; a mid-flight request whose
  budget expires is evicted from its slot before the next step.  Every
  shed is terminal (``req.shed``/``req.shed_reason``) and counted under
  ``serve.shed{reason=...}``, and the conservation invariant
  ``submitted == completed + shed + in_flight`` holds at every step
  boundary (tests/test_serve_front.py pins it);
* **query/ingest fairness** — mixed read+write traffic shares the engine;
  ``_drain_ingest`` applies at most ``max_ingest_per_step`` ingest batches
  between decode steps, so a sustained ingest stream can no longer starve
  queued queries (each deferral increments ``serve.ingest_deferred``);
* **retrieval** — the datastore is an ARGUMENT of the jitted decode step
  (not a closure capture): delta shapes are fixed at build, so ingest
  swaps buffer contents without a single recompile;
* **telemetry** (repro.obs): request/ingest latency histograms with
  serving percentiles, queue-depth / slot-occupancy gauges, shed and
  fairness counters, prefill/decode-step span timings —
  ``engine.metrics()`` snapshots them all, and sampled requests emit a
  linked span tree (queue wait -> prefill -> completion root) for
  ``Trace.reconstruct``.

``run()`` drives the queues to completion (offline / test harness);
``step()`` is one scheduler iteration, exposed so an open-loop driver
(benchmarks/bench_serve.py) can interleave arrivals with service exactly
as a network front would.

Single-host implementation of the multi-host pattern: on a real mesh the
same engine runs with params/caches sharded exactly as in the dry-run.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.obs import Registry, TraceContext, TraceSampler, use_trace
from repro.serve.retrieval import Datastore, ForestDatastore, ingest_keys

PyTree = Any

# shed reasons (Request.shed_reason / serve.shed{reason=...} counter labels)
SHED_REJECTED = "rejected"  # admission control refused at submit()
SHED_EXPIRED_QUEUE = "expired_queue"  # deadline passed while waiting in queue
SHED_EXPIRED_FLIGHT = "expired_flight"  # deadline passed while decoding
# speculative early expiry: the deadline has NOT lapsed yet, but the tokens
# still owed x the measured step time already overrun it — shedding now
# returns the slot instead of burning doomed decode steps until the clock
# catches up
SHED_EARLY = "early"


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    # latency budget in seconds, relative to submit(); None = no deadline
    # (never rejected, never expired — the pre-deadline behavior)
    deadline_s: float | None = None
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False  # completed normally (terminal)
    shed: bool = False  # load-shed (terminal; never set together with done)
    shed_reason: str = ""  # one of the SHED_* constants when shed
    # submit -> terminal state, queue wait INCLUDED (completed OR shed) —
    # the latency a client sees, and what the deadline budgets against
    latency_s: float = 0.0
    # tracing: assigned at submit() by the engine's sampler (or preset by
    # the caller); sampled requests emit a linked span tree — queue wait,
    # prefill, and a "serve.request_latency_s" root — into the registry's
    # event log
    trace: TraceContext | None = None
    _t0: float = 0.0  # perf_counter at slot admission (queue-wait accounting)
    _t_submit: float = 0.0  # perf_counter at submit (queue-wait accounting)
    _t_deadline: float = 0.0  # absolute perf_counter deadline (0 = none)

    @property
    def state(self) -> str:
        """Terminal: ``"done"`` / ``"shed"``; live: ``"running"`` (owns a
        slot) / ``"queued"`` (submitted) / ``"new"`` (never submitted)."""
        if self.shed:
            return "shed"
        if self.done:
            return "done"
        if self._t0 > 0.0:
            return "running"
        return "queued" if self._t_submit > 0.0 else "new"


@dataclass
class IngestRequest:
    """Insert (key, next-token) pairs into the serving datastore's delta.

    Requires a ForestDatastore built with ``stream_capacity > 0``.
    ``accepted`` reports how many pairs fit the destination buffers (the
    rest were capacity-rejected; clients re-submit after maintenance)."""

    rid: int
    keys: np.ndarray  # (B, Dk) f32
    values: np.ndarray  # (B,) i32 token ids
    accepted: int = 0
    done: bool = False
    latency_s: float = 0.0
    error: str = ""  # non-empty when the engine could not ingest at all


class ServeEngine:
    def __init__(
        self,
        model: Model,
        params: PyTree,
        *,
        num_slots: int = 4,
        max_len: int = 256,
        datastore: Datastore | None = None,
        greedy: bool = True,
        registry: Registry | None = None,
        trace_sample: float = 0.0,
        max_ingest_per_step: int = 8,
        step_time_hint_s: float | None = None,
    ):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.datastore = datastore
        self.greedy = greedy
        self.cache = model.init_cache(num_slots, max_len)
        self.slot_req: list[Request | None] = [None] * num_slots
        self.slot_pos = np.zeros(num_slots, np.int32)
        self.queue: list[Request] = []
        self.ingest_queue: list[IngestRequest] = []
        self._decode = jax.jit(self._decode_step)
        # slot refill is jitted end to end (prefill + cache merge + first
        # token): eagerly it costs ~1000 decode steps of per-op dispatch on
        # CPU, which would make admission — not decode — the bottleneck.
        # Re-traces once per distinct prompt LENGTH (shapes are static);
        # fronts with wildly variable prompts should pad to a few buckets.
        self._prefill = jax.jit(self._prefill_merge)
        self.steps = 0
        # query/ingest fairness: at most this many ingest batches apply per
        # scheduler step, so a saturating write stream cannot starve reads
        if max_ingest_per_step < 1:
            raise ValueError(
                f"max_ingest_per_step={max_ingest_per_step} must be >= 1 "
                "(ingest batches applied between decode steps)"
            )
        self.max_ingest_per_step = max_ingest_per_step
        # admission control's service-time model: median of recent decode
        # step wall times (a median shrugs off the compile-heavy first
        # step, which an EWMA would drag around for dozens of steps).
        # ``step_time_hint_s`` seeds it for deterministic admission before
        # the first measured step (tests; cold engines admit everything).
        self._step_times: deque[float] = deque(maxlen=32)
        if step_time_hint_s is not None:
            self._step_times.append(float(step_time_hint_s))
        # serving telemetry (repro.obs): request/ingest latency percentile
        # histograms + queue-depth / slot-occupancy gauges replace the old
        # scatter of per-request perf_counter fields as the ENGINE's view
        # (requests keep their latency_s for per-request callers)
        self.obs = registry if registry is not None else Registry()
        # per-request tracing: ``trace_sample`` of submitted decode requests
        # get a TraceContext (deterministic systematic sampling); their
        # queue-wait/prefill spans and completion root land in the
        # registry's event log for Trace.reconstruct
        self._tracer = TraceSampler(trace_sample)

    def metrics(self) -> dict[str, Any]:
        """One snapshot of the engine's registry: ``serve.*`` latency
        histograms (seconds, p50/p95/p99), queue/slot gauges, shed and
        fairness counters, and step/token counters."""
        return self.obs.snapshot()

    def reset_metrics(self, registry: Registry | None = None) -> Registry:
        """Swap the engine onto a fresh (or provided) registry and return
        it.  The service-time model and compiled programs persist — this
        exists so a sweep (benchmarks/bench_serve.py) can isolate each
        operating point's percentiles without rebuilding the engine."""
        self.obs = registry if registry is not None else Registry()
        return self.obs

    @property
    def busy(self) -> bool:
        """True while any work remains (live slots, queued decodes, or a
        pending ingest backlog)."""
        return (
            any(r is not None for r in self.slot_req)
            or bool(self.queue)
            or bool(self.ingest_queue)
        )

    # --- admission control --------------------------------------------------
    def step_time_s(self) -> float | None:
        """Current decode-step service-time estimate (median of recent
        measured steps), or None before any step ran."""
        if not self._step_times:
            return None
        return float(np.median(self._step_times))

    def projected_wait_s(self) -> float:
        """Projected queue wait for a request submitted NOW: the backlog's
        remaining decode work (tokens still owed to live slots + every
        queued request's full budget) drained through ``num_slots`` servers
        at the measured step time.  FCFS: a new request starts once that
        backlog has dispatched.  0.0 on a cold engine (no estimate yet —
        admit and let measurements accumulate).  Prefill cost is
        deliberately excluded: it is one step-shaped unknown per request
        and the projection only needs to be honest about the *queue*, which
        decode steps dominate."""
        step_s = self.step_time_s()
        if step_s is None:
            return 0.0
        inflight = sum(
            max(r.max_new_tokens - len(r.out_tokens), 0)
            for r in self.slot_req if r is not None
        )
        queued = sum(r.max_new_tokens for r in self.queue)
        return step_s * (inflight + queued) / self.num_slots

    def _shed(self, req: Request, reason: str, now: float) -> None:
        """Terminal shed: mark, count, observe the wasted wait, and — for a
        sampled request — close its trace tree with a shed root."""
        req.shed = True
        req.shed_reason = reason
        req.latency_s = now - req._t_submit if req._t_submit else 0.0
        self.obs.counter("serve.shed", reason=reason).inc()
        # observes serve.shed_wait_s AND (sampled + event log) emits the
        # trace root, so a shed request's tree closes like a completed one's
        self.obs.emit_trace_root(req.trace, "serve.shed_wait_s", req.latency_s)

    # --- jitted single step over all slots -------------------------------
    # ``datastore`` is a traced argument: ingest swaps in new delta contents
    # between steps and the same compiled step sees them (shapes are static).
    def _decode_step(self, params, tokens, cache, pos, datastore):
        logits, cache = self.model.decode_step(
            params, tokens, cache, pos, datastore=datastore
        )
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, cache

    # --- jitted slot refill: prefill + merge into the slot's cache lane ----
    # ``slot`` is a traced scalar, so one compiled program serves every slot.
    def _prefill_merge(self, params, prompt, cache, slot):
        logits, cache1 = self.model.prefill(
            params, {"tokens": prompt}, max_len=self.max_len
        )
        merged = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=self._batch_axis(full)
            ),
            cache, cache1,
        )
        return jnp.argmax(logits[0, -1]).astype(jnp.int32), merged

    # --- slot management ---------------------------------------------------
    def submit(self, req: Request | IngestRequest) -> bool:
        """Enqueue a request.  Returns False when admission control shed a
        decode request on the spot (``req.shed``/``req.shed_reason`` are
        set; the request never enters the queue and will NOT be returned by
        ``run()``/``step()`` — the submitter already holds it)."""
        if isinstance(req, IngestRequest):
            self.ingest_queue.append(req)
            return True
        now = time.perf_counter()
        req._t_submit = now
        self.obs.counter("serve.submitted").inc()
        if req.deadline_s is not None:
            req._t_deadline = now + req.deadline_s
            projected = self.projected_wait_s()
            self.obs.gauge("serve.projected_wait_s").set(projected)
            if projected > req.deadline_s:
                # reject-on-submit: the queue already owes more work than
                # this budget covers — shedding NOW costs nothing, admitting
                # would waste a prefill + queue slot on a doomed request
                self._shed(req, SHED_REJECTED, now)
                return False
        if req.trace is None:
            req.trace = self._tracer.maybe_trace()
        self.queue.append(req)
        return True

    def _drain_ingest(self) -> list[IngestRequest]:
        """Apply queued inserts to the datastore (between decode steps).

        Bounded: at most ``max_ingest_per_step`` batches per call, so a
        sustained ingest stream yields the engine back to queued queries
        every step (the deferred remainder is counted once per bounded
        stop under ``serve.ingest_deferred``)."""
        done: list[IngestRequest] = []
        streamable = (
            isinstance(self.datastore, ForestDatastore)
            and self.datastore.delta is not None
        )
        budget = self.max_ingest_per_step
        while self.ingest_queue and budget > 0:
            budget -= 1
            req = self.ingest_queue.pop(0)
            t0 = time.perf_counter()
            if not streamable:
                # fail THIS request, not the whole run loop (in-flight
                # decode requests must survive a misdirected insert)
                req.accepted = 0
                req.error = "datastore does not accept streaming inserts"
                self.obs.counter("serve.ingest_errors").inc()
            else:
                with self.obs.span("serve.ingest"):
                    self.datastore, n_acc = ingest_keys(
                        self.datastore, jnp.asarray(req.keys, jnp.float32),
                        jnp.asarray(req.values, jnp.int32),
                    )
                req.accepted = n_acc
                self.obs.counter("serve.ingested_keys").inc(n_acc)
            req.done = True
            req.latency_s = time.perf_counter() - t0
            self.obs.histogram("serve.ingest_latency_s").observe(req.latency_s)
            done.append(req)
        if self.ingest_queue:
            # fairness observable: the bound bit — queries get the next step
            self.obs.counter("serve.ingest_deferred").inc()
        return done

    def _expire_queue(self) -> list[Request]:
        """Shed queued requests whose deadline passed before they reached a
        slot — cheaper than admitting them into a doomed prefill."""
        now = time.perf_counter()
        expired = [
            r for r in self.queue if r._t_deadline and now > r._t_deadline
        ]
        if expired:
            self.queue = [
                r for r in self.queue
                if not (r._t_deadline and now > r._t_deadline)
            ]
            for r in expired:
                self._shed(r, SHED_EXPIRED_QUEUE, now)
        return expired

    def _expire_slots(self) -> list[Request]:
        """Evict mid-flight requests whose deadline passed — and,
        speculatively, those that cannot possibly finish in time: once the
        tokens still owed times the measured step time overrun the budget,
        the request is doomed, so shedding it NOW (reason ``"early"``)
        frees the slot for the refill below instead of burning steps until
        the clock catches up.  Partial ``out_tokens`` stay on the request
        either way (a caller may still use a truncated answer)."""
        now = time.perf_counter()
        step_s = self.step_time_s()
        evicted: list[Request] = []
        for s in range(self.num_slots):
            req = self.slot_req[s]
            if req is None or not req._t_deadline:
                continue
            if now > req._t_deadline:
                self._shed(req, SHED_EXPIRED_FLIGHT, now)
            elif step_s is not None:
                # tokens this slot still owes: budget remainder, capped by
                # the cache-length retirement below (slot_pos >= max_len-1)
                remaining = min(
                    req.max_new_tokens - len(req.out_tokens),
                    self.max_len - 1 - int(self.slot_pos[s]),
                )
                if now + remaining * step_s <= req._t_deadline:
                    continue
                self._shed(req, SHED_EARLY, now)
            else:
                continue
            self.slot_req[s] = None
            self.slot_pos[s] = 0
            evicted.append(req)
        return evicted

    def _fill_slots(self) -> None:
        for slot in range(self.num_slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            req._t0 = time.perf_counter()
            prompt = jnp.asarray(req.prompt[None, :], jnp.int32)
            with use_trace(req.trace):
                # queue wait was measured outside any span — record it into
                # the request's tree with the externally-measured duration
                self.obs.record_span(
                    "serve.queue_wait", req._t0 - req._t_submit
                )
                with self.obs.span("serve.prefill"):
                    first, self.cache = self._prefill(
                        self.params, prompt, self.cache, slot
                    )
                    first = int(first)  # block: the refill's real wall time
            req.out_tokens.append(first)
            self.slot_req[slot] = req
            self.slot_pos[slot] = len(req.prompt)

    def _batch_axis(self, leaf) -> int:
        # stage caches are stacked (n, B, ...) when scanned; (B, ...) when not
        return 1 if leaf.ndim >= 2 and leaf.shape[1] == self.num_slots else 0

    # --- scheduler ----------------------------------------------------------
    def step(self) -> list[Request | IngestRequest]:
        """One scheduler iteration: bounded ingest drain -> queue/slot
        deadline expiry -> slot refill (continuous batching) -> one batched
        decode step -> retire.  Returns every request that reached a
        terminal state during the iteration (completed decodes, shed
        decodes, ingest acks) — the unit an open-loop driver interleaves
        with arrivals."""
        finished: list[Request | IngestRequest] = []
        finished.extend(self._drain_ingest())
        finished.extend(self._expire_queue())
        finished.extend(self._expire_slots())
        self._fill_slots()
        live = [s for s in range(self.num_slots) if self.slot_req[s] is not None]
        self.obs.gauge("serve.queue_depth").set(len(self.queue))
        self.obs.gauge("serve.ingest_queue_depth").set(len(self.ingest_queue))
        self.obs.gauge("serve.slot_occupancy").set(len(live) / self.num_slots)
        if not live:
            return finished
        # per-slot positions: a freshly refilled slot with a shorter
        # prompt keeps decoding at ITS cache position — stepping every
        # slot at max(live positions) would skip past the refilled
        # slot's prompt and corrupt its decode.  Empty slots step at
        # their stale position and decode garbage, ignored.
        tokens = np.zeros((self.num_slots, 1), np.int32)
        for s in live:
            tokens[s, 0] = self.slot_req[s].out_tokens[-1]
        t_step = time.perf_counter()
        with self.obs.span("serve.decode_step"):
            nxt, self.cache = self._decode(
                self.params, jnp.asarray(tokens), self.cache,
                jnp.asarray(self.slot_pos), self.datastore,
            )
            nxt = np.asarray(nxt)  # block: the step's real wall time
        self._step_times.append(time.perf_counter() - t_step)
        self.steps += 1
        self.obs.counter("serve.steps").inc()
        self.obs.counter("serve.tokens").inc(len(live))
        for s in live:
            req = self.slot_req[s]
            req.out_tokens.append(int(nxt[s]))
            self.slot_pos[s] += 1
            if len(req.out_tokens) >= req.max_new_tokens \
                    or self.slot_pos[s] >= self.max_len - 1:
                req.done = True
                self.obs.counter("serve.completed").inc()
                req.latency_s = time.perf_counter() - req._t_submit
                # observes serve.request_latency_s AND — for a sampled
                # request with an event log attached — emits the trace's
                # root span, closing the tree the queue-wait/prefill
                # spans already parented to
                self.obs.emit_trace_root(
                    req.trace, "serve.request_latency_s", req.latency_s
                )
                finished.append(req)
                self.slot_req[s] = None
                self.slot_pos[s] = 0
        return finished

    def run(self, *, max_steps: int = 10_000) -> list[Request | IngestRequest]:
        """Process the queues to completion; returns finished requests
        (completed decodes, shed decodes, ingest acks, in completion
        order).  ``max_steps`` bounds DECODE steps; a pure ingest backlog
        always drains (each call applies up to ``max_ingest_per_step``)."""
        finished: list[Request | IngestRequest] = []
        while self.busy and self.steps < max_steps:
            finished.extend(self.step())
        return finished
