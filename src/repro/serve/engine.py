"""Batched serving engine: slot-based continuous batching with kNN-LM
retrieval (the paper's datastore) fused into every decode step.

Production behaviors implemented:
* fixed decode batch of ``num_slots``; finished/empty slots are refilled
  from the request queue between steps (continuous batching) — the jitted
  decode step never recompiles because shapes are static;
* per-slot positions: one jitted step advances all slots at their own
  position (position-masked attention; see layers.decode_attention);
* prompt processing via the prefill path, packed into the slot cache;
* retrieval datastore shared across slots; per-request flag to disable;
* mixed query/insert traffic: ``IngestRequest`` streams new (key, token)
  pairs into the datastore's delta buffers (serve/retrieval.ingest_keys)
  between decode steps — one engine serves IoT-style read+write load.
  The datastore is an ARGUMENT of the jitted decode step (not a closure
  capture): delta shapes are fixed at build, so ingest swaps buffer
  contents without a single recompile;
* telemetry (repro.obs): request/ingest latency histograms with serving
  percentiles, queue-depth and slot-occupancy gauges, prefill/decode-step
  span timings — ``engine.metrics()`` snapshots them all.

Single-host implementation of the multi-host pattern: on a real mesh the
same engine runs with params/caches sharded exactly as in the dry-run.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.obs import Registry, TraceContext, TraceSampler, use_trace
from repro.serve.retrieval import Datastore, ForestDatastore, ingest_keys

PyTree = Any


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0
    # tracing: assigned at submit() by the engine's sampler (or preset by
    # the caller); sampled requests emit a linked span tree — queue wait,
    # prefill, and a "serve.request" root — into the registry's event log
    trace: TraceContext | None = None
    _t0: float = 0.0  # perf_counter at slot admission (latency accounting)
    _t_submit: float = 0.0  # perf_counter at submit (queue-wait accounting)


@dataclass
class IngestRequest:
    """Insert (key, next-token) pairs into the serving datastore's delta.

    Requires a ForestDatastore built with ``stream_capacity > 0``.
    ``accepted`` reports how many pairs fit the destination buffers (the
    rest were capacity-rejected; clients re-submit after maintenance)."""

    rid: int
    keys: np.ndarray  # (B, Dk) f32
    values: np.ndarray  # (B,) i32 token ids
    accepted: int = 0
    done: bool = False
    latency_s: float = 0.0
    error: str = ""  # non-empty when the engine could not ingest at all


class ServeEngine:
    def __init__(
        self,
        model: Model,
        params: PyTree,
        *,
        num_slots: int = 4,
        max_len: int = 256,
        datastore: Datastore | None = None,
        greedy: bool = True,
        registry: Registry | None = None,
        trace_sample: float = 0.0,
    ):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.datastore = datastore
        self.greedy = greedy
        self.cache = model.init_cache(num_slots, max_len)
        self.slot_req: list[Request | None] = [None] * num_slots
        self.slot_pos = np.zeros(num_slots, np.int32)
        self.queue: list[Request] = []
        self.ingest_queue: list[IngestRequest] = []
        self._decode = jax.jit(self._decode_step)
        self.steps = 0
        # serving telemetry (repro.obs): request/ingest latency percentile
        # histograms + queue-depth / slot-occupancy gauges replace the old
        # scatter of per-request perf_counter fields as the ENGINE's view
        # (requests keep their latency_s for per-request callers)
        self.obs = registry if registry is not None else Registry()
        # per-request tracing: ``trace_sample`` of submitted decode requests
        # get a TraceContext (deterministic systematic sampling); their
        # queue-wait/prefill spans and completion root land in the
        # registry's event log for Trace.reconstruct
        self._tracer = TraceSampler(trace_sample)

    def metrics(self) -> dict[str, Any]:
        """One snapshot of the engine's registry: ``serve.*`` latency
        histograms (seconds, p50/p95/p99), queue/slot gauges, and step/
        token counters."""
        return self.obs.snapshot()

    # --- jitted single step over all slots -------------------------------
    # ``datastore`` is a traced argument: ingest swaps in new delta contents
    # between steps and the same compiled step sees them (shapes are static).
    def _decode_step(self, params, tokens, cache, pos, datastore):
        logits, cache = self.model.decode_step(
            params, tokens, cache, pos, datastore=datastore
        )
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, cache

    # --- slot management ---------------------------------------------------
    def submit(self, req: Request | IngestRequest) -> None:
        if isinstance(req, IngestRequest):
            self.ingest_queue.append(req)
        else:
            req._t_submit = time.perf_counter()
            if req.trace is None:
                req.trace = self._tracer.maybe_trace()
            self.queue.append(req)

    def _drain_ingest(self) -> list[IngestRequest]:
        """Apply queued inserts to the datastore (between decode steps)."""
        done: list[IngestRequest] = []
        streamable = (
            isinstance(self.datastore, ForestDatastore)
            and self.datastore.delta is not None
        )
        while self.ingest_queue:
            req = self.ingest_queue.pop(0)
            t0 = time.perf_counter()
            if not streamable:
                # fail THIS request, not the whole run loop (in-flight
                # decode requests must survive a misdirected insert)
                req.accepted = 0
                req.error = "datastore does not accept streaming inserts"
                self.obs.counter("serve.ingest_errors").inc()
            else:
                with self.obs.span("serve.ingest"):
                    self.datastore, n_acc = ingest_keys(
                        self.datastore, jnp.asarray(req.keys, jnp.float32),
                        jnp.asarray(req.values, jnp.int32),
                    )
                req.accepted = n_acc
                self.obs.counter("serve.ingested_keys").inc(n_acc)
            req.done = True
            req.latency_s = time.perf_counter() - t0
            self.obs.histogram("serve.ingest_latency_s").observe(req.latency_s)
            done.append(req)
        return done

    def _fill_slots(self) -> None:
        for slot in range(self.num_slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            req._t0 = time.perf_counter()
            prompt = jnp.asarray(req.prompt[None, :], jnp.int32)
            with use_trace(req.trace):
                # queue wait was measured outside any span — record it into
                # the request's tree with the externally-measured duration
                self.obs.record_span(
                    "serve.queue_wait", req._t0 - req._t_submit
                )
                with self.obs.span("serve.prefill"):
                    logits, cache1 = self.model.prefill(
                        self.params, {"tokens": prompt}, max_len=self.max_len
                    )
            # merge the single-row cache into this slot's lane
            self.cache = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, axis=self._batch_axis(full)
                ),
                self.cache, cache1,
            )
            first = int(jnp.argmax(logits[0, -1]))
            req.out_tokens.append(first)
            self.slot_req[slot] = req
            self.slot_pos[slot] = len(req.prompt)

    def _batch_axis(self, leaf) -> int:
        # stage caches are stacked (n, B, ...) when scanned; (B, ...) when not
        return 1 if leaf.ndim >= 2 and leaf.shape[1] == self.num_slots else 0

    # --- main loop ----------------------------------------------------------
    def run(self, *, max_steps: int = 10_000) -> list[Request | IngestRequest]:
        """Process the queues to completion; returns finished requests
        (decode requests and ingest acks, in completion order)."""
        finished: list[Request | IngestRequest] = []
        finished.extend(self._drain_ingest())
        while (any(r is not None for r in self.slot_req) or self.queue) \
                and self.steps < max_steps:
            finished.extend(self._drain_ingest())
            self._fill_slots()
            live = [s for s in range(self.num_slots) if self.slot_req[s] is not None]
            self.obs.gauge("serve.queue_depth").set(len(self.queue))
            self.obs.gauge("serve.ingest_queue_depth").set(
                len(self.ingest_queue)
            )
            self.obs.gauge("serve.slot_occupancy").set(
                len(live) / self.num_slots
            )
            if not live:
                break
            # per-slot positions: a freshly refilled slot with a shorter
            # prompt keeps decoding at ITS cache position — stepping every
            # slot at max(live positions) would skip past the refilled
            # slot's prompt and corrupt its decode.  Empty slots step at
            # their stale position and decode garbage, ignored.
            tokens = np.zeros((self.num_slots, 1), np.int32)
            for s in live:
                tokens[s, 0] = self.slot_req[s].out_tokens[-1]
            with self.obs.span("serve.decode_step"):
                nxt, self.cache = self._decode(
                    self.params, jnp.asarray(tokens), self.cache,
                    jnp.asarray(self.slot_pos), self.datastore,
                )
                nxt = np.asarray(nxt)  # block: the step's real wall time
            self.steps += 1
            self.obs.counter("serve.steps").inc()
            self.obs.counter("serve.tokens").inc(len(live))
            for s in live:
                req = self.slot_req[s]
                req.out_tokens.append(int(nxt[s]))
                self.slot_pos[s] += 1
                if len(req.out_tokens) >= req.max_new_tokens \
                        or self.slot_pos[s] >= self.max_len - 1:
                    req.done = True
                    req.latency_s = time.perf_counter() - req._t0
                    # observes serve.request_latency_s AND — for a sampled
                    # request with an event log attached — emits the trace's
                    # root span, closing the tree the queue-wait/prefill
                    # spans already parented to
                    self.obs.emit_trace_root(
                        req.trace, "serve.request_latency_s", req.latency_s
                    )
                    finished.append(req)
                    self.slot_req[s] = None
                    self.slot_pos[s] = 0
        return finished
