"""Deprecation machinery for the pre-facade public surfaces.

A dedicated warning class (instead of bare ``DeprecationWarning``) lets the
CI gate turn *exactly these* warnings into errors — shim usage inside
``src/repro`` itself fails the build (tests/test_api_facade.py) without
tripping on deprecations emitted by third-party libraries.

This module is a leaf: it must import nothing from ``repro`` so that the
shims (core/pipeline.py, core/knn.py, stream/maintenance.py) can use it
without creating an import cycle with ``repro.api``.
"""
from __future__ import annotations

import warnings


class RepoDeprecationWarning(FutureWarning):
    """A repro-owned API surface superseded by ``repro.api.OverlapIndex``.

    Subclasses ``FutureWarning`` (not ``DeprecationWarning``) so the
    migration signal is VISIBLE by default in user code too — Python's
    default filters swallow DeprecationWarning outside ``__main__``, which
    would hide the shims' message from exactly the downstream callers who
    need to migrate.
    """


def warn_deprecated(old: str, new: str, *, stacklevel: int = 3) -> None:
    warnings.warn(
        f"{old} is deprecated; use {new} instead",
        RepoDeprecationWarning,
        stacklevel=stacklevel,
    )
