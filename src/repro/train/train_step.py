"""The jitted train step: microbatched grad accumulation, optional gradient
compression, global-norm clipping, optimizer update.

Distribution is GSPMD-first: the step is written single-program and sharded
via in/out shardings + the logical-axis constraints inside the model.  Two
distributed-optimization knobs live here:

* grad accumulation (``cfg.grad_accum``): lax.scan over microbatches —
  activation memory / ga, identical math;
* gradient compression (``grad_dtype='bfloat16'``): accumulated gradients
  are kept (and therefore cross-replica-reduced) in bf16 — halves the
  data-parallel all-reduce bytes; master params/optimizer stay f32.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.optim.optimizer import Optimizer, clip_by_global_norm

Array = jax.Array
PyTree = Any


def make_train_step(
    model: Model,
    optimizer: Optimizer,
    lr_schedule,
    *,
    grad_dtype: str = "float32",
    clip_norm: float = 1.0,
):
    cfg = model.cfg
    ga = max(cfg.grad_accum, 1)
    gdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[grad_dtype]

    def loss_fn(params, micro):
        loss, metrics = model.loss(params, micro)
        return loss, metrics

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params, opt_state, step = state["params"], state["opt"], state["step"]

        def micro_slice(i, x):
            b = x.shape[0] // ga
            return jax.lax.dynamic_slice_in_dim(x, i * b, b, axis=0)

        def accum(carry, i):
            gsum, lsum = carry
            micro = jax.tree.map(functools.partial(micro_slice, i), batch)
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, micro)
            grads = jax.tree.map(lambda a: a.astype(gdt), grads)
            gsum = jax.tree.map(jnp.add, gsum, grads)
            return (gsum, lsum + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)
        if ga > 1:
            (gsum, lsum), _ = jax.lax.scan(accum, (zeros, 0.0), jnp.arange(ga))
        else:
            (gsum, lsum), _ = accum((zeros, 0.0), 0)
        # stay in grad_dtype through the cross-replica reduction (casting to
        # f32 here doubles the gradient all-reduce wire bytes — measured
        # 1.2 TB/step on deepseek-67b zero3; optimizers upcast internally)
        grads = jax.tree.map(lambda g: g / ga, gsum)
        loss = lsum / ga
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_schedule(step)
        new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
        new_state = {"params": new_params, "opt": new_opt, "step": step + 1}
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


def init_train_state(model: Model, optimizer: Optimizer, rng) -> dict:
    params = model.init(rng)
    return {"params": params, "opt": optimizer.init(params), "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(model: Model, optimizer: Optimizer) -> PyTree:
    """eval_shape'd state for the dry-run (no allocation)."""
    return jax.eval_shape(lambda: init_train_state(model, optimizer, jax.random.key(0)))
