"""Training loop with production fault-tolerance behaviors:

* resume-from-latest-valid checkpoint (restart safety — data pipeline is
  step-indexed so batches replay identically);
* atomic periodic checkpointing (checkpoint/);
* step watchdog: per-step wall-time EWMA; steps slower than
  ``straggler_factor`` x EWMA are logged as straggler events and counted —
  on a real fleet this signal feeds the controller that re-schedules or
  evicts the slow host (here: hook + structured log);
* NaN/loss-spike guard: skips the update and restores from checkpoint after
  ``max_bad_steps`` consecutive bad steps (hardware-flake tolerance);
* elastic re-mesh: on restart with a different device count, shardings are
  recomputed (checkpoints are stored unsharded/logical — see elastic.py).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.checkpointing import restore_latest, save_checkpoint

PyTree = Any


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    log_every: int = 10
    straggler_factor: float = 3.0
    max_bad_steps: int = 3
    keep_checkpoints: int = 3


@dataclass
class TrainerReport:
    steps_run: int = 0
    resumed_from: int = -1
    losses: list[float] = field(default_factory=list)
    straggler_events: list[dict] = field(default_factory=list)
    bad_step_events: int = 0
    restores: int = 0
    wall_time_s: float = 0.0


class Trainer:
    def __init__(
        self,
        train_step: Callable[[PyTree, dict], tuple[PyTree, dict]],
        pipeline,
        cfg: TrainerConfig,
    ):
        self.train_step = train_step
        self.pipeline = pipeline
        self.cfg = cfg

    def run(self, state: PyTree) -> tuple[PyTree, TrainerReport]:
        cfg = self.cfg
        report = TrainerReport()
        t_start = time.perf_counter()

        restored, step0 = restore_latest(cfg.ckpt_dir, state)
        if restored is not None:
            state = jax.tree.map(jax.numpy.asarray, restored)
            report.resumed_from = step0
            report.restores += 1
        step = int(np.asarray(state["step"])) if "step" in state else max(step0, 0)

        ewma = None
        bad = 0
        while step < cfg.total_steps:
            batch = self.pipeline.batch_at(step)
            t0 = time.perf_counter()
            new_state, metrics = self.train_step(state, batch)
            loss = float(np.asarray(metrics["loss"]))  # blocks; wall time real
            dt = time.perf_counter() - t0

            if ewma is None:
                ewma = dt
            if dt > cfg.straggler_factor * ewma and step > 2:
                report.straggler_events.append(
                    {"step": step, "wall_s": round(dt, 4), "ewma_s": round(ewma, 4)}
                )
            ewma = 0.9 * ewma + 0.1 * dt

            if not np.isfinite(loss):
                bad += 1
                report.bad_step_events += 1
                if bad >= cfg.max_bad_steps:
                    restored, rstep = restore_latest(cfg.ckpt_dir, state)
                    if restored is not None:
                        state = jax.tree.map(jax.numpy.asarray, restored)
                        step = rstep
                        report.restores += 1
                    bad = 0
                    continue
                step += 1  # skip the update
                continue
            bad = 0
            state = new_state
            step += 1
            report.losses.append(loss)
            if step % cfg.ckpt_every == 0 or step == cfg.total_steps:
                save_checkpoint(cfg.ckpt_dir, step,
                                jax.tree.map(np.asarray, state),
                                keep=cfg.keep_checkpoints)
            if step % cfg.log_every == 0:
                print(f"[train] step={step} loss={loss:.4f} "
                      f"wall={dt*1e3:.1f}ms", flush=True)

        report.steps_run = cfg.total_steps - max(step0, 0)
        report.wall_time_s = time.perf_counter() - t_start
        Path(cfg.ckpt_dir, "trainer_report.json").write_text(
            json.dumps(report.__dict__, default=str))
        return state, report
