"""Fault-tolerant checkpointing.

Design (single-process stand-in for the multi-host protocol, same layout):
* one ``step_<N>/`` directory per checkpoint: flattened param/opt leaves as
  .npy files + ``manifest.json`` (tree structure, shapes, dtypes, per-file
  crc32, mesh-INDEPENDENT — arrays are saved unsharded/logical so restore
  works on any mesh, the elastic-rescale contract);
* atomic publish: written to ``step_<N>.tmp`` then os.rename'd — a crash
  mid-write never corrupts the latest checkpoint;
* ``restore_latest`` validates checksums and falls back to the previous
  checkpoint on corruption (fault tolerance);
* retention: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from pathlib import Path
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> tuple[list[tuple[str, np.ndarray]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        name = "_".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        ) or "leaf"
        out.append((name, np.asarray(leaf)))
    return out, jax.tree.structure(tree)


def save_checkpoint(ckpt_dir: str | Path, step: int, tree: PyTree, *, keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, _ = _flatten(tree)
    manifest = {"step": step, "files": []}
    for i, (name, arr) in enumerate(leaves):
        fname = f"{i:05d}_{name[:128]}.npy"
        np.save(tmp / fname, arr)
        crc = zlib.crc32((tmp / fname).read_bytes())
        manifest["files"].append(
            {"file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype), "crc32": crc}
        )
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: Path, keep: int) -> None:
    ckpts = sorted(d for d in ckpt_dir.iterdir()
                   if d.is_dir() and d.name.startswith("step_") and not d.name.endswith(".tmp"))
    for d in ckpts[:-keep]:
        shutil.rmtree(d, ignore_errors=True)


def _validate(d: Path) -> bool:
    try:
        manifest = json.loads((d / "manifest.json").read_text())
        for f in manifest["files"]:
            data = (d / f["file"]).read_bytes()
            if zlib.crc32(data) != f["crc32"]:
                return False
        return True
    except Exception:
        return False


def restore_checkpoint(d: str | Path, template: PyTree) -> PyTree:
    """Load into the structure of ``template`` (mesh-independent)."""
    d = Path(d)
    manifest = json.loads((d / "manifest.json").read_text())
    arrays = [np.load(d / f["file"]) for f in manifest["files"]]
    treedef = jax.tree.structure(template)
    return jax.tree_util.tree_unflatten(treedef, arrays)


def restore_latest(ckpt_dir: str | Path, template: PyTree) -> tuple[PyTree | None, int]:
    """Newest valid checkpoint (corrupted ones are skipped with a warning).
    Returns (tree | None, step)."""
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None, -1
    ckpts = sorted(
        (d for d in ckpt_dir.iterdir()
         if d.is_dir() and d.name.startswith("step_") and not d.name.endswith(".tmp")),
        reverse=True,
    )
    for d in ckpts:
        if _validate(d):
            step = int(d.name.split("_")[1])
            return restore_checkpoint(d, template), step
        print(f"[ckpt] WARNING: {d} failed checksum validation, trying older")
    return None, -1
