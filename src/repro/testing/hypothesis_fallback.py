"""Minimal stand-in for the ``hypothesis`` API used by this repo's tests.

The property tests (tests/test_knn.py, test_overlap.py, test_substrate.py)
use only ``@settings(max_examples=..., deadline=None)``, ``@given(**kwargs)``
and the ``st.integers`` / ``st.floats`` strategies.  When real hypothesis is
installed (declared in pyproject.toml's ``test`` extra; CI installs it) the
tests use it; in hermetic environments without it, this fallback keeps the
suite collectable and runs each property over a fixed number of
deterministically drawn examples.

It is NOT a shrinker and does no example database — it exists so a missing
optional dependency degrades to plain seeded sampling instead of an
ImportError that kills collection of entire test modules.
"""
from __future__ import annotations

import functools
import inspect

import numpy as np

_DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (subset)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(
        min_value: float,
        max_value: float,
        *,
        allow_nan: bool = False,
        allow_infinity: bool = False,
        **_: object,
    ) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(2)))


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    """Attach the example budget; composes with ``@given`` in either order."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strats: _Strategy):
    """Run the test over deterministically drawn examples of each strategy."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples", None) or getattr(
                fn, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES
            )
            rng = np.random.default_rng(0)
            for _ in range(n):
                fn(**{name: s.draw(rng) for name, s in strats.items()})

        # Hide the wrapped signature from pytest: drawn args are not fixtures.
        wrapper.__signature__ = inspect.Signature(parameters=[])
        return wrapper

    return deco
