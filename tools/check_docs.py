"""Docs gate: README cross-references must resolve and the quickstart must
run.

Two checks, both hard CI failures (.github/workflows/ci.yml "Docs check"):

1. **Reference check** — across every README.md in the repo:
   * relative markdown links ``[text](path)`` must point at an existing
     file/directory (http(s)/mailto/#anchor links are skipped);
   * backtick-quoted file references (`` `src/repro/obs/README.md` ``,
     `` `kernels/README.md` ``, `` `tests/test_obs.py::test_x` ``) must
     resolve against the README's own directory or one of the repo's
     conventional roots (repo root, src/repro, examples, benchmarks,
     tools, tests).  The READMEs cross-reference each other heavily
     (distributed <-> obs <-> serve); this is what keeps a rename from
     silently stranding them.

2. **Snippet check** — the FIRST ```python code block of the top-level
   README (the quickstart) is executed in a temp directory with a clean
   namespace.  The quickstart is the repo's front door; this is what keeps
   it from rotting into pseudocode (it already had an undefined-variable
   bug once — caught by exactly this check).

Usage: ``PYTHONPATH=src python tools/check_docs.py [--no-run] [--root DIR]``
"""
from __future__ import annotations

import argparse
import os
import re
import sys
import tempfile

# [text](target) — target captured up to the closing paren (no nesting in
# our docs); external schemes and pure anchors are filtered later
_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `path/to/file.md` or `tests/test_x.py::test_name` inside backticks
_TICK_REF = re.compile(r"`([A-Za-z0-9_./-]+\.(?:md|py))(?:::[^`]*)?`")
_FENCE = re.compile(r"^```(\w*)\s*$")

# backtick references resolve against the README's directory first, then
# these repo-root-relative bases (matching how the docs name things:
# "kernels/README.md" from the top level means src/repro/kernels/README.md,
# "quickstart.py" means examples/quickstart.py)
_BASES = ("", "src", "src/repro", "examples", "benchmarks", "tools", "tests")


def find_readmes(root: str) -> list[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [
            d for d in dirnames
            if not d.startswith(".") and d not in ("__pycache__", "node_modules")
        ]
        if "README.md" in filenames:
            out.append(os.path.join(dirpath, "README.md"))
    return sorted(out)


def _strip_code(text: str) -> str:
    """Drop fenced code blocks: code is checked by execution (snippet
    check) and by the test suite, not by reference-resolution heuristics."""
    out, in_fence = [], False
    for line in text.splitlines():
        if _FENCE.match(line):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(line)
    return "\n".join(out)


def check_refs(readme: str, root: str) -> list[str]:
    """All unresolvable references in one README, as error strings."""
    with open(readme) as f:
        text = _strip_code(f.read())
    here = os.path.dirname(readme)
    rel = os.path.relpath(readme, root)
    errors = []

    for target in _MD_LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not os.path.exists(os.path.normpath(os.path.join(here, path))):
            errors.append(f"{rel}: broken link ({target})")

    for ref in set(_TICK_REF.findall(text)):
        candidates = [os.path.join(here, ref)] + [
            os.path.join(root, base, ref) for base in _BASES
        ]
        if not any(os.path.exists(os.path.normpath(c)) for c in candidates):
            errors.append(f"{rel}: dangling file reference (`{ref}`)")
    return errors


def first_python_block(readme: str) -> str | None:
    """The first fenced ```python block's source, or None."""
    lines, block, in_block = [], None, False
    with open(readme) as f:
        for line in f:
            m = _FENCE.match(line)
            if m and not in_block and m.group(1) == "python":
                in_block, lines = True, []
            elif m and in_block:
                block = "".join(lines)
                break
            elif in_block:
                lines.append(line)
    return block


def run_snippet(src: str, label: str) -> list[str]:
    """Execute a README snippet in a temp cwd; errors become doc failures."""
    cwd = os.getcwd()
    with tempfile.TemporaryDirectory(prefix="repro_docs_") as tmp:
        os.chdir(tmp)
        try:
            exec(compile(src, label, "exec"), {"__name__": "__docs__"})
        except Exception as e:  # noqa: BLE001 — any failure fails the gate
            return [f"{label}: quickstart snippet failed: {type(e).__name__}: {e}"]
        finally:
            os.chdir(cwd)
    return []


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    ap.add_argument("--no-run", action="store_true",
                    help="reference check only (skip snippet execution)")
    a = ap.parse_args(argv)

    readmes = find_readmes(a.root)
    errors = []
    for r in readmes:
        errors.extend(check_refs(r, a.root))
    print(f"# checked references in {len(readmes)} READMEs")

    if not a.no_run:
        top = os.path.join(a.root, "README.md")
        src = first_python_block(top)
        if src is None:
            errors.append("README.md: no ```python quickstart block found")
        else:
            print(f"# running README quickstart ({len(src.splitlines())} lines)")
            errors.extend(run_snippet(src, "README.md quickstart"))

    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    if not errors:
        print(f"docs check OK ({len(readmes)} READMEs)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
