"""Per-query tracing, overlap-attribution explain, and metrics export.

Covers the observability tentpole end to end: TraceContext propagation and
``Trace.reconstruct`` round trips (index searches AND multi-request serving
runs), ``OverlapIndex.explain`` attribution — conservation against
``SearchStats.buckets_visited`` and bitwise identity with plain search —
the measured-waste maintenance trigger, event-log rotation, and the
Prometheus/CLI export surface.
"""
import json
import math

import numpy as np
import pytest

from repro.api import (
    Config,
    IndexConfig,
    ObsConfig,
    OverlapIndex,
    StreamConfig,
)
from repro.obs import (
    EventLog,
    Registry,
    Trace,
    TraceContext,
    TraceSampler,
    current_trace,
    new_trace,
    use_trace,
)
from repro.obs import export as obs_export
from repro.obs.attribution import attribute_visits


def _cfg(**kw) -> Config:
    obs_kw = {k: kw.pop(k) for k in list(kw)
              if k in ("trace_sample", "events_path", "events_max_bytes",
                       "events_backups", "enabled")}
    stream_kw = {"capacity": 64, **{k: kw.pop(k) for k in list(kw)
                                    if k in ("wasted_rebuild", "fill_rebuild")}}
    assert not kw, kw
    return Config(
        index=IndexConfig(
            method="vbm", eps=1.5, min_pts=8, xi_min=0.3, xi_max=0.7
        ),
        stream=StreamConfig(**stream_kw),
        obs=ObsConfig(**obs_kw),
    )


# ---------------------------------------------------------------------------
# trace primitives
# ---------------------------------------------------------------------------


def test_trace_context_ids_and_parentage():
    ctx = TraceContext("abc")
    assert ctx.root_id == "abc.1"
    s1, p1 = ctx.push()
    assert (s1, p1) == ("abc.2", "abc.1")
    s2, p2 = ctx.push()  # nests under s1
    assert (s2, p2) == ("abc.3", "abc.2")
    l1, lp = ctx.link()  # point event parented at the open span, no push
    assert lp == s2 and l1 == "abc.4"
    ctx.pop()
    s3, p3 = ctx.push()  # back at depth 1 -> parents to s1 again
    assert p3 == s1
    ctx.pop()
    ctx.pop()
    _, p4 = ctx.push()  # empty stack -> parents to the root
    assert p4 == ctx.root_id


def test_use_trace_ambient_and_noop():
    assert current_trace() is None
    ctx = new_trace()
    with use_trace(ctx):
        assert current_trace() is ctx
        # None is a true no-op: the outer context stays ambient
        with use_trace(None):
            assert current_trace() is ctx
        # unsampled contexts are never installed
        with use_trace(new_trace(sampled=False)):
            assert current_trace() is ctx
    assert current_trace() is None


def test_sampler_is_deterministic_and_exact():
    s = TraceSampler(0.25)
    admitted = [i for i in range(100) if s.sample()]
    assert len(admitted) == 25
    # systematic: every 4th request, reproducibly
    assert admitted == [i for i in range(3, 100, 4)]
    assert TraceSampler(0.0).maybe_trace() is None
    assert all(TraceSampler(1.0).sample() for _ in range(10))
    with pytest.raises(ValueError, match="rate"):
        TraceSampler(1.5)


def test_registry_spans_join_ambient_trace(tmp_path):
    p = tmp_path / "ev.jsonl"
    reg = Registry(events=EventLog(str(p)))
    ctx = new_trace()
    with use_trace(ctx):
        with reg.span("outer"):
            with reg.span("inner"):
                reg.emit_event({"event": "note"}, traced_only=True)
        reg.record_span("external_wait", 0.5)
        reg.emit_trace_root(ctx, "request", 1.0)
    with reg.span("untraced"):
        pass
    reg.emit_event({"event": "dropped"}, traced_only=True)  # no ambient trace
    recs = EventLog.read(str(p))
    by_span = {r.get("span", r.get("event")): r for r in recs}
    assert "dropped" not in by_span
    root = by_span["request"]
    assert root["span_id"] == ctx.root_id and root["parent_id"] is None
    assert by_span["outer"]["parent_id"] == ctx.root_id
    assert by_span["outer/inner"]["parent_id"] == by_span["outer"]["span_id"]
    assert by_span["note"]["parent_id"] == by_span["outer/inner"]["span_id"]
    assert by_span["external_wait"]["parent_id"] == ctx.root_id
    assert by_span["external_wait"]["dur_s"] == 0.5
    assert "trace_id" not in by_span["untraced"]
    # the tree reassembles: one root, everything under it
    t = Trace.reconstruct(str(p), ctx.trace_id)
    assert [r.name for r in t.roots] == ["request"]
    assert t.span_names() == {"request", "outer", "outer/inner", "note",
                              "external_wait"}
    assert "request" in t.render()


# ---------------------------------------------------------------------------
# event-log rotation
# ---------------------------------------------------------------------------


def test_event_log_rotation_keeps_backups_and_read_spans(tmp_path):
    p = str(tmp_path / "rot.jsonl")
    log = EventLog(p, max_bytes=120, backups=2)
    for i in range(40):
        log.emit({"event": "x", "i": i})
    log.close()
    files = EventLog.rotated_paths(p)
    assert files == [f"{p}.2", f"{p}.1", p]
    recs = EventLog.read(p)
    seq = [r["i"] for r in recs]
    # oldest rotations fell off the end; what remains is contiguous,
    # oldest-first, and ends at the newest event
    assert seq == sorted(seq) and seq[-1] == 39
    assert len(seq) < 40


def test_event_log_rotation_zero_backups_truncates(tmp_path):
    p = str(tmp_path / "zero.jsonl")
    log = EventLog(p, max_bytes=100, backups=0)
    for i in range(30):
        log.emit({"event": "x", "i": i})
    log.close()
    assert EventLog.rotated_paths(p) == [p]
    seq = [r["i"] for r in EventLog.read(p)]
    assert seq == sorted(seq) and seq[-1] == 29 and len(seq) < 30


def test_event_log_single_event_never_splits(tmp_path):
    # a record larger than max_bytes still lands whole in one file
    p = str(tmp_path / "big.jsonl")
    log = EventLog(p, max_bytes=16, backups=1)
    log.emit({"event": "huge", "payload": "y" * 100})
    log.emit({"event": "next"})
    log.close()
    recs = EventLog.read(p)
    assert [r["event"] for r in recs] == ["huge", "next"]


def test_event_log_rotation_validation(tmp_path):
    with pytest.raises(ValueError, match="max_bytes"):
        EventLog(str(tmp_path / "a.jsonl"), max_bytes=0)
    with pytest.raises(ValueError, match="backups"):
        EventLog(str(tmp_path / "b.jsonl"), backups=-1)


# ---------------------------------------------------------------------------
# explain: attribution semantics
# ---------------------------------------------------------------------------


def test_attribute_visits_hand_case():
    # 2 indexes; buckets: row0 (idx 0) holds ids {0,1}, row1 (idx 1) holds
    # {2}, row2 (idx 1) holds {3}.  Query 0 visited rows [0, 2] and kept
    # ids {0, 1}: row0 contributed, row2 (owned by 1, home 0) was wasted.
    rep = attribute_visits(
        order=np.array([[0, 2, 1]]),
        visits=np.array([[2]]),
        dorder=None,
        dvisits=None,
        result_ids=np.array([[0, 1]]),
        home=np.array([0]),
        n_indexes=2,
        bucket_index=np.array([0, 1, 1]),
        bucket_ids=np.array([[0, 1], [2, -1], [3, -1]]),
        bucket_mask=np.array([[True, True], [True, False], [True, False]]),
        main_rows_per_shard=3,
        rates=np.array([[0.0, 0.4], [0.4, 0.0]]),
        method="vbm",
    )
    assert rep.contributing.tolist() == [1]
    assert rep.wasted.tolist() == [1]
    assert rep.wasted_pair[1, 0] == 1 and rep.wasted_pair.sum() == 1
    assert rep.visited_pair[0, 0] == 1 and rep.visited_pair[1, 0] == 1
    assert rep.wasted_fraction == 0.5
    top = rep.top_pairs()
    assert top[0] == {"visited": 1, "home": 0, "wasted": 1, "visits": 1,
                      "rate": 0.4}
    assert json.dumps(rep.to_dict())


@pytest.fixture(scope="module")
def explained(blob_data):
    """One index + queries + (search, explain) results, with delta phase."""
    ix = OverlapIndex.build(blob_data, _cfg())
    g = np.random.default_rng(5)
    ix.ingest(
        (blob_data[g.choice(len(blob_data), 48)]
         + 0.1 * g.normal(size=(48, blob_data.shape[1]))).astype(np.float32)
    )
    q = np.asarray(blob_data[g.choice(len(blob_data), 24)])
    return ix, q, ix.search(q, k=6), ix.explain(q, k=6)


def test_explain_conservation_and_bitwise(explained):
    ix, q, res, rep = explained
    # bitwise: the explain plan runs the identical op sequence
    np.testing.assert_array_equal(rep.result.dists, res.dists)
    np.testing.assert_array_equal(rep.result.ids, res.ids)
    # conservation: every visit is contributing XOR wasted, per query
    np.testing.assert_array_equal(
        rep.contributing + rep.wasted, res.stats["buckets_visited"]
    )
    assert rep.queries == len(q)
    assert (rep.home >= 0).all() and (rep.home < ix.n_indexes).all()
    # pair matrices cover exactly the visits attributed to real indexes
    assert rep.visited_pair.sum() <= rep.total_visits
    assert rep.wasted_pair.sum() <= rep.wasted.sum()
    assert 0.0 <= rep.wasted_fraction <= 1.0
    # a clustered query set finds most answers near home: some contribution
    assert rep.contributing.sum() > 0


def test_explain_separate_plan_leaves_search_plan_alone(explained):
    ix, q, res, rep = explained
    assert rep.result.plan.key.explain is True
    assert res.plan.key.explain is False
    assert rep.result.plan is not res.plan
    # plan cache keeps both compiled executors; repeat calls re-use them
    before = ix.plans.stats()["misses"]
    ix.search(q, k=6)
    ix.explain(q, k=6)
    assert ix.plans.stats()["misses"] == before


def test_explain_metrics_rollup(explained):
    ix, q, res, rep = explained
    m = ix.metrics()
    oh = m["overlap_health"]
    assert oh["explained_queries"] >= len(q)
    assert oh["contributing"] >= int(rep.contributing.sum())
    assert oh["wasted"] >= int(rep.wasted.sum())
    assert 0.0 <= oh["wasted_fraction"] <= 1.0
    total_pairs = sum(oh["wasted_pairs"].values())
    assert total_pairs == sum(
        v for (n, _), v in ix.obs.counters().items()
        if n == "explain.wasted_pair"
    )
    # monitor received the evidence (delta exists -> monitor exists)
    assert oh["monitor_wasted_share"] is not None
    assert json.dumps(m["overlap_health"])


def test_wasted_trigger_fires_and_resets(blob_data):
    ix = OverlapIndex.build(blob_data, _cfg(wasted_rebuild=0.05))
    g = np.random.default_rng(6)
    ix.ingest(
        (blob_data[g.choice(len(blob_data), 32)]
         + 0.1 * g.normal(size=(32, blob_data.shape[1]))).astype(np.float32)
    )
    # far-flung queries waste visits across every index they touch
    q = g.uniform(-15, 15, size=(32, blob_data.shape[1])).astype(np.float32)
    rep = ix.explain(q, k=5)
    share = ix.monitor.wasted_share()
    assert (ix.monitor.attr_visits >= 0).all()
    report = ix.check()
    fired = [i for i, why in report.reasons.items() if "wasted" in why]
    expect = [
        i for i in range(ix.n_indexes)
        if ix.monitor.attr_visits[i] >= ix.monitor.WASTED_MIN_VISITS
        and share[i] >= 0.05
    ]
    assert fired == expect
    assert expect, "waste evidence should fire the trigger in this setup"
    # a maintain() rebuild recreates the monitor -> accumulators reset, the
    # measured-waste trigger cannot re-fire off stale evidence
    ix.maintain()
    assert ix.monitor.attr_visits.sum() == 0
    assert not any(
        "wasted" in why for why in ix.check().reasons.values()
    )


def test_explain_without_monitor_or_delta(blob_data):
    ix = OverlapIndex.build(blob_data, _cfg())
    q = np.asarray(blob_data[:8])
    rep = ix.explain(q, k=4)  # no ingest: no delta, no monitor
    res = ix.search(q, k=4)
    np.testing.assert_array_equal(rep.result.ids, res.ids)
    np.testing.assert_array_equal(
        rep.contributing + rep.wasted, res.stats["buckets_visited"]
    )
    assert ix.metrics()["overlap_health"]["monitor_wasted_share"] is None


# ---------------------------------------------------------------------------
# trace propagation through the index + reconstruction
# ---------------------------------------------------------------------------


def test_search_self_sampling_tracing(blob_data, tmp_path):
    p = str(tmp_path / "ix.jsonl")
    ix = OverlapIndex.build(blob_data, _cfg(
        trace_sample=0.5, events_path=p,
    ))
    q = np.asarray(blob_data[:4])
    for _ in range(6):
        ix.search(q, k=3)
    tids = Trace.trace_ids(p)
    assert len(tids) == 3  # deterministic: every 2nd search
    t = Trace.reconstruct(p, tids[0])
    # one root ("search" — its synthesized parent id is never emitted),
    # with the per-phase spans and the per-island point event beneath it
    assert len(t.roots) == 1 and t.roots[0].name == "search"
    names = t.span_names()
    assert {"search", "search/plan_lookup", "search/device_execute",
            "search/host_transfer", "island"} <= names
    # untraced searches still recorded their spans, unlinked
    unlinked = [r for r in EventLog.read(p)
                if r.get("span") == "search" and "trace_id" not in r]
    assert len(unlinked) == 3


def test_search_explicit_trace_joins_caller_tree(blob_data, tmp_path):
    p = str(tmp_path / "joined.jsonl")
    ix = OverlapIndex.build(blob_data, _cfg(events_path=p))
    ctx = new_trace()
    ix.search(np.asarray(blob_data[:4]), k=3, trace=ctx)
    t = Trace.reconstruct(p, ctx.trace_id)
    assert len(t.roots) == 1
    assert t.roots[0].record["parent_id"] == ctx.root_id
    assert "search/device_execute" in t.span_names()


def test_tracing_off_emits_no_linkage(blob_data, tmp_path):
    p = str(tmp_path / "off.jsonl")
    ix = OverlapIndex.build(blob_data, _cfg(events_path=p))  # sample 0.0
    ix.search(np.asarray(blob_data[:4]), k=3)
    assert Trace.trace_ids(p) == []


def test_serving_run_reconstructs_per_request_trees(tmp_path):
    import jax

    from repro.configs import get_smoke_config
    from repro.configs.base import RetrievalConfig
    from repro.data.synthetic import embedding_datastore
    from repro.models.model import Model
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.retrieval import build_flat_datastore

    cfg = get_smoke_config("qwen2-0.5b").replace(
        retrieval=RetrievalConfig(enabled=True, k=4, lam=0.5,
                                  temperature=1.0, datastore_size=512))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    keys, values = embedding_datastore(256, cfg.d_model, seed=3)
    ds = build_flat_datastore(keys, values % cfg.vocab_size)
    p = str(tmp_path / "serve.jsonl")
    reg = Registry(events=EventLog(p))
    engine = ServeEngine(model, params, num_slots=2, max_len=32,
                         datastore=ds, registry=reg, trace_sample=1.0)
    g = np.random.default_rng(0)
    reqs = [Request(rid=rid,
                    prompt=g.integers(0, cfg.vocab_size, 6).astype(np.int32),
                    max_new_tokens=3)
            for rid in range(5)]
    for r in reqs:
        engine.submit(r)
    finished = engine.run()
    assert len(finished) == 5
    # every request got its own trace; each reassembles into one tree
    # rooted at the request with queue wait + prefill beneath it
    tids = Trace.trace_ids(p)
    assert len(tids) == 5
    assert {r.trace.trace_id for r in reqs} == set(tids)
    for tid in tids:
        t = Trace.reconstruct(p, tid)
        assert len(t.roots) == 1
        assert t.roots[0].name == "serve.request_latency_s"
        assert t.roots[0].dur_s > 0.0
        assert {"serve.queue_wait", "serve.prefill"} <= t.span_names()
    # sampled-off engines keep the latency histogram behavior
    assert reg.snapshot()["histograms"]["serve.request_latency_s"]["count"] == 5


# ---------------------------------------------------------------------------
# export surface
# ---------------------------------------------------------------------------


def test_prometheus_render_parse_roundtrip(blob_data):
    ix = OverlapIndex.build(blob_data, _cfg())
    q = np.asarray(blob_data[:8])
    ix.search(q, k=5)
    ix.explain(q, k=5)
    text = ix.obs.to_prometheus()
    samples = obs_export.parse_prometheus(text)  # raises on malformed output
    assert samples, "expected at least one sample"
    by_name = {s["name"]: s for s in samples}
    assert "search_queries" in by_name
    assert by_name["search_queries"]["value"] == 16.0
    # histograms render as summaries with quantiles + sum/count
    assert any(s["name"] == "search" and s["labels"].get("quantile") == "0.5"
               for s in samples)
    assert "search_count" in by_name and by_name["search_count"]["value"] >= 1
    # island counters carry their labels through
    island = [s for s in samples
              if s["name"].startswith("search_island_buckets_visited")]
    assert island and all("island" in s["labels"] for s in island)


def test_prometheus_parser_rejects_garbage():
    with pytest.raises(ValueError, match="line 1"):
        obs_export.parse_prometheus("not a metric line\n")


def test_prometheus_nonfinite_values():
    reg = Registry()
    reg.gauge("g").set(math.inf)
    reg.histogram("h")  # registered but never observed -> NaN percentiles
    samples = obs_export.parse_prometheus(reg.to_prometheus())
    gauges = [s for s in samples if s["name"] == "g"]
    assert gauges and gauges[0]["value"] == math.inf
    p50 = [s for s in samples
           if s["name"] == "h" and s["labels"].get("quantile") == "0.5"]
    assert p50 and math.isnan(p50[0]["value"])
    count = [s for s in samples if s["name"] == "h_count"]
    assert count and count[0]["value"] == 0.0


def test_export_cli_check_and_snapshot(blob_data, tmp_path, capsys):
    p = str(tmp_path / "cli.jsonl")
    ix = OverlapIndex.build(blob_data, _cfg(
        events_path=p, trace_sample=1.0,
    ))
    ix.search(np.asarray(blob_data[:4]), k=3)
    snap_path = tmp_path / "metrics.json"
    snap_path.write_text(json.dumps(ix.metrics()))

    assert obs_export.main(["--events", p, "--check"]) == 0
    out = capsys.readouterr().out
    assert "prometheus render OK" in out
    assert "search/device_execute" in out  # span latency table

    assert obs_export.main(["--snapshot", str(snap_path),
                            "--format", "prometheus"]) == 0
    out = capsys.readouterr().out
    obs_export.parse_prometheus(out)

    assert obs_export.main(["--events", p, "--traces"]) == 0
    tid = capsys.readouterr().out.strip().splitlines()[0]
    assert obs_export.main(["--events", p, "--trace", tid]) == 0
    assert "search" in capsys.readouterr().out
    assert obs_export.main(["--events", p, "--trace", "nope"]) == 1
    capsys.readouterr()


def test_export_cli_events_from_env(tmp_path, monkeypatch, capsys):
    p = str(tmp_path / "env.jsonl")
    with EventLog(p) as log:
        reg = Registry(events=log)
        with reg.span("phase"):
            pass
    monkeypatch.setenv("REPRO_OBS_EVENTS", p)
    assert obs_export.main(["--check"]) == 0
    assert "phase" in capsys.readouterr().out
