"""Tier-2 exactness gates for the routed device layout (distributed/router/).

The routing tier's contract is the sharded layout's contract plus one more
theorem: host pruning must be INVISIBLE in the results.  Every test here
asserts BITWISE identity (distances AND ids) between the routed executor —
under every fanout mode — the plain sharded fan-all islands, and the
single-device executor, across f32/int8, the delta phase, maintenance
rebuild swaps, and save -> re-route -> load; plus a direct soundness check
of the pruning rule itself (a pruned host's nearest owned member always
sits strictly beyond the fan-all kth-best).

Run under a forced host mesh (set BEFORE jax initializes):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_routed_exec.py

On a single-device host the whole module skips (tier-1 collection still
imports it, so an import-time regression fails everywhere).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import (
    Config,
    IndexConfig,
    LayoutConfig,
    ObsConfig,
    OverlapIndex,
    RoutingConfig,
    SearchConfig,
    StreamConfig,
    make_backend,
)

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="routed layout tests need >= 4 devices; set "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax init",
)

ROUTED4 = LayoutConfig(kind="routed", shards=4)
SHARDED4 = LayoutConfig(kind="sharded", shards=4)
INDEX_KW = dict(method="vbm", eps=2.5, min_pts=8, xi_min=0.3, xi_max=0.7)


def _islands(seed: int = 0, n_per: int = 400, spread: float = 30.0) -> np.ndarray:
    """Well-separated clusters — the workload the routing tier exists for:
    most hosts provably cannot hold a near-cluster query's answer.  The
    spread keeps inter-cluster gaps >> cluster radii (strong pruning) while
    the int8 quantization grid (~spread/40 per step) stays fine enough that
    distinct members keep distinct quantized distances — exact ties would
    merge in layout-dependent order on ANY multi-host layout, fan-all
    included."""
    g = np.random.default_rng(seed)
    centers = g.normal(size=(4, 8)) * spread
    return np.concatenate(
        [c + g.normal(size=(n_per, 8)) for c in centers]
    ).astype(np.float32)


def _queries(x: np.ndarray, n: int = 24, seed: int = 3) -> np.ndarray:
    g = np.random.default_rng(seed)
    base = x[g.choice(len(x), n)]
    return (base + 0.05 * g.normal(size=base.shape)).astype(np.float32)


def _cfg(*, quantize=False, capacity=64, layout=None, index_kw=None) -> Config:
    return Config(
        index=IndexConfig(**(index_kw or INDEX_KW)),
        search=SearchConfig(quantize=quantize),
        stream=StreamConfig(capacity=capacity),
        layout=layout or LayoutConfig(),
        obs=ObsConfig(enabled=True),
    )


def _assert_same_results(res, ref, what=""):
    np.testing.assert_array_equal(res.dists, ref.dists, err_msg=what)
    np.testing.assert_array_equal(res.ids, ref.ids, err_msg=what)


@pytest.fixture(scope="module")
def data():
    return _islands()


@pytest.fixture(scope="module")
def trio(data):
    """Factory: (single, sharded fan-all, routed) triple over the same data.
    ``fresh=True`` for tests that mutate (ingest/rebuild)."""
    cache = {}

    def get(*, quantize=False, routing=None, fresh=False):
        key = (quantize, routing)
        if fresh or key not in cache:
            routed = LayoutConfig(
                kind="routed", shards=4, routing=routing or RoutingConfig()
            )
            built = tuple(
                OverlapIndex.build(data, _cfg(quantize=quantize, layout=lay))
                for lay in (LayoutConfig(), SHARDED4, routed)
            )
            if fresh:
                return built
            cache[key] = built
        return cache[key]

    return get


# ---------------------------------------------------------------------------
# bitwise identity: routed == fan-all == single, every fanout mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quantize", [False, True], ids=["f32", "int8"])
def test_routed_bitwise_across_layouts(trio, data, quantize):
    single, sharded, routed = trio(quantize=quantize, fresh=True)
    assert routed.backend.kind == "routed" and routed.backend.shards == 4
    q = _queries(data)
    for mode in ("forest", "all"):
        for k in (1, 5, 17):
            ref = single.search(q, k=k, mode=mode)
            _assert_same_results(
                sharded.search(q, k=k, mode=mode), ref,
                what=f"sharded/{mode}/k{k}",
            )
            _assert_same_results(
                routed.search(q, k=k, mode=mode), ref,
                what=f"routed/{mode}/k{k}",
            )
    # the delta phase folds into the eligibility bounds: same stream into
    # all three layouts, still bitwise
    batch = _queries(data, 40, seed=9)
    np.testing.assert_array_equal(single.ingest(batch), routed.ingest(batch))
    sharded.ingest(batch)
    for mode in ("forest", "all"):
        ref = single.search(q, k=9, mode=mode)
        _assert_same_results(
            routed.search(q, k=9, mode=mode), ref, what=f"delta/{mode}"
        )


@pytest.mark.parametrize("fanout", ["targeted", "all"])
def test_forced_fanout_modes_stay_bitwise(trio, data, fanout):
    single, _, routed = trio(routing=RoutingConfig(fanout=fanout))
    q = _queries(data)
    for k in (1, 7):
        _assert_same_results(
            routed.search(q, k=k), single.search(q, k=k),
            what=f"fanout={fanout}/k{k}",
        )
    m = routed.metrics()["router"]
    assert m["fanout"][fanout] > 0
    other = "all" if fanout == "targeted" else "targeted"
    assert m["fanout"][other] == 0
    if fanout == "targeted":
        assert m["pruned_hosts"] > 0  # clustered data: pruning actually fires


# ---------------------------------------------------------------------------
# cost model + metrics: targeted on clustered, fan-all on uniform
# ---------------------------------------------------------------------------

def test_auto_picks_targeted_on_clustered_and_reports(trio, data):
    single, _, routed = trio(fresh=True)
    q = _queries(data)
    ref = single.search(q, k=10)
    _assert_same_results(routed.search(q, k=10), ref, what="auto")
    m = routed.metrics()["router"]
    assert m["queries"] == len(q)
    # clustered + well-separated: the lower bounds prune most of the fleet
    assert m["eligible_hosts"] < 4 * len(q)
    assert m["fanout"]["targeted"] == len(q) and m["fanout"]["all"] == 0
    assert m["pruned_hosts"] > 0
    assert 0 < m["est_bytes"]["targeted"] < m["est_bytes"]["all"]
    assert m["table"]["hosts"] == 4
    assert sum(m["table"]["host_counts"]) == routed.n_total


def test_auto_degenerates_to_fanall_on_uniform():
    g = np.random.default_rng(5)
    x = g.uniform(-10, 10, size=(1200, 6)).astype(np.float32)
    kw = dict(method="vbm", eps=1.8, min_pts=6, xi_min=0.3, xi_max=0.7)
    single = OverlapIndex.build(x, _cfg(index_kw=kw))
    routed = OverlapIndex.build(x, _cfg(index_kw=kw, layout=ROUTED4))
    q = _queries(x, 16, seed=2)
    _assert_same_results(routed.search(q, k=10), single.search(q, k=10))
    m = routed.metrics()["router"]
    # nothing prunable -> pricing must refuse the routing-tier overhead
    assert m["fanout"]["all"] == len(q) and m["fanout"]["targeted"] == 0
    assert m["pruned_hosts"] == 0


# ---------------------------------------------------------------------------
# pruning soundness: the rule itself, not just its end-to-end shadow
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_pruning_soundness_property(seed):
    """For every (query, pruned host): the host's nearest owned member lies
    STRICTLY beyond the fan-all kth-best, so dropping the host cannot touch
    the top-k.  Checked against brute-force numpy distances with the
    ownership arithmetic the executor actually shards by."""
    from repro.core import knn as cknn
    from repro.core.metric import pairwise
    from repro.distributed.router import host_eligibility
    from repro.distributed.router.table import shard_owners

    x = _islands(seed=seed, n_per=250, spread=60.0 * (1 + seed))
    ix = OverlapIndex.build(x, _cfg(layout=ROUTED4))
    q = _queries(x, 16, seed=seed + 7)
    k = 8
    res = ix.search(q, k=k)

    dev = ix.device
    table = ix.backend.table
    d_center = jnp.sqrt(cknn.route_points(dev.index_centers, jnp.asarray(q))[0])
    sel, _, _ = cknn.route_select(dev, jnp.asarray(q), mode="forest")
    d_host = pairwise(jnp.asarray(q), table.host_centers, metric="l2",
                      use_kernel=False)
    elig, _ = host_eligibility(table, d_center, d_host, sel, k)
    elig = np.asarray(elig)

    # brute-force per-host nearest member under the executor's ownership
    f = ix.forest
    owner = shard_owners(f.n_buckets, 4)  # (NB,)
    mask = np.asarray(f.bucket_mask)
    member_owner = np.broadcast_to(owner[:, None], mask.shape)[mask]  # (N,)
    member_x = np.asarray(f.bucket_x, np.float32)[mask]  # (N, D)
    d = np.sqrt(((q[:, None, :] - member_x[None]) ** 2).sum(-1))  # (Q, N)
    kth = np.sqrt(np.asarray(res.dists)[:, -1])  # searches return squared
    for h in range(4):
        on_h = member_owner == h
        if not on_h.any():
            continue
        nearest = d[:, on_h].min(axis=1)
        dropped = ~elig[:, h]
        assert (nearest[dropped] > kth[dropped]).all(), f"host {h} unsound"
    # and the property is not vacuous: something was actually pruned
    assert (~elig).any()


# ---------------------------------------------------------------------------
# maintenance: rebuild swaps refresh the table
# ---------------------------------------------------------------------------

def test_rebuild_swap_refreshes_table_and_stays_bitwise(trio, data):
    single, _, routed = trio(fresh=True)
    batch = _queries(data, 50, seed=5)
    single.ingest(batch)
    routed.ingest(batch)
    before = np.asarray(jax.device_get(routed.backend.table.count_hi))
    assert single.forest.n_indexes >= 2
    triggers = [0, single.forest.n_indexes - 1]
    single._rebuild(triggers)
    routed._rebuild(triggers)
    after = np.asarray(jax.device_get(routed.backend.table.count_hi))
    # absorbed delta members moved into the tree: ownership counts moved too
    # (the table counts FOREST members; survivors' un-absorbed buffers stay
    # in the delta term of the eligibility bounds, not in the table)
    assert after.sum() > before.sum()
    assert after.sum() == np.asarray(routed.forest.bucket_mask).sum()
    q = _queries(data)
    for mode in ("forest", "all"):
        _assert_same_results(
            routed.search(q, k=7, mode=mode),
            single.search(q, k=7, mode=mode),
            what=f"post-rebuild/{mode}",
        )


# ---------------------------------------------------------------------------
# persistence: RoutingConfig round trip + host-count clamp rebuilds the table
# ---------------------------------------------------------------------------

def test_persistence_reroute_roundtrip(data, tmp_path):
    routing = RoutingConfig(fanout="targeted", overlap_method="dbm")
    ix = OverlapIndex.build(
        data,
        _cfg(layout=LayoutConfig(kind="routed", shards=4, routing=routing)),
    )
    ix.ingest(_queries(data, 30, seed=4))
    path = ix.save(tmp_path / "routed.npz")
    q = _queries(data)
    ref = ix.search(q, k=9)

    as_saved = OverlapIndex.load(path)
    assert as_saved.backend.kind == "routed"
    assert as_saved.cfg.layout.routing == routing  # config round-trips typed
    assert as_saved.backend.routing.fanout == "targeted"
    _assert_same_results(as_saved.search(q, k=9), ref, what="saved")
    tab = as_saved.backend.table
    # forest members only: the streamed-but-unabsorbed rows ride the delta
    assert int(jax.device_get(tab.host_counts).sum()) == int(
        np.asarray(as_saved.forest.bucket_mask).sum()
    )

    # layout override at load: routed -> single and routed -> sharded
    as_single = OverlapIndex.load(path, layout=LayoutConfig())
    as_sharded = OverlapIndex.load(path, layout=SHARDED4)
    _assert_same_results(as_single.search(q, k=9), ref, what="to-single")
    _assert_same_results(as_sharded.search(q, k=9), ref, what="to-sharded")


def test_load_clamp_rebuilds_routing_table(data, tmp_path, monkeypatch):
    """A snapshot saved routed x4 loaded on a 2-device host must re-shard
    AND rebuild the table for the clamped ownership — a 4-host table over
    2-host islands would silently mis-route."""
    ix = OverlapIndex.build(data, _cfg(layout=ROUTED4))
    path = ix.save(tmp_path / "clamp.npz")
    q = _queries(data)
    ref = ix.search(q, k=9)

    real_count = jax.device_count
    monkeypatch.setattr(jax, "device_count", lambda *a, **kw: 2)
    try:
        with pytest.warns(UserWarning, match="re-sharding to 2"):
            clamped = OverlapIndex.load(path)
        assert clamped.backend.kind == "routed"
        assert clamped.backend.shards == 2
        res = clamped.search(q, k=9)
    finally:
        monkeypatch.setattr(jax, "device_count", real_count)
    _assert_same_results(res, ref, what="clamped")
    tab = jax.device_get(clamped.backend.table)
    assert tab.host_counts.shape == (2,)  # table rebuilt for 2 hosts
    assert int(tab.host_counts.sum()) == ix.n_total
    assert clamped.metrics()["router"]["table"]["hosts"] == 2


# ---------------------------------------------------------------------------
# serving: the datastore rides the routed layout
# ---------------------------------------------------------------------------

def test_serving_datastore_rides_routed_layout(trio, data):
    from repro.serve.retrieval import forest_knn

    single, _, routed = trio()
    vals = np.arange(single.n_total) % 97
    ds_s = single.to_datastore(vals, stream_capacity=128)
    ds_r = routed.to_datastore(vals, stream_capacity=128)
    assert ds_r.shards == 4
    assert ds_r.router_table is not None
    assert ds_r.fanout == "auto"

    q = jnp.asarray(_queries(data, 12))
    d_s, v_s = forest_knn(q, ds_s, k=5)
    d_r, v_r = forest_knn(q, ds_r, k=5)
    np.testing.assert_array_equal(np.asarray(d_r), np.asarray(d_s))
    np.testing.assert_array_equal(np.asarray(v_r), np.asarray(v_s))

    # inside an outer jit — the engine's decode step boundary
    jit_knn = jax.jit(forest_knn, static_argnames=("k", "kernel"))
    d_rj, v_rj = jit_knn(q, ds_r, k=5)
    np.testing.assert_array_equal(np.asarray(d_rj), np.asarray(d_s))
    np.testing.assert_array_equal(np.asarray(v_rj), np.asarray(v_s))


# ---------------------------------------------------------------------------
# plumbing: plan keys, explain, defaults
# ---------------------------------------------------------------------------

def test_plan_keys_carry_fanout(trio, data):
    _, sharded, routed = trio()
    q = _queries(data, 4)
    rr = routed.search(q, k=3)
    rs = sharded.search(q, k=3)
    assert rr.plan.key.fanout == "auto"
    assert rs.plan.key.fanout is None
    assert rr.plan.key != rs.plan.key
    assert "routed" in repr(routed)


def test_routed_explain_bitwise_with_router_stats(trio, data):
    single, _, routed = trio()
    q = _queries(data, 8)
    ref = single.search(q, k=5)
    rep = routed.explain(q, k=5)
    np.testing.assert_array_equal(rep.result.dists, ref.dists)
    np.testing.assert_array_equal(rep.result.ids, ref.ids)
    np.testing.assert_array_equal(
        rep.contributing + rep.wasted, rep.result.stats["buckets_visited"]
    )


def test_routed_single_shard_degenerates():
    backend = make_backend(LayoutConfig(kind="routed", shards=1))
    assert backend.kind == "single"  # one host: routing is vacuous
