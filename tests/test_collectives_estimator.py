"""Collective helpers (shard_map islands) + HBM-traffic estimator tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.distributed import context as dctx
from repro.distributed.collectives import (
    compressed_psum,
    ring_allgather_pipelined,
    topk_allgather_merge,
)
from repro.distributed.estimator import estimate_memory_bytes
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def mesh1d():
    return make_host_mesh()


def _run_island(mesh, fn, *args, in_specs=None, out_specs=P()):
    n = len(jax.devices())
    return dctx.shard_map(
        fn, mesh=mesh,
        in_specs=in_specs or tuple(P() for _ in args),
        out_specs=out_specs, check_vma=False,
    )(*args)


def test_compressed_psum_matches_fp32(mesh1d, rng):
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    got = _run_island(mesh1d, lambda a: compressed_psum(a, "data"), x)
    want = _run_island(mesh1d, lambda a: jax.lax.psum(a, "data"), x)
    # single value per shard (replicated input): compression error ~ bf16 eps
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2)
    assert got.dtype == jnp.float32  # wire dtype restored


def test_ring_allgather_pipelined_matches_plain(mesh1d, rng):
    n = len(jax.devices())
    x = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)

    def island(a):
        plain = jax.lax.all_gather(a, "data", axis=0, tiled=True)
        chunked = ring_allgather_pipelined(a, "data", chunks=4)
        return plain, chunked

    plain, chunked = _run_island(
        mesh1d, island, x,
        in_specs=(P("data"),) if n > 1 else (P(),),
        out_specs=(P(), P()),
    )
    np.testing.assert_allclose(np.asarray(plain), np.asarray(chunked), atol=1e-6)


def test_topk_allgather_merge(mesh1d, rng):
    vals = jnp.asarray(np.sort(rng.normal(size=(4, 3)), axis=1), jnp.float32)
    payload = jnp.asarray(rng.integers(0, 100, (4, 3)), jnp.int32)

    def island(v, p):
        return topk_allgather_merge(v, p, "data", k=3)

    got_v, got_p = _run_island(mesh1d, island, vals, payload,
                               out_specs=(P(), P()))
    # replicated input: global top-k == local top-k
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(vals), atol=1e-6)


def test_estimator_terms_positive_and_ordered():
    """Every shape kind produces positive totals; decode dominated by
    params+cache; train dominated by activations at these scales."""
    cfg = get_config("granite-20b")
    mesh = make_host_mesh()

    train = estimate_memory_bytes(cfg, SHAPES["train_4k"], mesh,
                                  params_local=int(1e9), opt_local=int(1e8))
    decode = estimate_memory_bytes(cfg, SHAPES["decode_32k"], mesh,
                                   params_local=int(1e9), cache_local=int(5e8),
                                   datastore_local=int(1e7))
    prefill = estimate_memory_bytes(cfg, SHAPES["prefill_32k"], mesh,
                                    params_local=int(1e9), cache_local=int(5e8))
    for parts in (train, decode, prefill):
        assert parts["total"] > 0
        assert all(v >= 0 for v in parts.values())
    assert train["layer_working_set"] > train["params"] * 0.01
    assert decode["params"] + decode["cache"] >= 0.9 * (
        decode["total"] - decode["datastore"] - decode["activations"])
