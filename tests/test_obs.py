"""Telemetry layer (repro.obs) tests: metric primitives, registry
snapshots, span nesting, JSONL event round-trip, the no-effect guarantee
(metrics-enabled search bitwise-identical to metrics-off), the facade's
``OverlapIndex.metrics()`` snapshot shape, and the plan-cache accounting
fixes that rode along (eviction keeps lifetime traces; ``stats_to_host``
is one batched device fetch)."""
import json
import math
import threading

import numpy as np
import pytest

from repro.api import Config, IndexConfig, ObsConfig, OverlapIndex, StreamConfig
from repro.api.plan import PlanCache, PlanKey, stats_to_host
from repro.obs import EventLog, Histogram, Registry, events_path_from_env


def _cfg(obs: bool = True, **obs_kw) -> Config:
    return Config(
        index=IndexConfig(
            method="vbm", eps=1.5, min_pts=8, xi_min=0.3, xi_max=0.7
        ),
        stream=StreamConfig(capacity=64),
        obs=ObsConfig(enabled=obs, **obs_kw),
    )


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def test_counter_gauge_basics():
    reg = Registry()
    reg.counter("c").inc()
    reg.counter("c").inc(4)
    assert reg.value("c") == 5
    assert reg.value("never_touched") == 0
    reg.gauge("g").set(2.5)
    reg.gauge("g").add(-0.5)
    assert reg.snapshot()["gauges"]["g"] == 2.0


def test_counter_labels_are_distinct_series():
    reg = Registry()
    reg.counter("hits", method="dbm").inc(3)
    reg.counter("hits", method="obm").inc(7)
    assert reg.value("hits", method="dbm") == 3
    assert reg.value("hits", method="obm") == 7
    snap = reg.snapshot()["counters"]
    assert snap["hits{method=dbm}"] == 3
    assert snap["hits{method=obm}"] == 7


@pytest.mark.parametrize("n", [1, 2, 7, 100, 2048])
def test_histogram_percentiles_match_numpy(n):
    # while count <= window the windowed percentile must be EXACTLY
    # numpy's linear-interpolation percentile over everything observed
    g = np.random.default_rng(n)
    vals = g.normal(size=n) ** 2
    h = Histogram(window=2048)
    for v in vals:
        h.observe(v)
    for q in (0, 25, 50, 95, 99, 100):
        assert h.percentile(q) == pytest.approx(
            float(np.percentile(vals, q)), rel=1e-12
        )
    s = h.snapshot()
    assert s["count"] == n
    assert s["sum"] == pytest.approx(vals.sum())
    assert s["min"] == vals.min() and s["max"] == vals.max()


def test_histogram_windowing_drops_oldest():
    h = Histogram(window=4)
    for v in [100.0, 100.0, 1.0, 2.0, 3.0, 4.0]:
        h.observe(v)
    # window holds the newest 4 observations; lifetime extrema persist
    assert h.percentile(100) == 4.0
    assert h.snapshot()["max"] == 100.0
    assert h.snapshot()["count"] == 6
    assert h.snapshot()["window"] == 4


def test_histogram_empty_is_nan():
    s = Histogram().snapshot()
    assert s["count"] == 0
    assert math.isnan(s["p50"]) and math.isnan(s["min"])


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


def test_span_nesting_records_paths():
    reg = Registry()
    with reg.span("search") as outer:
        assert outer == "search"
        with reg.span("plan_lookup") as inner:
            assert inner == "search/plan_lookup"
    with reg.span("search"):
        pass
    hists = reg.snapshot()["histograms"]
    assert hists["search"]["count"] == 2
    assert hists["search/plan_lookup"]["count"] == 1
    assert hists["search/plan_lookup"]["p50"] >= 0.0


def test_span_unwinds_and_records_on_exception():
    reg = Registry()
    with pytest.raises(RuntimeError):
        with reg.span("outer"):
            with reg.span("boom"):
                raise RuntimeError("phase failed")
    hists = reg.snapshot()["histograms"]
    # both spans recorded despite the raise, and the stack unwound fully
    assert hists["outer/boom"]["count"] == 1
    assert hists["outer"]["count"] == 1
    with reg.span("clean") as path:
        assert path == "clean"  # not "outer/clean" — stack is empty again


def test_span_stack_is_per_thread():
    reg = Registry()
    seen = {}

    def worker(name):
        with reg.span(name):
            with reg.span("inner") as p:
                seen[name] = p

    ts = [threading.Thread(target=worker, args=(f"t{i}",)) for i in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert seen == {f"t{i}": f"t{i}/inner" for i in range(4)}


def test_disabled_registry_is_inert():
    reg = Registry(enabled=False)
    reg.counter("c").inc(10)
    reg.gauge("g").set(3)
    reg.histogram("h").observe(1.0)
    with reg.span("s") as path:
        assert path is None
    snap = reg.snapshot()
    assert snap["enabled"] is False
    assert snap["counters"] == {} and snap["histograms"] == {}
    # null objects are shared singletons — no per-call allocation
    assert reg.counter("a") is reg.counter("b")


# ---------------------------------------------------------------------------
# events (JSONL)
# ---------------------------------------------------------------------------


def test_event_log_roundtrip(tmp_path):
    p = tmp_path / "events.jsonl"
    with EventLog(str(p)) as log:
        log.emit({"event": "custom", "x": 1})
        reg = Registry(events=log)
        with reg.span("search", method="vbm"):
            pass
    recs = EventLog.read(str(p))
    assert [r["event"] for r in recs] == ["custom", "span"]
    assert recs[1]["span"] == "search"
    assert recs[1]["labels"] == {"method": "vbm"}
    assert recs[1]["dur_s"] >= 0.0
    assert all("ts" in r for r in recs)
    # append mode: reopening adds, never truncates
    with EventLog(str(p)) as log:
        log.emit({"event": "later"})
    assert len(EventLog.read(str(p))) == 3


def test_events_path_from_env(monkeypatch):
    monkeypatch.delenv("REPRO_OBS_EVENTS", raising=False)
    assert events_path_from_env() is None
    monkeypatch.setenv("REPRO_OBS_EVENTS", "/tmp/x.jsonl")
    assert events_path_from_env() == "/tmp/x.jsonl"


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------


def test_obs_config_validation():
    from repro.api import ConfigError

    with pytest.raises(ConfigError, match="window"):
        ObsConfig(window=0)
    with pytest.raises(ConfigError, match="events_path"):
        ObsConfig(events_path="")
    with pytest.raises(ConfigError, match="trace_sample"):
        ObsConfig(trace_sample=1.5)
    with pytest.raises(ConfigError, match="trace_sample"):
        ObsConfig(trace_sample=-0.1)
    with pytest.raises(ConfigError, match="events_max_bytes"):
        ObsConfig(events_max_bytes=0)
    with pytest.raises(ConfigError, match="events_backups"):
        ObsConfig(events_backups=-1)
    with pytest.raises(ConfigError, match="wasted_rebuild"):
        StreamConfig(wasted_rebuild=0.0)
    with pytest.raises(ConfigError, match="wasted_rebuild"):
        StreamConfig(wasted_rebuild=1.5)


# ---------------------------------------------------------------------------
# facade integration
# ---------------------------------------------------------------------------


def test_metrics_enabled_search_bitwise_identical(blob_data, tmp_path):
    q = np.asarray(blob_data[:8])
    idx_on = OverlapIndex.build(blob_data, _cfg(obs=True))
    idx_off = OverlapIndex.build(blob_data, _cfg(obs=False))
    r_on = idx_on.search(q, k=5)
    r_off = idx_off.search(q, k=5)
    assert np.array_equal(np.asarray(r_on.dists), np.asarray(r_off.dists))
    assert np.array_equal(np.asarray(r_on.ids), np.asarray(r_off.ids))
    assert idx_off.metrics()["enabled"] is False
    assert idx_off.metrics()["search"]["queries"] == 0
    # sampled tracing is host-side bookkeeping too: a fully traced search
    # (every request gets a span tree in the event log) returns the same
    # bits as the metrics-off search
    idx_tr = OverlapIndex.build(blob_data, _cfg(
        obs=True, trace_sample=1.0,
        events_path=str(tmp_path / "tr.jsonl"),
    ))
    r_tr = idx_tr.search(q, k=5)
    assert np.array_equal(np.asarray(r_tr.dists), np.asarray(r_off.dists))
    assert np.array_equal(np.asarray(r_tr.ids), np.asarray(r_off.ids))
    # explain() runs the identical op sequence plus host-side attribution:
    # its embedded result must match plain search() bitwise as well
    rep = idx_tr.explain(q, k=5)
    assert np.array_equal(np.asarray(rep.result.dists), np.asarray(r_off.dists))
    assert np.array_equal(np.asarray(rep.result.ids), np.asarray(r_off.ids))


def test_facade_metrics_snapshot_shape(blob_data):
    idx = OverlapIndex.build(blob_data, _cfg())
    q = np.asarray(blob_data[:8])
    idx.search(q, k=5)
    idx.search(q, k=5)
    g = np.random.default_rng(0)
    idx.ingest(g.normal(size=(16, blob_data.shape[1])).astype(np.float32))
    idx.check()
    m = idx.metrics()
    assert m["enabled"] is True
    # per-phase spans under the search root
    spans = m["search"]["spans"]
    for path in ("search", "search/plan_lookup", "search/device_execute",
                 "search/host_transfer"):
        assert spans[path]["count"] == 2, path
    assert m["search"]["queries"] == 16
    assert m["search"]["buckets_visited"] > 0
    assert m["search"]["bound_distances"] > 0
    # plan cache counters flow into the same registry AND the stats dict
    assert m["plan_cache"]["misses"] >= 1
    assert m["registry"]["counters"]["plan_cache.misses"] \
        == m["plan_cache"]["misses"]
    assert m["ingest"]["points"] == 16
    assert m["maintenance"]["checks"] == 1
    # single layout: exactly one island, carrying the paper's cost currency
    assert set(m["islands"]) == {0}
    isl = m["islands"][0]
    assert isl["buckets_visited"] == m["search"]["buckets_visited"]
    assert isl["distances"] == m["search"]["distances"]
    assert json.dumps(m["registry"])  # whole snapshot is JSON-serializable


def test_metrics_events_jsonl(blob_data, tmp_path):
    p = tmp_path / "spans.jsonl"
    idx = OverlapIndex.build(blob_data, _cfg(events_path=str(p)))
    idx.search(np.asarray(blob_data[:4]), k=3)
    spans = {r["span"] for r in EventLog.read(str(p))}
    assert "search" in spans and "search/device_execute" in spans


# ---------------------------------------------------------------------------
# plan-cache accounting satellites
# ---------------------------------------------------------------------------


def _fake_key(i: int) -> PlanKey:
    return PlanKey(k=i + 1, mode="exact", beam=4, kernel=True,
                   quantize=False, delta_capacity=None, shards=1)


def test_plan_cache_eviction_keeps_lifetime_traces():
    cache = PlanCache(max_plans=2)
    for i in range(4):  # 4 misses into a 2-slot cache -> 2 evictions
        plan = cache.plan(_fake_key(i))
        plan.traces += 1
    st = cache.stats()
    assert st["evictions"] == 2
    assert st["plans"] == 2
    # lifetime traces survive eviction: 4 plans traced once each
    assert st["traces"] == 4


def test_plan_cache_counters_flow_into_registry():
    reg = Registry()
    cache = PlanCache(max_plans=2, registry=reg)
    cache.plan(_fake_key(0))
    cache.plan(_fake_key(0))
    cache.plan(_fake_key(1))
    cache.plan(_fake_key(2))
    assert reg.value("plan_cache.hits") == 1
    assert reg.value("plan_cache.misses") == 3
    assert reg.value("plan_cache.evictions") == 1


def test_stats_to_host_single_device_get(monkeypatch):
    import jax
    import jax.numpy as jnp

    import repro.api.plan as plan_mod
    from repro.core.knn import SearchStats

    stats = SearchStats(
        buckets_visited=jnp.ones((4,), jnp.int32),
        distances=jnp.ones((4,), jnp.int32),
        bound_distances=jnp.ones((4,), jnp.int32),
        padded_distances=jnp.ones((4,), jnp.int32),
        comparisons=jnp.ones((4,), jnp.int32),
        steps=jnp.int32(3),
    )
    calls = []
    real = jax.device_get

    def counting(x):
        calls.append(x)
        return real(x)

    monkeypatch.setattr(plan_mod.jax, "device_get", counting)
    host = stats_to_host(stats)
    assert len(calls) == 1  # ONE batched fetch, not one per field
    assert set(host) == {"buckets_visited", "distances", "bound_distances",
                         "padded_distances", "comparisons", "steps"}
    assert isinstance(host["steps"], int)
