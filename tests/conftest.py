import os

# Tests must see the single real CPU device (the 512-device fake mesh is
# strictly dryrun.py's business — see launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)


@pytest.fixture(scope="session")
def blob_data():
    """Clustered dataset with noise — the shape of the paper's IoT data."""
    g = np.random.default_rng(7)
    centers = g.normal(size=(5, 8)) * 10.0
    parts = [c + g.normal(size=(400, 8)) for c in centers]
    parts.append(g.uniform(-15, 15, size=(100, 8)))
    return np.concatenate(parts).astype(np.float32)
