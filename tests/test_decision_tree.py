"""Decision stage (§4.3) invariants + BCCF tree construction tests."""
import numpy as np
import pytest

from repro.core import decide, dbscan, partitions_from_labels
from repro.core.bccf import build_tree
from repro.core.decision import Partition


def _setup(blob_data, method):
    x = blob_data[:1200]
    res = dbscan(x, 1.5, 8)
    pivots, radii, assign = partitions_from_labels(x, res.labels, res.n_clusters)
    groups, stats = decide(x, pivots, radii, assign, method=method, xi_min=0.3, xi_max=0.7)
    return x, groups, stats


@pytest.mark.parametrize("method", ["vbm", "dbm", "obm"])
def test_decision_is_a_partition_of_objects(blob_data, method):
    """No object lost, none duplicated — regardless of merges/extractions."""
    x, groups, stats = _setup(blob_data, method)
    all_members = np.concatenate([g.members for g in groups])
    assert len(all_members) == len(x)
    assert len(np.unique(all_members)) == len(x)
    assert stats.n_final == len(groups)


@pytest.mark.parametrize("method", ["vbm", "dbm"])
def test_decision_geometry_and_links(blob_data, method):
    x, groups, _ = _setup(blob_data, method)
    for i, g in enumerate(groups):
        # radius covers members
        d = np.sqrt(((x[g.members] - g.pivot) ** 2).sum(-1))
        assert (d <= g.radius + 1e-3).all()
        # neighbor links are symmetric and valid
        for nb in g.neighbors:
            assert 0 <= nb < len(groups) and nb != i
            assert i in groups[nb].neighbors
        if g.is_overlap_index:
            assert len(g.neighbors) >= 1


def test_merge_all_when_thresholds_zero(blob_data):
    """xi_max=0 forces every overlapping pair to merge."""
    x = blob_data[:600]
    res = dbscan(x, 1.5, 8)
    pivots, radii, assign = partitions_from_labels(x, res.labels, res.n_clusters)
    groups, _ = decide(x, pivots, radii, assign, method="dbm", xi_min=0.0, xi_max=0.0)
    # every group disjoint from every other (or single group)
    for i, g in enumerate(groups):
        for j, h in enumerate(groups):
            if i < j:
                d = np.sqrt(((g.pivot - h.pivot) ** 2).sum())
                assert d >= g.radius + h.radius - 1e-3


@pytest.mark.parametrize("pivot_method", ["gh", "kmeans"])
def test_tree_invariants(blob_data, pivot_method):
    x = blob_data[:700]
    ids = np.arange(len(x))
    tree = build_tree(x, ids, c_max=30, pivot_method=pivot_method, seed=0)
    # every object in exactly one bucket
    got = np.sort(np.concatenate(tree.bucket_members))
    assert (got == ids).all()
    # bucket capacity respected
    assert max(len(b) for b in tree.bucket_members) <= 30
    # structure bookkeeping consistent
    s = tree.structure
    assert s.n_leaves == len(tree.bucket_members)
    assert s.n_internal == len(tree.node_children)
    assert sum(s.nodes_per_level.values()) == s.n_internal + s.n_leaves
    # binary tree: leaves = internal + 1
    assert s.n_leaves == s.n_internal + 1
    assert tree.counters.distances > 0 and tree.counters.comparisons > 0


def test_tree_radii_cover_subtree(blob_data):
    """Def. 12: node radii are max distance over the whole subtree."""
    x = blob_data[:400]
    tree = build_tree(x, np.arange(len(x)), c_max=25, pivot_method="gh", seed=1)

    def collect(node: int) -> np.ndarray:
        if node < 0:
            return tree.bucket_members[-(node + 1)]
        l, r = tree.node_children[node]
        return np.concatenate([collect(l), collect(r)])

    for nid in range(len(tree.node_children)):
        members = collect(nid)
        for side in (0, 1):
            d = np.sqrt(((x[members] - tree.node_pivots[nid, side]) ** 2).sum(-1))
            assert d.max() <= tree.node_radii[nid, side] + 1e-3


def test_gh_cheaper_than_kmeans(blob_data):
    """The paper's §4.3 rationale: GH construction needs fewer distances."""
    x = blob_data[:1000]
    t_gh = build_tree(x, np.arange(len(x)), c_max=32, pivot_method="gh", seed=0)
    t_km = build_tree(x, np.arange(len(x)), c_max=32, pivot_method="kmeans", seed=0)
    assert t_gh.counters.distances < t_km.counters.distances


def test_duplicate_points_dont_hang():
    x = np.ones((100, 4), np.float32)
    tree = build_tree(x, np.arange(100), c_max=10, pivot_method="gh", seed=0)
    assert sum(len(b) for b in tree.bucket_members) == 100
