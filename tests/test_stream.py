"""Streaming subsystem tests: ingest routing/append, forest+delta search
exactness, overlap-drift triggers at the ξ threshold, and rebuild hot swaps
(structure freshness + no correctness gap)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    IndexConfig,
    build_index,
    device_forest,
    knn_exact,
    knn_search,
    route_eligibility,
)
from repro.stream import (
    MaintenanceConfig,
    OverlapMonitor,
    StreamingForest,
    alloc_delta,
    delta_view,
    ingest,
    pull_delta_meta,
)


@pytest.fixture(scope="module")
def built(blob_data):
    cfg = IndexConfig(method="vbm", eps=1.5, min_pts=8, xi_min=0.3, xi_max=0.7)
    forest, _ = build_index(blob_data, cfg)
    return blob_data, forest


def _stream_points(x, n, seed):
    g = np.random.default_rng(seed)
    base = x[g.choice(len(x), n)]
    return (base + 0.3 * g.normal(size=base.shape)).astype(np.float32)


def test_ingest_routes_and_appends(built):
    x, forest = built
    df = device_forest(forest)
    delta = alloc_delta(forest, capacity=64)
    xb = _stream_points(x, 40, seed=0)
    ids = np.arange(len(x), len(x) + 40)
    delta, acc = ingest(df, delta, jnp.asarray(xb), jnp.asarray(ids))
    assert bool(np.asarray(acc).all())
    host = pull_delta_meta(delta, ids=True)
    assert host["count"].sum() == 40
    # routing must equal host-side argmin over index centers
    d = ((xb[:, None, :] - forest.index_centers[None]) ** 2).sum(-1)
    want = d.argmin(axis=1)
    got = np.full(40, -1)
    for i in range(forest.n_indexes):
        for j in range(host["count"][i]):
            got[host["ids"][i, j] - len(x)] = i
    np.testing.assert_array_equal(got, want)
    # every id stored exactly once; coordinates round-trip
    stored = np.sort(host["ids"][host["ids"] >= 0])
    np.testing.assert_array_equal(stored, ids)


def test_ingest_capacity_reject_reported(built):
    x, forest = built
    df = device_forest(forest)
    delta = alloc_delta(forest, capacity=4)
    xb = _stream_points(x, 200, seed=1)
    delta, acc = ingest(
        df, delta, jnp.asarray(xb), jnp.asarray(np.arange(200) + len(x))
    )
    acc = np.asarray(acc)
    host = pull_delta_meta(delta)
    assert (host["count"] <= 4).all()  # never written past capacity
    assert host["count"].sum() == acc.sum()
    assert host["dropped"].sum() == (~acc).sum() > 0  # rejects are visible


def test_forest_plus_delta_matches_brute_force(built, rng):
    x, forest = built
    df = device_forest(forest)
    delta = alloc_delta(forest, capacity=256)
    xs = _stream_points(x, 300, seed=2)
    delta, acc = ingest(
        df, delta, jnp.asarray(xs), jnp.asarray(np.arange(300) + len(x))
    )
    assert bool(np.asarray(acc).all())
    x_all = np.concatenate([x, xs])
    q = rng.normal(size=(24, x.shape[1])).astype(np.float32) * 8
    d, ids, stats = knn_search(
        df, jnp.asarray(q), k=12, mode="all", delta=delta_view(delta)
    )
    de, _ = knn_exact(jnp.asarray(x_all), jnp.asarray(q), k=12)
    np.testing.assert_allclose(np.asarray(d), np.asarray(de), rtol=1e-4, atol=1e-4)
    # returned ids must cover delta members too (streamed points are findable)
    d2, ids2, _ = knn_search(
        df, jnp.asarray(xs[:8]), k=1, mode="all", delta=delta_view(delta)
    )
    np.testing.assert_array_equal(
        np.asarray(ids2)[:, 0], np.arange(8) + len(x)
    )


def test_empty_delta_is_noop(built, rng):
    x, forest = built
    df = device_forest(forest)
    delta = alloc_delta(forest, capacity=32)
    q = rng.normal(size=(8, x.shape[1])).astype(np.float32) * 8
    d0, i0, _ = knn_search(df, jnp.asarray(q), k=7, mode="all")
    d1, i1, _ = knn_search(df, jnp.asarray(q), k=7, mode="all", delta=delta_view(delta))
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


def test_route_eligibility_matches_one_hot_reference(built, rng):
    _, forest = built
    n_idx = forest.n_indexes
    closest = jnp.asarray(rng.integers(0, n_idx, 32), jnp.int32)
    neighbors = jnp.asarray(forest.neighbors)
    sel = np.asarray(route_eligibility(closest, neighbors))
    # reference: dense one-hot construction (the pre-segment-ops semantics)
    want = np.zeros((32, n_idx), bool)
    cl = np.asarray(closest)
    nb = np.asarray(neighbors)
    for qi in range(32):
        want[qi, cl[qi]] = True
        for n in nb[cl[qi]]:
            if n >= 0:
                want[qi, n] = True
    np.testing.assert_array_equal(sel, want)


def test_overlap_drift_trigger_fires_at_xi():
    """The ξ threshold is sharp: rate just above fires, just below doesn't."""
    g = np.random.default_rng(5)
    dim = 6
    c2 = np.zeros(dim)
    c2[0] = 18.0
    x0 = np.concatenate(
        [g.normal(size=(300, dim)), c2 + g.normal(size=(300, dim))]
    ).astype(np.float32)
    sf = StreamingForest(
        x0, IndexConfig(method="vbm", eps=1.5, min_pts=8),
        MaintenanceConfig(method="dbm", xi_rebuild=0.99, fill_rebuild=0.99),
        delta_capacity=512,
    )
    assert sf.forest.n_indexes == 2
    # corridor points inflate the conservative radii -> DBM rate rises
    mid = np.zeros(dim)
    mid[0] = 9.0
    sf.ingest((mid + g.normal(size=(150, dim)) * [4, 1, 1, 1, 1, 1]).astype(np.float32))
    rep = sf.check()
    worst = float(np.max(rep.rates))
    assert worst > 0.05, "drift scenario must create measurable overlap"
    below = OverlapMonitor(
        sf.forest, MaintenanceConfig(method="dbm", xi_rebuild=worst - 0.02,
                                     fill_rebuild=0.99)
    ).check(sf.delta)
    above = OverlapMonitor(
        sf.forest, MaintenanceConfig(method="dbm", xi_rebuild=worst + 0.02,
                                     fill_rebuild=0.99)
    ).check(sf.delta)
    assert any("overlap" in v for v in below.reasons.values())
    assert not any("overlap" in v for v in above.reasons.values())


@pytest.mark.parametrize("method", ["dbm", "obm"])
def test_monitor_methods_run(built, method):
    x, forest = built
    sf = StreamingForest(
        x, IndexConfig(method="vbm", eps=1.5, min_pts=8),
        MaintenanceConfig(method=method, xi_rebuild=0.9, fill_rebuild=0.9),
        delta_capacity=64,
    )
    sf.ingest(_stream_points(x, 30, seed=3))
    rep = sf.check()
    assert rep.rates.shape == (sf.forest.n_indexes,) * 2
    assert np.isfinite(rep.rates).all()


def test_rebuild_swap_exactness_and_fresh_structure(built, rng):
    x, forest = built
    sf = StreamingForest(
        x, IndexConfig(method="vbm", eps=1.5, min_pts=8),
        # low fill threshold: rebuilds fire quickly
        MaintenanceConfig(method="dbm", xi_rebuild=0.95, fill_rebuild=0.2),
        delta_capacity=128,
    )
    stats0 = dict(sf.forest.build_stats)
    for step in range(4):
        sf.ingest(_stream_points(x, 120, seed=10 + step))
        sf.maintain()
    assert sf.forest.build_stats["rebuilds"] > 0
    # counters accumulate across rebuilds (construction-cost metric)
    assert sf.forest.build_stats["tree_distances"] > stats0["tree_distances"]
    # structure rollup reflects the swapped trees (fresh host copies)
    s = sf.structure()
    assert s["total_leaves"] == sf.forest.n_buckets
    assert s["n_objects"] == sf.n_total == len(x) + 4 * 120
    # and search stays exact across all those swaps
    q = rng.normal(size=(16, x.shape[1])).astype(np.float32) * 8
    d, ids, _ = sf.search(q, k=10, mode="all")
    de, _ = knn_exact(jnp.asarray(sf.x_all), jnp.asarray(q), k=10)
    np.testing.assert_allclose(np.asarray(d), np.asarray(de), rtol=1e-4, atol=1e-4)


def test_stale_tree_copies_detected(built):
    """aggregate_structure must refuse to report over stale host trees."""
    x, forest = built
    forest2, _ = build_index(
        x[: len(x) // 2], IndexConfig(method="vbm", eps=1.5, min_pts=8)
    )
    broken = type(forest)(
        index_centers=forest.index_centers,
        index_radii=forest.index_radii,
        neighbors=forest.neighbors,
        is_overlap_index=forest.is_overlap_index,
        bucket_x=forest.bucket_x,
        bucket_ids=forest.bucket_ids,
        bucket_mask=forest.bucket_mask,
        bucket_pivot=forest.bucket_pivot,
        bucket_radius=forest.bucket_radius,
        bucket_index=forest.bucket_index,
        c_max=forest.c_max,
        trees=forest2.trees,  # stale/mismatched host copies
        build_stats=forest.build_stats,
    )
    if sum(t.structure.n_leaves for t in forest2.trees) == forest.n_buckets:
        pytest.skip("coincidental leaf-count match")
    with pytest.raises(RuntimeError, match="stale"):
        broken.aggregate_structure()


def test_ingest_never_loses_points_under_overflow(built, rng):
    """Forced maintenance on capacity rejects: every point stays findable."""
    x, forest = built
    sf = StreamingForest(
        x, IndexConfig(method="vbm", eps=1.5, min_pts=8),
        MaintenanceConfig(method="dbm", xi_rebuild=0.95, fill_rebuild=0.95),
        delta_capacity=16,  # tiny: guaranteed overflow
    )
    xs = _stream_points(x, 400, seed=21)
    ids = sf.ingest(xs)
    # every streamed point must be its own 1-NN through the serving path
    d, got, _ = sf.search(xs[:32], k=1, mode="all")
    np.testing.assert_array_equal(np.asarray(got)[:, 0], ids[:32])
    assert sf.forest.build_stats["rebuilds"] > 0  # overflow forced rebuilds
