"""Pallas kernel sweeps: shapes x dtypes, interpret mode vs jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import quantize_datastore
from repro.kernels.pairwise_l2 import (
    pairwise_sq_l2_int8_pallas,
    pairwise_sq_l2_pallas,
)
from repro.kernels.topk import knn_topk_pallas

SHAPES = [
    (8, 16, 4),     # tiny, all-padded
    (64, 64, 64),   # exact tile fit
    (65, 130, 33),  # ragged everything
    (128, 257, 96), # ragged N
    (1, 300, 20),   # single query (decode-style)
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("q_n,x_n,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_pairwise_matches_ref(q_n, x_n, d, dtype, rng):
    q = jnp.asarray(rng.normal(size=(q_n, d)), dtype)
    x = jnp.asarray(rng.normal(size=(x_n, d)), dtype)
    got = pairwise_sq_l2_pallas(q, x, bq=64, bn=64, bd=64, interpret=True)
    want = ref.pairwise_sq_l2_ref(q, x)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("bq,bn,bd", [(16, 16, 16), (64, 32, 128)])
def test_pairwise_block_shape_invariance(bq, bn, bd, rng):
    q = jnp.asarray(rng.normal(size=(70, 40)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(90, 40)), jnp.float32)
    got = pairwise_sq_l2_pallas(q, x, bq=bq, bn=bn, bd=bd, interpret=True)
    want = ref.pairwise_sq_l2_ref(q, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("q_n,x_n,d", [(16, 100, 24), (33, 257, 48)])
@pytest.mark.parametrize("k", [1, 5, 16])
def test_knn_topk_matches_ref(q_n, x_n, d, k, rng):
    q = jnp.asarray(rng.normal(size=(q_n, d)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(x_n, d)), jnp.float32)
    gv, gi = knn_topk_pallas(q, x, k=k, bq=16, bn=64, interpret=True)
    wv, wi = ref.knn_topk_ref(q, x, k)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-4, atol=1e-4)
    # indices must point at rows achieving those distances (ties allowed)
    d2 = np.asarray(ref.pairwise_sq_l2_ref(q, x))
    picked = d2[np.arange(q_n)[:, None], np.asarray(gi)]
    np.testing.assert_allclose(picked, np.asarray(gv), rtol=1e-4, atol=1e-4)


def test_knn_topk_fewer_rows_than_k(rng):
    q = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(3, 8)), jnp.float32)
    gv, gi = knn_topk_pallas(q, x, k=8, bq=16, bn=16, interpret=True)
    assert np.isinf(np.asarray(gv)[:, 3:]).all()
    assert (np.asarray(gi)[:, 3:] == -1).all()


@pytest.mark.parametrize("q_n,x_n,d", [(16, 64, 32), (40, 130, 20)])
def test_pairwise_int8_matches_ref(q_n, x_n, d, rng):
    q = jnp.asarray(rng.normal(size=(q_n, d)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(x_n, d)), jnp.float32)
    xq, scale = quantize_datastore(x)
    got = pairwise_sq_l2_int8_pallas(q, xq, scale, bq=32, bn=32, bd=32, interpret=True)
    want = ref.pairwise_sq_l2_int8_ref(q, xq, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
    # quantization error vs exact distances stays small for unit-scale data
    exact = ref.pairwise_sq_l2_ref(q, x)
    rel = np.abs(np.asarray(got) - np.asarray(exact)) / (np.asarray(exact) + 1.0)
    assert rel.mean() < 0.05


def test_ops_dispatch_cpu_uses_ref(rng):
    """On CPU without force-pallas, ops must route to the oracle (fast path)."""
    from repro.kernels import ops

    q = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(9, 8)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.pairwise_sq_l2(q, x)),
        np.asarray(ref.pairwise_sq_l2_ref(q, x)),
        rtol=1e-6,
    )
