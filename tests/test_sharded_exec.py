"""Tier-2 exactness gates for the sharded device layout (``cfg.layout``).

Every test here asserts BITWISE identity between the single-device
executor and the sharded shard_map islands (distributed/knn_island.py) on
the same data — distances AND ids, f32 and int8, forest and delta phase,
across maintenance rebuild swaps and save/load re-sharding.  Exactness is
the layout layer's contract, not a tolerance: per-member distance
arithmetic is shard-local and identical, and k-per-shard candidates make
the merged global top-k exact.

Run under a forced host mesh (set BEFORE jax initializes):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_sharded_exec.py

On a single-device host the whole module skips (tier-1 collection still
imports it, so an import-time regression fails everywhere).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import (
    Config,
    IndexConfig,
    LayoutConfig,
    ObsConfig,
    OverlapIndex,
    SearchConfig,
    StreamConfig,
    make_backend,
)

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="sharded layout tests need >= 4 devices; set "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax init",
)

SHARDED4 = LayoutConfig(kind="sharded", shards=4)


def _tracks() -> np.ndarray:
    """3-d trajectory-like clusters — a second shape/density regime, so the
    bitwise gate is exercised beyond the 8-d blobs fixture."""
    g = np.random.default_rng(21)
    centers = g.normal(size=(6, 3)) * 9.0
    parts = [c + 0.6 * g.normal(size=(300, 3)) for c in centers]
    parts.append(g.uniform(-12, 12, size=(60, 3)))
    return np.concatenate(parts).astype(np.float32)


def _queries(x: np.ndarray, n: int = 24, seed: int = 3) -> np.ndarray:
    g = np.random.default_rng(seed)
    base = x[g.choice(len(x), n)]
    return (base + 0.1 * x.std() * g.normal(size=base.shape)).astype(np.float32)


def _cfg(index_kw: dict, *, quantize=False, capacity=64, layout=None) -> Config:
    return Config(
        index=IndexConfig(**index_kw),
        search=SearchConfig(quantize=quantize),
        stream=StreamConfig(capacity=capacity),
        layout=layout or LayoutConfig(),
    )


@pytest.fixture(scope="module")
def datasets(blob_data):
    return {
        "blobs": (blob_data, dict(method="vbm", eps=1.5, min_pts=8,
                                  xi_min=0.3, xi_max=0.7)),
        "tracks": (_tracks(), dict(method="vbm", eps=0.8, min_pts=8,
                                   xi_min=0.4, xi_max=0.8)),
    }


@pytest.fixture(scope="module")
def pair(datasets):
    """Factory for a (single-layout, 4-shard) index pair over one dataset.

    ``fresh=True`` returns an uncached pair for tests that MUTATE the
    indexes (ingest / rebuild); read-only tests share the cached builds.
    """
    cache = {}

    def get(name, *, quantize=False, capacity=64, fresh=False):
        key = (name, quantize, capacity)
        if fresh or key not in cache:
            x, kw = datasets[name]
            built = (
                OverlapIndex.build(
                    x, _cfg(kw, quantize=quantize, capacity=capacity)
                ),
                OverlapIndex.build(
                    x, _cfg(kw, quantize=quantize, capacity=capacity,
                            layout=SHARDED4)
                ),
            )
            if fresh:
                return built
            cache[key] = built
        return cache[key]

    return get


def _assert_same_results(res, ref, what=""):
    np.testing.assert_array_equal(res.dists, ref.dists, err_msg=what)
    np.testing.assert_array_equal(res.ids, ref.ids, err_msg=what)
    # eligibility-derived instrumentation must agree too ('visits' may not:
    # each shard's bounded scan terminates on its LOCAL bound ordering)
    np.testing.assert_array_equal(
        res.stats["bound_distances"], ref.stats["bound_distances"], err_msg=what
    )


# ---------------------------------------------------------------------------
# search: forest phase + delta phase, f32 + int8, both datasets
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quantize", [False, True], ids=["f32", "int8"])
@pytest.mark.parametrize("name", ["blobs", "tracks"])
def test_search_bitwise_across_layouts(pair, datasets, name, quantize):
    single, sharded = pair(name, quantize=quantize, fresh=True)
    assert sharded.backend.shards == 4
    x, _ = datasets[name]
    q = _queries(x)
    for mode in ("forest", "all"):
        for k in (1, 5, 17):
            _assert_same_results(
                sharded.search(q, k=k, mode=mode),
                single.search(q, k=k, mode=mode),
                what=f"{name}/{mode}/k{k}/no-delta",
            )
    # mid-fill delta: the SAME stream into both layouts, then the two-phase
    # (forest + delta) search must still agree bitwise
    batch = _queries(x, 40, seed=9)
    np.testing.assert_array_equal(single.ingest(batch), sharded.ingest(batch))
    assert int(np.asarray(single.delta.count).sum()) == len(batch)
    for mode in ("forest", "all"):
        _assert_same_results(
            sharded.search(q, k=9, mode=mode),
            single.search(q, k=9, mode=mode),
            what=f"{name}/{mode}/k9/delta",
        )


# ---------------------------------------------------------------------------
# ingest: collective scatter == single-device routing, rejects aggregate
# ---------------------------------------------------------------------------

def test_sharded_ingest_matches_single_with_capacity_rejects(pair, datasets):
    # capacity 16 + batches up to 64: ragged power-of-two padding, chunking,
    # AND the capacity-reject -> forced-rebuild -> retry loop all fire; both
    # layouts must walk the identical deterministic path
    single, sharded = pair("blobs", capacity=16, fresh=True)
    x, _ = datasets["blobs"]
    for seed, n in enumerate((16, 7, 33, 64)):
        batch = _queries(x, n, seed=seed)
        np.testing.assert_array_equal(single.ingest(batch), sharded.ingest(batch))
        for field, a, b in zip(single.delta._fields, single.delta, sharded.delta):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"delta.{field} after n={n}"
            )
    # same compiled-shape discipline on both write paths
    assert single.ingest_stats() == sharded.ingest_stats()
    q = _queries(x)
    _assert_same_results(sharded.search(q, k=8), single.search(q, k=8))


def test_sharded_ingest_never_retraces_steady_state(pair, datasets):
    _, sharded = pair("blobs", fresh=True)
    x, _ = datasets["blobs"]
    for seed, n in enumerate((64, 64, 40, 64)):  # 40 pads up to 64
        sharded.ingest(_queries(x, n, seed=seed))
    st = sharded.ingest_stats()
    assert st["traces"] == 1, f"steady-state sharded ingest re-traced: {st}"
    assert st["calls"] >= 4


# ---------------------------------------------------------------------------
# maintenance: the rebuild hot-swap under sharding
# ---------------------------------------------------------------------------

def test_forced_rebuild_hot_swap_stays_bitwise(pair, datasets):
    single, sharded = pair("blobs", fresh=True)
    x, _ = datasets["blobs"]
    batch = _queries(x, 50, seed=5)
    single.ingest(batch)
    sharded.ingest(batch)
    assert single.forest.n_indexes >= 2
    triggers = [0, single.forest.n_indexes - 1]
    single._rebuild(triggers)
    sharded._rebuild(triggers)
    # survivors kept their buffers, rebuilt indexes absorbed theirs — the
    # LOGICAL delta state must agree exactly post-swap
    assert single.forest.n_indexes == sharded.forest.n_indexes
    np.testing.assert_array_equal(
        np.asarray(single.delta.count), np.asarray(sharded.delta.count)
    )
    q = _queries(x)
    for mode in ("forest", "all"):
        _assert_same_results(
            sharded.search(q, k=7, mode=mode),
            single.search(q, k=7, mode=mode),
            what=f"post-rebuild/{mode}",
        )
    # streaming continues across the swap without divergence
    more = _queries(x, 20, seed=6)
    np.testing.assert_array_equal(single.ingest(more), sharded.ingest(more))
    _assert_same_results(sharded.search(q, k=7), single.search(q, k=7))


# ---------------------------------------------------------------------------
# persistence: snapshots are layout-independent
# ---------------------------------------------------------------------------

def test_persistence_reshard_roundtrip(datasets, tmp_path):
    x, kw = datasets["blobs"]
    ix = OverlapIndex.build(x, _cfg(kw, layout=SHARDED4))
    ix.ingest(_queries(x, 30, seed=4))
    path = ix.save(tmp_path / "sharded.npz")
    q = _queries(x)
    ref = ix.search(q, k=9)

    as_saved = OverlapIndex.load(path)
    as_single = OverlapIndex.load(path, layout=LayoutConfig())
    as_two = OverlapIndex.load(path, layout=LayoutConfig(kind="sharded", shards=2))
    assert as_saved.backend.shards == 4
    assert as_single.backend.kind == "single"
    assert as_two.backend.shards == 2

    for tag, other in (("saved", as_saved), ("single", as_single), ("two", as_two)):
        res = other.search(q, k=9)
        np.testing.assert_array_equal(res.dists, ref.dists, err_msg=tag)
        np.testing.assert_array_equal(res.ids, ref.ids, err_msg=tag)
        # streamed object ids survive the save -> re-shard -> load round trip
        np.testing.assert_array_equal(
            np.asarray(other.delta.ids), np.asarray(ix.delta.ids), err_msg=tag
        )
        np.testing.assert_array_equal(
            np.asarray(other.delta.count), np.asarray(ix.delta.count), err_msg=tag
        )


# ---------------------------------------------------------------------------
# serving: the datastore rides the index's layout
# ---------------------------------------------------------------------------

def test_serving_datastore_rides_sharded_layout(pair, datasets):
    from repro.serve.retrieval import forest_knn, ingest_keys

    single, sharded = pair("blobs", fresh=True)
    x, _ = datasets["blobs"]
    vals = np.arange(single.n_total) % 97
    ds_s = single.to_datastore(vals, stream_capacity=128)
    ds_h = sharded.to_datastore(vals, stream_capacity=128)
    assert ds_h.shards == 4

    q = jnp.asarray(_queries(x, 12))
    d_s, v_s = forest_knn(q, ds_s, k=5)
    d_h, v_h = forest_knn(q, ds_h, k=5)
    np.testing.assert_array_equal(np.asarray(d_h), np.asarray(d_s))
    np.testing.assert_array_equal(np.asarray(v_h), np.asarray(v_s))

    # the engine's decode step is the compilation boundary: the island must
    # give the same answers from INSIDE an outer jit
    jit_knn = jax.jit(forest_knn, static_argnames=("k", "kernel"))
    d_hj, v_hj = jit_knn(q, ds_h, k=5)
    np.testing.assert_array_equal(np.asarray(d_hj), np.asarray(d_s))
    np.testing.assert_array_equal(np.asarray(v_hj), np.asarray(v_s))

    # serve-side streaming: same accepts, same values, same retrievals
    keys = _queries(x, 50, seed=8)
    toks = np.arange(50) % 97
    ds_s2, acc_s = ingest_keys(ds_s, jnp.asarray(keys), toks)
    ds_h2, acc_h = ingest_keys(ds_h, jnp.asarray(keys), toks)
    assert acc_s == acc_h
    assert acc_s > 0
    np.testing.assert_array_equal(
        np.asarray(ds_h2.values), np.asarray(ds_s2.values)
    )
    d_s3, v_s3 = forest_knn(q, ds_s2, k=5)
    d_h3, v_h3 = forest_knn(q, ds_h2, k=5)
    np.testing.assert_array_equal(np.asarray(d_h3), np.asarray(d_s3))
    np.testing.assert_array_equal(np.asarray(v_h3), np.asarray(v_s3))


# ---------------------------------------------------------------------------
# plan + backend plumbing
# ---------------------------------------------------------------------------

def test_plan_keys_distinguish_layouts(pair, datasets):
    single, sharded = pair("blobs")
    x, _ = datasets["blobs"]
    q = _queries(x, 4)
    rs = single.search(q, k=3)
    rh = sharded.search(q, k=3)
    assert rs.plan.key.shards == 1
    assert rh.plan.key.shards == 4
    assert rs.plan.key != rh.plan.key
    assert "shardedx4" in repr(sharded)


def test_layout_default_shards_uses_all_devices():
    backend = make_backend(LayoutConfig(kind="sharded"))
    assert backend.kind == "sharded"
    assert backend.shards == jax.device_count()


# ---------------------------------------------------------------------------
# observability under sharding: metrics gates + per-island attribution
# ---------------------------------------------------------------------------

def _obs_cfg(index_kw: dict, *, enabled=True, layout=None, **obs_kw) -> Config:
    return Config(
        index=IndexConfig(**index_kw),
        search=SearchConfig(),
        stream=StreamConfig(capacity=64),
        layout=layout or SHARDED4,
        obs=ObsConfig(enabled=enabled, **obs_kw),
    )


def test_sharded_metrics_on_off_bitwise(datasets):
    # the no-effect guarantee under the sharded layout: metrics are host-side
    # bookkeeping, so flipping the registry must not move a single bit of
    # the island executors' output — forest phase and delta phase alike
    x, kw = datasets["blobs"]
    on = OverlapIndex.build(x, _obs_cfg(kw))
    off = OverlapIndex.build(x, _obs_cfg(kw, enabled=False))
    batch = _queries(x, 40, seed=9)
    np.testing.assert_array_equal(on.ingest(batch), off.ingest(batch))
    q = _queries(x)
    for mode in ("forest", "all"):
        r_on = on.search(q, k=7, mode=mode)
        r_off = off.search(q, k=7, mode=mode)
        np.testing.assert_array_equal(r_on.dists, r_off.dists, err_msg=mode)
        np.testing.assert_array_equal(r_on.ids, r_off.ids, err_msg=mode)
    assert off.metrics()["enabled"] is False
    assert on.metrics()["search"]["queries"] == 2 * len(q)


def test_sharded_explain_and_tracing_bitwise(datasets, tmp_path):
    from repro.obs import Trace

    x, kw = datasets["blobs"]
    p = str(tmp_path / "trace.jsonl")
    plain = OverlapIndex.build(x, _obs_cfg(kw))
    traced = OverlapIndex.build(
        x, _obs_cfg(kw, trace_sample=1.0, events_path=p)
    )
    batch = _queries(x, 40, seed=9)
    plain.ingest(batch)
    traced.ingest(batch)
    q = _queries(x)
    ref = plain.search(q, k=9)
    r_tr = traced.search(q, k=9)
    np.testing.assert_array_equal(r_tr.dists, ref.dists)
    np.testing.assert_array_equal(r_tr.ids, ref.ids)
    # explain() decodes the sharded VisitRows (shard-local sorted orders +
    # per-phase counts): bitwise results AND exact visit conservation
    rep = traced.explain(q, k=9)
    np.testing.assert_array_equal(rep.result.dists, ref.dists)
    np.testing.assert_array_equal(rep.result.ids, ref.ids)
    np.testing.assert_array_equal(
        rep.contributing + rep.wasted, rep.result.stats["buckets_visited"]
    )
    # the traced search's tree carries one island point event per shard
    tids = Trace.trace_ids(p)
    assert tids
    t = Trace.reconstruct(p, tids[0])
    islands = [r for r in t.records if r.get("event") == "island"]
    assert sorted(r["island"] for r in islands) == [0, 1, 2, 3]


def test_island_counters_sum_to_fleet_totals(datasets):
    x, kw = datasets["blobs"]
    ix = OverlapIndex.build(x, _obs_cfg(kw))
    q = _queries(x)
    ix.search(q, k=5, mode="forest")
    ix.ingest(_queries(x, 40, seed=9))
    # forest mode again: delta-phase work still lands in the island rows,
    # and forest-mode routing keeps the bound_distances relation exact below
    # (mode="all" skips routing entirely)
    ix.search(q, k=9, mode="forest")
    m = ix.metrics()
    assert set(m["islands"]) == {0, 1, 2, 3}
    for name in ("buckets_visited", "distances"):
        fleet = m["search"][name]
        assert fleet > 0
        assert sum(isl[name] for isl in m["islands"].values()) == fleet, name
    # bound_distances: every shard routes the replicated queries itself, so
    # the island rows over-count routing by (S - 1) x queries x centers
    # relative to the fleet total (which counts routing once per query)
    fleet = m["search"]["bound_distances"]
    summed = sum(isl["bound_distances"] for isl in m["islands"].values())
    assert summed == fleet + (4 - 1) * m["search"]["queries"] * ix.n_indexes
