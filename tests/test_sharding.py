"""Sharding-rule tests: param/cache spec resolution, divisibility fallback,
duplicate-axis guard, local-byte accounting, MoE shard_map island (on a
small host mesh)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import context as dctx
from repro.distributed import sharding as shd
from repro.distributed.estimator import _local_bytes


@pytest.fixture(scope="module")
def mesh22():
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    # single CPU device: mesh (1,1) still exercises the rule resolution
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_rules_basic(mesh22):
    m = mesh22
    assert shd.spec_for_param("embed", (1024, 64), m) == P(None, None)  # 1-size axes
    # with axis sizes 1 everything degrades to replication; rule paths are
    # exercised against a fake big mesh below via _raw_spec
    assert shd._raw_spec("stages/0/u0/attn/wq", 4) == ["none", "fsdp", "heads", "none"]
    assert shd._raw_spec("stages/1/u0/moe/w_in", 4) == ["none", "expert", "fsdp", "none"]
    assert shd._raw_spec("opt/mu/stages/0/u0/mlp/w_out", 3) == ["none", "mlp", "fsdp"]
    # adafactor factored stats inherit parent minus reduced dim
    assert shd._raw_spec("v/stages/0/u0/mlp/w_in/vr", 2) == ["none", "fsdp"]
    assert shd._raw_spec("v/stages/0/u0/mlp/w_in/vc", 2) == ["none", "mlp"]
    assert shd._raw_spec("v/stages/0/u0/moe/w_out/vr", 3) == ["none", "expert", "none"]


def test_cache_rules():
    assert [r for r in shd._CACHE_RULES if r[0] == r"/(k|v)$"][0][1] == (
        "batch", "seq_kv", "none", "none")


def test_divisibility_fallback(mesh22):
    """Dims that don't divide the axis product degrade to replication."""
    import math

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    m = FakeMesh()
    # 9 heads on 16-way tensor axis -> None
    spec = shd.spec_for_param("stages/0/u0/attn/wq", (30, 576, 9, 64), m)
    assert spec == P(None, ("data",), None, None) or spec == P(None, "data", None, None)
    # 64 heads divide -> sharded
    spec = shd.spec_for_param("stages/0/u0/attn/wq", (30, 8192, 64, 128), m)
    assert spec[2] in ("model", ("model",))


def test_logical_constraint_dedupes_axes(mesh22):
    with dctx.use_mesh(mesh22):
        x = jnp.zeros((4, 8, 16))
        # seq and vocab both map to 'model' — must not raise
        shd.set_rule("seq", ("model",))
        try:
            out = shd.logical_constraint(x, ("batch", "seq", "vocab"))
            assert out.shape == x.shape
        finally:
            shd.set_rule("seq", ())


def test_local_bytes_accounting():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}

    from jax.sharding import NamedSharding

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"a": jax.ShapeDtypeStruct((8, 16), jnp.float32)}
    shardings = {"a": jax.NamedSharding(mesh, P("data", "model"))}
    # mesh of size 1x1: no reduction
    assert _local_bytes(tree, shardings) == 8 * 16 * 4


def test_moe_island_on_host_mesh(rng):
    """MoE under a real (1, n) mesh: shard_map path must agree with the
    single-device dense path."""
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.models import moe as moe_lib

    n = len(jax.devices())
    mesh = jax.make_mesh((1, n), ("data", "model"))
    cfg = ModelConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=64,
        moe=MoEConfig(num_experts=4 * n, top_k=2, d_ff_expert=16,
                      capacity_factor=float(2 * n)),
    )
    p = moe_lib.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
    ref, _ = moe_lib.moe_ffn(p, x, cfg)  # no mesh -> dense path
    with dctx.use_mesh(mesh):
        got, _ = moe_lib.moe_ffn(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4, rtol=1e-4)
