"""Fused bucket-scan kernel (kernels/bucket_scan.py) vs its jnp oracle.

Interpret-mode sweeps on CPU (the REPRO_FORCE_PALLAS=1 path), covering the
forest-scan edge cases: fewer than k reachable objects, duplicate
distances, D not a multiple of the tile width, beam not dividing NB — plus
the end-to-end exactness guarantee that the kernelized ``mode='all'``
search still matches brute force.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import IndexConfig, build_baseline, knn_exact, knn_search_host
from repro.core.knn import device_forest, knn_search
from repro.kernels import ref
from repro.kernels.bucket_scan import bucket_scan_topk_pallas
from repro.kernels.ops import quantize_datastore


def _problem(rng, qn, nb, cap, dim, beam, kk, *, pad_frac=0.3, seeded_topk=True):
    q = jnp.asarray(rng.normal(size=(qn, dim)), jnp.float32)
    bx = jnp.asarray(rng.normal(size=(nb, cap, dim)), jnp.float32)
    ids = jnp.asarray(
        np.arange(nb * cap, dtype=np.int32).reshape(nb, cap)
    )
    ids = jnp.where(jnp.asarray(rng.random((nb, cap)) < pad_frac), -1, ids)
    bsel = jnp.asarray(rng.integers(0, nb, size=(qn, beam)), jnp.int32)
    act = jnp.asarray(rng.random((qn, beam)) < 0.75)
    if seeded_topk:
        top_d = jnp.sort(
            jnp.asarray(rng.random((qn, kk)).astype(np.float32) * 40.0), axis=1
        )
        top_d = top_d.at[:, kk // 2 :].set(jnp.inf)
        top_i = jnp.where(
            jnp.isinf(top_d), -1,
            jnp.asarray(rng.integers(10_000, 20_000, (qn, kk)), jnp.int32),
        )
    else:
        top_d = jnp.full((qn, kk), jnp.inf)
        top_i = jnp.full((qn, kk), -1, jnp.int32)
    return q, bx, ids, bsel, act, top_d, top_i


def _check_ids_achieve_values(q, bx, ids, got_d, got_i):
    """Returned ids must achieve the returned distances (tie-tolerant)."""
    flat_x = np.asarray(bx).reshape(-1, bx.shape[-1])
    flat_ids = np.asarray(ids).reshape(-1)
    qn = q.shape[0]
    got_d = np.asarray(got_d)
    got_i = np.asarray(got_i)
    for qi in range(qn):
        for j in range(got_d.shape[1]):
            gid = got_i[qi, j]
            if gid < 0 or gid >= 10_000 or not np.isfinite(got_d[qi, j]):
                continue  # seeded/pad entries carry no coordinates
            rows = flat_x[flat_ids == gid]
            d2 = ((rows - np.asarray(q)[qi]) ** 2).sum(-1)
            assert np.any(np.abs(d2 - got_d[qi, j]) < 1e-3), (qi, j, gid)


SHAPES = [
    # (Q, NB, C, D, beam, kk) — D=6/33 exercise the lane-padding path,
    # C=5 the sublane padding, kk=7/11 the alignment tail
    (4, 7, 5, 6, 3, 4),
    (2, 9, 8, 16, 4, 7),
    (1, 3, 2, 33, 2, 5),
    (5, 6, 4, 8, 6, 11),
]


@pytest.mark.parametrize("qn,nb,cap,dim,beam,kk", SHAPES)
def test_bucket_scan_matches_ref(qn, nb, cap, dim, beam, kk, rng):
    q, bx, ids, bsel, act, top_d, top_i = _problem(rng, qn, nb, cap, dim, beam, kk)
    rd, ri = ref.bucket_scan_topk_ref(q, bx, ids, bsel, act, top_d, top_i)
    kd, ki = bucket_scan_topk_pallas(
        q, bx, ids, bsel, act, top_d, top_i, interpret=True
    )
    np.testing.assert_allclose(np.asarray(kd), np.asarray(rd), rtol=1e-5, atol=1e-5)
    _check_ids_achieve_values(q, bx, ids, kd, ki)
    # result stays sorted ascending (inf tail allowed; inf-inf diffs are nan)
    with np.errstate(invalid="ignore"):
        diffs = np.diff(np.asarray(kd), axis=1)
    assert np.all((diffs >= -1e-6) | np.isnan(diffs))


def test_bucket_scan_fewer_than_k_reachable(rng):
    """Heavily padded buckets + sparse activity: inf/-1 tail, no garbage."""
    q, bx, ids, bsel, act, top_d, top_i = _problem(
        rng, 3, 4, 3, 5, 2, 9, pad_frac=0.8, seeded_topk=False
    )
    rd, ri = ref.bucket_scan_topk_ref(q, bx, ids, bsel, act, top_d, top_i)
    kd, ki = bucket_scan_topk_pallas(
        q, bx, ids, bsel, act, top_d, top_i, interpret=True
    )
    np.testing.assert_allclose(np.asarray(kd), np.asarray(rd), rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.isinf(np.asarray(kd)), np.asarray(ki) == -1)


def test_bucket_scan_dry_pool_keeps_ids_unique(rng):
    """Partially filled top-k + a step contributing NO live candidates: the
    kernel's min-extraction must not re-emit an already-extracted id once
    the pool runs dry (regression: argmin over an all-inf row points at an
    arbitrary slot)."""
    qn, nb, cap, dim, beam, kk = 2, 3, 4, 5, 2, 5
    q = jnp.asarray(rng.normal(size=(qn, dim)), jnp.float32)
    bx = jnp.asarray(rng.normal(size=(nb, cap, dim)), jnp.float32)
    ids = jnp.full((nb, cap), -1, jnp.int32)  # every member is padding
    bsel = jnp.asarray(rng.integers(0, nb, size=(qn, beam)), jnp.int32)
    act = jnp.zeros((qn, beam), bool)  # ...and nothing is active anyway
    top_d = jnp.array([[1.0, 2.5, jnp.inf, jnp.inf, jnp.inf]] * qn, jnp.float32)
    top_i = jnp.array([[42, 7, -1, -1, -1]] * qn, jnp.int32)
    rd, ri = ref.bucket_scan_topk_ref(q, bx, ids, bsel, act, top_d, top_i)
    kd, ki = bucket_scan_topk_pallas(
        q, bx, ids, bsel, act, top_d, top_i, interpret=True
    )
    np.testing.assert_allclose(np.asarray(kd), np.asarray(rd), rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    assert np.array_equal(np.asarray(ki), np.asarray(top_i))  # unchanged


def test_bucket_scan_duplicate_distances(rng):
    """Exactly tied candidates: values must agree with the oracle even when
    tie-broken ids legitimately differ."""
    qn, nb, cap, dim, beam, kk = 3, 5, 4, 6, 3, 6
    q = jnp.asarray(rng.normal(size=(qn, dim)), jnp.float32)
    # duplicate the same member row across buckets -> equal distances
    row = rng.normal(size=(dim,)).astype(np.float32)
    bx = np.broadcast_to(row, (nb, cap, dim)).copy()
    bx[2:] = rng.normal(size=(nb - 2, cap, dim))
    bx = jnp.asarray(bx, jnp.float32)
    ids = jnp.asarray(np.arange(nb * cap, dtype=np.int32).reshape(nb, cap))
    bsel = jnp.asarray(rng.integers(0, nb, size=(qn, beam)), jnp.int32)
    act = jnp.ones((qn, beam), bool)
    top_d = jnp.full((qn, kk), jnp.inf)
    top_i = jnp.full((qn, kk), -1, jnp.int32)
    rd, _ = ref.bucket_scan_topk_ref(q, bx, ids, bsel, act, top_d, top_i)
    kd, ki = bucket_scan_topk_pallas(
        q, bx, ids, bsel, act, top_d, top_i, interpret=True
    )
    np.testing.assert_allclose(np.asarray(kd), np.asarray(rd), rtol=1e-5, atol=1e-5)
    _check_ids_achieve_values(q, bx, ids, kd, ki)


def test_bucket_scan_int8_matches_ref(rng):
    qn, nb, cap, dim, beam, kk = 4, 6, 5, 12, 3, 6
    q, bx, ids, bsel, act, top_d, top_i = _problem(rng, qn, nb, cap, dim, beam, kk)
    xq, scale = quantize_datastore(bx.reshape(nb * cap, dim))
    bxq = xq.reshape(nb, cap, dim)
    bscale = scale.reshape(nb, cap)
    rd, _ = ref.bucket_scan_topk_ref(q, bxq, ids, bsel, act, top_d, top_i, bscale)
    kd, _ = bucket_scan_topk_pallas(
        q, bxq, ids, bsel, act, top_d, top_i, bscale, interpret=True
    )
    np.testing.assert_allclose(np.asarray(kd), np.asarray(rd), rtol=1e-4, atol=1e-4)


@pytest.fixture()
def small_forest():
    g = np.random.default_rng(3)
    x = g.normal(size=(90, 5)).astype(np.float32) * 4
    forest, _ = build_baseline(x, IndexConfig(c_max=8))
    return x, forest


def _forced_pallas(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    # drop traces cached before the env flip (dispatch reads env at trace time)
    knn_search.clear_cache()


@pytest.mark.parametrize("beam", [4, 7])
def test_search_beam_not_dividing_nb(small_forest, monkeypatch, beam):
    """Forced-pallas search with beam not dividing NB == jnp-reference search."""
    x, forest = small_forest
    assert forest.n_buckets % beam != 0, "shape must exercise the pad lanes"
    g = np.random.default_rng(5)
    q = g.normal(size=(4, 5)).astype(np.float32) * 4
    d_ref, _, s_ref = knn_search_host(forest, q, k=6, mode="all", beam=beam, kernel=False)
    _forced_pallas(monkeypatch)
    try:
        d_k, _, s_k = knn_search_host(forest, q, k=6, mode="all", beam=beam, kernel=True)
    finally:
        knn_search.clear_cache()
    np.testing.assert_allclose(d_k, d_ref, rtol=1e-4, atol=1e-4)
    assert np.array_equal(s_k["buckets_visited"], s_ref["buckets_visited"])
    assert np.array_equal(s_k["distances"], s_ref["distances"])


def test_kernelized_mode_all_exact(small_forest, monkeypatch):
    """Acceptance: kernelized mode='all' still matches brute force."""
    x, forest = small_forest
    g = np.random.default_rng(11)
    q = g.normal(size=(6, 5)).astype(np.float32) * 4
    de, _ = knn_exact(jnp.asarray(x), jnp.asarray(q), k=10)
    _forced_pallas(monkeypatch)
    try:
        d, ids, _ = knn_search_host(forest, q, k=10, mode="all", kernel=True)
    finally:
        knn_search.clear_cache()
    np.testing.assert_allclose(d, np.asarray(de), rtol=1e-4, atol=1e-4)
    assert (np.asarray(ids) >= 0).all()


def test_quantized_bucket_storage_recall(small_forest):
    """int8 bucket storage (device_forest knob): near-exact neighbors."""
    x, forest = small_forest
    g = np.random.default_rng(13)
    q = g.normal(size=(8, 5)).astype(np.float32) * 4
    de, ie = knn_exact(jnp.asarray(x), jnp.asarray(q), k=5)
    df = device_forest(forest, quantize=True)
    assert df.bucket_x.dtype == jnp.int8 and df.bucket_scale is not None
    d, ids, _ = knn_search_host(forest, q, k=5, mode="all", quantize=True)
    ie = np.asarray(ie)
    recall = np.mean(
        [len(set(ids[i].tolist()) & set(ie[i].tolist())) / 5 for i in range(len(q))]
    )
    assert recall >= 0.9, recall
    np.testing.assert_allclose(d, np.asarray(de), rtol=0.05, atol=0.05)
