"""Per-architecture smoke tests (reduced same-family configs): forward +
train-step shapes, finiteness, cache consistency (prefill + decode ==
teacher-forced forward), and gradient flow."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import Model, num_params


def _batch(cfg, b, s, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    batch["targets"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)) * 0.1, jnp.float32)
    if cfg.frontend == "vision_stub":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_stub_patches, cfg.d_model)) * 0.1, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    batch = _batch(cfg, 2, 16, rng)
    logits, aux, _ = m.forward(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert num_params(params) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_grads(arch, rng):
    """One loss+grad step: finite loss, finite nonzero grads."""
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.key(1))
    batch = _batch(cfg, 2, 8, rng)
    (loss, metrics), grads = jax.value_and_grad(m.loss, has_aux=True)(params, batch)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch, rng):
    """KV-cache / state correctness: step-by-step decode must reproduce the
    teacher-forced logits.  MLA runs its absorbed decode path in f32 here
    (the bf16 delta between decompressed and absorbed orderings is
    reassociation noise, verified ~1e-6 in f32)."""
    cfg = get_smoke_config(arch).replace(compute_dtype="float32")
    m = Model(cfg)
    params = m.init(jax.random.key(2))
    b, s, t = 2, 6, 10
    batch = _batch(cfg, b, t, rng)
    full_logits, _, _ = m.forward(params, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :s]
    lg_pre, cache = m.prefill(params, pre, max_len=t)
    np.testing.assert_allclose(
        np.asarray(lg_pre[:, -1]), np.asarray(full_logits[:, s - 1]), atol=2e-3, rtol=1e-3)
    for pos in range(s, t):
        lg, cache = m.decode_step(
            params, batch["tokens"][:, pos : pos + 1], cache, jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full_logits[:, pos]), atol=2e-3, rtol=1e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full (non-smoke) configs carry the published hyperparameters."""
    cfg = get_config(arch)
    expect = {
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect
    if arch == "jamba-1.5-large-398b":
        assert cfg.moe.num_experts == 16 and cfg.moe.top_k == 2
    if arch == "deepseek-v2-236b":
        assert cfg.moe.num_experts == 160 and cfg.moe.top_k == 6
        assert cfg.mla.kv_lora_rank == 512
    if arch == "qwen3-moe-235b-a22b":
        assert cfg.moe.num_experts == 128 and cfg.moe.top_k == 8


def test_moe_capacity_drop_counts(rng):
    """Capacity factor controls dropping; generous capacity == dense math
    (validated against a per-expert dense oracle)."""
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.models import moe as moe_lib

    cfg = ModelConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=64,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=16, capacity_factor=2.0),
    )
    p = moe_lib.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 16, 16)), jnp.float32)
    out, _ = moe_lib.moe_ffn(p, x, cfg)
    x2d = x.reshape(-1, 16)
    logits = x2d @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    tp_, te_ = jax.lax.top_k(probs, 2)
    tp_ = tp_ / tp_.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x2d)
    for e in range(4):
        h = jax.nn.silu(x2d @ p["w_gate"][e]) * (x2d @ p["w_in"][e])
        y = h @ p["w_out"][e]
        ref += y * ((te_ == e) * tp_).sum(-1)[:, None]
    np.testing.assert_allclose(
        np.asarray(out.reshape(-1, 16)), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_rwkv_long_context_state_is_constant_memory(rng):
    """SSM family: decode state size is independent of context length."""
    from repro.models.rwkv import init_rwkv_state

    cfg = get_smoke_config("rwkv6-3b")
    s1 = init_rwkv_state(cfg, 2, jnp.float32)
    total = sum(x.size for x in jax.tree.leaves(s1))
    # no dependence on any sequence length parameter at all
    assert total == 2 * (cfg.d_model + cfg.d_model // cfg.rwkv.head_dim
                         * cfg.rwkv.head_dim**2 + cfg.d_model)
