"""Substrate tests: optimizer, schedule, checkpointing (incl. corruption
fault tolerance), data pipeline determinism, elastic re-mesh planning,
trainer resume, sharding rules."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # optional dep: degrade to seeded sampling
    from repro.testing.hypothesis_fallback import given, settings
    from repro.testing.hypothesis_fallback import strategies as st

from repro.checkpoint.checkpointing import (
    restore_latest,
    save_checkpoint,
)
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed.elastic import plan_mesh, rescale_batch
from repro.optim.optimizer import adafactor, adamw, clip_by_global_norm
from repro.optim.schedule import cosine_with_warmup


# ---------------------------------------------------------------- optimizer
def _quadratic_params():
    return {"w": jnp.array([3.0, -2.0, 1.0]), "b": jnp.array([[1.0, -1.0]] * 2)}


@pytest.mark.parametrize("opt_fn", [adamw, adafactor])
def test_optimizer_descends_quadratic(opt_fn):
    opt = opt_fn(weight_decay=0.0)
    params = _quadratic_params()
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum(x**2) for x in jax.tree.leaves(p))

    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params, jnp.float32(0.05))
    assert float(loss(params)) < 0.2 * l0


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"big": jnp.zeros((64, 32)), "vec": jnp.zeros((7,))}
    state = opt.init(params)
    assert set(state["v"]["big"]) == {"vr", "vc"}
    assert state["v"]["big"]["vr"].shape == (64,)
    assert state["v"]["big"]["vc"].shape == (32,)
    assert state["v"]["vec"]["v"].shape == (7,)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(norm), 20.0)
    got = np.sqrt(np.sum(np.square(np.asarray(clipped["a"]))))
    assert np.isclose(got, 1.0, rtol=1e-5)


def test_schedule_shape():
    lr = cosine_with_warmup(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert np.isclose(float(lr(10)), 1e-3)
    assert float(lr(100)) < float(lr(50)) < float(lr(10)) + 1e-9
    assert float(lr(100)) >= 1e-4 - 1e-9  # min_ratio floor


# -------------------------------------------------------------- checkpoints
def test_checkpoint_roundtrip_and_retention(tmp_path):
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "nested": {"b": np.ones(5), "step": np.int32(7)}}
    for step in (10, 20, 30, 40):
        save_checkpoint(tmp_path, step, tree, keep=2)
    kept = sorted(p.name for p in tmp_path.iterdir() if p.is_dir())
    assert kept == ["step_00000030", "step_00000040"]
    restored, step = restore_latest(tmp_path, tree)
    assert step == 40
    np.testing.assert_array_equal(restored["w"], tree["w"])
    np.testing.assert_array_equal(restored["nested"]["b"], tree["nested"]["b"])


def test_checkpoint_corruption_falls_back(tmp_path):
    tree = {"w": np.arange(6, dtype=np.float32)}
    save_checkpoint(tmp_path, 1, tree, keep=5)
    save_checkpoint(tmp_path, 2, {"w": tree["w"] * 2}, keep=5)
    # corrupt the newest checkpoint
    latest = tmp_path / "step_00000002"
    payload = next(latest.glob("*.npy"))
    payload.write_bytes(b"garbage")
    restored, step = restore_latest(tmp_path, tree)
    assert step == 1  # fell back past the corrupted one
    np.testing.assert_array_equal(restored["w"], tree["w"])


def test_checkpoint_empty_dir(tmp_path):
    restored, step = restore_latest(tmp_path / "nope", {"w": np.ones(2)})
    assert restored is None and step == -1


# ------------------------------------------------------------ data pipeline
def test_pipeline_deterministic_and_host_sharded():
    cfg = DataConfig(seq_len=16, global_batch=8, vocab_size=1000)
    p0 = TokenPipeline(cfg, host_id=0, n_hosts=2)
    p1 = TokenPipeline(cfg, host_id=1, n_hosts=2)
    a = p0.batch_at(5)
    b = p0.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # restart-safe
    c = p1.batch_at(5)
    assert not np.array_equal(a["tokens"], c["tokens"])  # disjoint hosts
    assert a["tokens"].shape == (4, 16)
    # targets are inputs shifted by one position in the stream
    assert (a["tokens"][:, 1:] == a["targets"][:, :-1]).all()


# ------------------------------------------------------------------ elastic
@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 4096))
def test_plan_mesh_covers_all_devices(n):
    plan = plan_mesh(n)
    total = 1
    for s in plan.shape:
        total *= s
    assert total == n
    if "model" in plan.axes:
        assert plan.shape[plan.axes.index("model")] <= 16


def test_rescale_batch():
    assert rescale_batch(256, 256, 128) == 128
    assert rescale_batch(256, 256, 512) == 512


# ------------------------------------------------------------------ trainer
def test_trainer_resumes_after_interrupt(tmp_path):
    """Train 30 steps with ckpt_every=10, kill at 20, resume to 30 — the
    fault-tolerance contract."""
    from repro.configs import get_smoke_config
    from repro.models.model import Model
    from repro.optim.optimizer import get_optimizer
    from repro.optim.schedule import cosine_with_warmup
    from repro.train.train_step import init_train_state, make_train_step
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_smoke_config("smollm-135m")
    model = Model(cfg)
    opt = get_optimizer(cfg.optimizer)
    step_fn = jax.jit(make_train_step(model, opt, cosine_with_warmup(1e-3, 5, 30)))
    pipeline = TokenPipeline(DataConfig(seq_len=16, global_batch=4,
                                        vocab_size=cfg.vocab_size))
    state = init_train_state(model, opt, jax.random.key(0))

    t1 = Trainer(step_fn, pipeline, TrainerConfig(
        total_steps=20, ckpt_every=10, ckpt_dir=str(tmp_path), log_every=100))
    state, rep1 = t1.run(state)
    assert rep1.resumed_from == -1

    # "restart": fresh state object, must resume from step 20 checkpoint
    state2 = init_train_state(model, opt, jax.random.key(1))
    t2 = Trainer(step_fn, pipeline, TrainerConfig(
        total_steps=30, ckpt_every=10, ckpt_dir=str(tmp_path), log_every=100))
    state2, rep2 = t2.run(state2)
    assert rep2.resumed_from == 20
    assert int(np.asarray(state2["step"])) == 30
    report = json.loads(Path(tmp_path, "trainer_report.json").read_text())
    assert report["restores"] >= 1
