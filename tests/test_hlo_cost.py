"""HLO cost analyzer tests: exact flops through scans, nested loops,
trip-count extraction, collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.hlo_cost import analyze_module


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_exact():
    def f(x, w):
        def body(x, wi):
            return x @ wi, None
        x, _ = jax.lax.scan(body, x, w)
        return x

    txt = _compile_text(
        f, jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((17, 32, 32), jnp.float32))
    mc = analyze_module(txt)
    assert mc.flops == 17 * 2 * 32**3
    assert 17.0 in mc.trip_counts.values()


def test_nested_scan_flops_exact():
    def f(x, w):
        def outer(x, _):
            def body(x, wi):
                return x @ wi, None
            x, _ = jax.lax.scan(body, x, w)
            return x, None
        x, _ = jax.lax.scan(outer, x, None, length=5)
        return x

    txt = _compile_text(
        f, jax.ShapeDtypeStruct((16, 16), jnp.float32),
        jax.ShapeDtypeStruct((9, 16, 16), jnp.float32))
    mc = analyze_module(txt)
    assert mc.flops == 5 * 9 * 2 * 16**3


def test_unrolled_flops_exact():
    def f(x, a, b):
        return (x @ a) @ b

    txt = _compile_text(
        f, jax.ShapeDtypeStruct((8, 24), jnp.float32),
        jax.ShapeDtypeStruct((24, 40), jnp.float32),
        jax.ShapeDtypeStruct((40, 8), jnp.float32))
    mc = analyze_module(txt)
    assert mc.flops == 2 * 8 * 24 * 40 + 2 * 8 * 40 * 8


def test_bytes_positive_and_bounded():
    def f(x):
        return jnp.tanh(x) * 2.0

    txt = _compile_text(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    mc = analyze_module(txt)
    nbytes = 128 * 128 * 4
    assert nbytes <= mc.bytes <= 6 * nbytes  # in + out (+ copy slack)


def test_grad_of_scan_counts_bwd_flops():
    """Backward flops must exceed forward flops (2x dots + remat)."""
    w = jax.ShapeDtypeStruct((6, 16, 16), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 16), jnp.float32)

    def fwd(x, w):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        x, _ = jax.lax.scan(jax.checkpoint(body), x, w)
        return jnp.sum(x)

    fwd_txt = _compile_text(fwd, x, w)
    grad_txt = _compile_text(jax.grad(fwd, argnums=1), x, w)
    f1 = analyze_module(fwd_txt).flops
    f2 = analyze_module(grad_txt).flops
    assert f2 >= 2.5 * f1
