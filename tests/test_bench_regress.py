"""Rolling-median bench regression gate tests (benchmarks/check_regress.py
+ the history substrate in benchmarks/common.py): stable history passes, a
single noisy spike passes, a SUSTAINED 2x regression fails, short history
is warn-only, seeding + --update materialize correctly, and the JSONL
round-trip preserves series order."""
import json
import sys

import pytest

sys.path.insert(0, ".")  # benchmarks/ is a top-level package, not under src

from benchmarks.check_regress import (  # noqa: E402
    INSUFFICIENT,
    OK,
    REGRESSED,
    check_series,
    main,
    run_check,
)
from benchmarks.common import (  # noqa: E402
    append_history,
    history_entries,
    history_series,
    load_history,
    rolling_median,
)


def _payload(us: float, *, t: float = 1.0) -> dict:
    """A minimal BENCH_search artifact: one (dataset, method) series, a
    k sweep of two records around ``us``."""
    return {
        "bench": "search",
        "meta": {"unix_time": t},
        "records": [
            {"name": "a", "dataset": "Tracking", "method": "vbm",
             "k": 5, "us_per_query": us * 0.9},
            {"name": "b", "dataset": "Tracking", "method": "vbm",
             "k": 20, "us_per_query": us * 1.1},
            {"name": "plans", "dataset": "Tracking"},  # no us -> ignored
        ],
    }


# ---------------------------------------------------------------------------
# history substrate
# ---------------------------------------------------------------------------


def test_history_entries_median_over_k_sweep():
    (e,) = history_entries(_payload(50.0, t=7.0))
    assert e["dataset"] == "Tracking" and e["method"] == "vbm"
    assert e["us_per_query"] == pytest.approx(50.0)  # median of 45, 55
    assert e["n_points"] == 2 and e["t"] == 7.0


def test_history_entries_namespace_sharded_runs():
    # a tier-2 sharded run must land in its own series — same dataset and
    # method, but suffixed so it can't corrupt the single-device medians
    p = _payload(50.0)
    for r in p["records"]:
        r["shards"] = 4
    (e,) = history_entries(p)
    assert e["method"] == "vbm/s4"
    mixed = _payload(50.0)
    mixed["records"] += [dict(r, shards=4) for r in mixed["records"][:2]]
    entries = history_entries(mixed)
    assert sorted(e["method"] for e in entries) == ["vbm", "vbm/s4"]


def test_history_jsonl_roundtrip(tmp_path):
    p = str(tmp_path / "h.jsonl")
    assert load_history(p) == []  # missing file is empty history
    append_history(p, history_entries(_payload(10.0)))
    append_history(p, history_entries(_payload(20.0)))
    series = history_series(load_history(p))
    assert series[("Tracking", "vbm")] == pytest.approx([10.0, 20.0])


def test_rolling_median_window():
    assert rolling_median([1, 2, 3, 100, 100, 100], 3) == 100.0
    assert rolling_median([1, 2, 3], 10) == 2.0


# ---------------------------------------------------------------------------
# verdict logic (check_series): last element is the run under test
# ---------------------------------------------------------------------------

STABLE = [50.0] * 12


def test_stable_series_ok():
    status, d = check_series(STABLE + [50.0], window=5, threshold=1.5,
                             min_runs=10)
    assert status == OK and d["ratio"] == pytest.approx(1.0)


def test_single_spike_does_not_trip():
    # one 10x-slow run cannot move a 5-run window median
    status, _ = check_series(STABLE + [500.0], window=5, threshold=1.5,
                             min_runs=10)
    assert status == OK


def test_sustained_regression_trips():
    status, d = check_series(STABLE + [100.0] * 5, window=5, threshold=1.5,
                             min_runs=10)
    assert status == REGRESSED and d["ratio"] == pytest.approx(2.0)


def test_short_history_is_warn_only():
    status, d = check_series([50.0] * 4, window=5, threshold=1.5, min_runs=10)
    assert status == INSUFFICIENT
    assert d["runs"] == 4 and d["min_runs"] == 10
    # even a huge value cannot fail below min_runs
    status, _ = check_series([50.0] * 3 + [5000.0], window=5, threshold=1.5,
                             min_runs=10)
    assert status == INSUFFICIENT


def test_window_worth_of_runs_but_no_baseline_is_insufficient():
    # min_runs satisfied but nothing OLDER than the window to compare to
    status, _ = check_series([50.0] * 5, window=5, threshold=1.5, min_runs=5)
    assert status == INSUFFICIENT


# ---------------------------------------------------------------------------
# run_check end to end (CLI semantics)
# ---------------------------------------------------------------------------


def _write(path, payload):
    with open(path, "w") as f:
        json.dump(payload, f)


def test_gate_passes_then_fails_on_sustained_2x(tmp_path):
    art = str(tmp_path / "BENCH_search.json")
    hist = str(tmp_path / "hist.jsonl")
    for _ in range(12):
        append_history(hist, history_entries(_payload(50.0)))
    _write(art, _payload(50.0))
    assert run_check(art, hist, window=5, gate=True) == 0

    # five sustained 2x runs in history + a 2x run under test -> fail
    for _ in range(5):
        append_history(hist, history_entries(_payload(100.0)))
    _write(art, _payload(100.0))
    assert run_check(art, hist, window=5, gate=True) == 1
    # same regression without --gate only warns
    assert run_check(art, hist, window=5, gate=False) == 0


def test_seed_bootstraps_empty_history_warn_only(tmp_path):
    art = str(tmp_path / "BENCH_search.json")
    hist = str(tmp_path / "hist.jsonl")  # does not exist
    seed = str(tmp_path / "seed.jsonl")
    append_history(seed, history_entries(_payload(50.0)))
    # a 100x-slow run against a 1-entry seeded history must be warn-only
    _write(art, _payload(5000.0))
    assert run_check(art, hist, seed_path=seed, window=5, gate=True,
                     update=True) == 0
    # --update materialized the seed + this run into the real history
    series = history_series(load_history(hist))
    assert series[("Tracking", "vbm")] == pytest.approx([50.0, 5000.0])


def test_update_appends_run_under_test(tmp_path):
    art = str(tmp_path / "BENCH_search.json")
    hist = str(tmp_path / "hist.jsonl")
    _write(art, _payload(50.0))
    assert run_check(art, hist, update=True) == 0
    assert run_check(art, hist, update=True) == 0
    assert len(load_history(hist)) == 2


def test_new_series_in_old_history_is_independent(tmp_path):
    # an unrelated (dataset, method) history must not gate a new series
    art = str(tmp_path / "BENCH_search.json")
    hist = str(tmp_path / "hist.jsonl")
    for _ in range(12):
        append_history(hist, [dict(t=1.0, bench="search", dataset="WARD",
                                   method="dbm", us_per_query=1.0,
                                   n_points=2)])
    _write(art, _payload(5000.0))  # Tracking/vbm: no history of its own
    assert run_check(art, hist, window=5, gate=True) == 0


def test_cli_main(tmp_path, capsys):
    art = str(tmp_path / "BENCH_search.json")
    hist = str(tmp_path / "hist.jsonl")
    _write(art, _payload(50.0))
    rc = main(["--artifact", art, "--history", hist, "--update", "--gate"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "warn-only" in out and "appended" in out


def test_empty_artifact_is_noop(tmp_path):
    art = str(tmp_path / "BENCH_search.json")
    _write(art, {"bench": "search", "meta": {}, "records": []})
    assert run_check(art, str(tmp_path / "h.jsonl"), gate=True) == 0
