"""Serving-front traffic semantics: deadlines, admission control, load
shedding, counter conservation, and query/ingest fairness.

Companion to tests/test_retrieval_serving.py (which pins the decode/
retrieval correctness of the same engine); this module pins the TRAFFIC
behavior the production front added: every request reaches exactly one
terminal state (done XOR shed), the shed counters conserve against
submissions, and a saturating write stream cannot starve reads.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.synthetic import embedding_datastore
from repro.models.model import Model
from repro.serve.engine import (
    SHED_EARLY,
    SHED_EXPIRED_FLIGHT,
    SHED_EXPIRED_QUEUE,
    SHED_REJECTED,
    IngestRequest,
    Request,
    ServeEngine,
)


@pytest.fixture(scope="module")
def lm():
    cfg = get_smoke_config("smollm-135m")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _req(cfg, rid, *, tokens=4, deadline=None, seed=0):
    g = np.random.default_rng(seed + rid)
    return Request(
        rid=rid, prompt=g.integers(0, cfg.vocab_size, 5).astype(np.int32),
        max_new_tokens=tokens, deadline_s=deadline,
    )


def _shed_total(reg):
    return sum(
        reg.value("serve.shed", reason=r)
        for r in (
            SHED_REJECTED, SHED_EXPIRED_QUEUE, SHED_EXPIRED_FLIGHT, SHED_EARLY,
        )
    )


def _assert_conserved(engine):
    """submitted == completed + shed + in-flight, at any step boundary."""
    reg = engine.obs
    in_flight = len(engine.queue) + sum(
        1 for r in engine.slot_req if r is not None
    )
    assert reg.value("serve.submitted") == (
        reg.value("serve.completed") + _shed_total(reg) + in_flight
    )


def test_reject_on_submit_accounting(lm):
    """Admission control sheds at submit() when the projected queue wait
    already exceeds the deadline; the request never enters the queue and
    the books still balance."""
    cfg, model, params = lm
    # a deliberately absurd service-time hint: ANY queued work projects a
    # wait of >= hint/num_slots seconds, so the second submit must bounce
    engine = ServeEngine(model, params, num_slots=1, max_len=24,
                         step_time_hint_s=10.0)
    a = _req(cfg, 0, tokens=3)  # no deadline: never rejected
    b = _req(cfg, 1, tokens=3, deadline=0.5)
    assert engine.submit(a) is True
    assert engine.submit(b) is False  # projected 30s >> 0.5s budget
    assert b.shed and b.shed_reason == SHED_REJECTED and b.state == "shed"
    assert not b.done and b.out_tokens == []
    assert engine.obs.value("serve.submitted") == 2
    assert engine.obs.value("serve.shed", reason=SHED_REJECTED) == 1
    _assert_conserved(engine)

    finished = engine.run()
    # the rejected request is NOT re-surfaced by run(); the submitter holds it
    assert finished == [a] and a.done and len(a.out_tokens) >= 3
    assert engine.obs.value("serve.completed") == 1
    _assert_conserved(engine)
    # projected-wait gauge was published for the deadline submit
    assert engine.metrics()["gauges"]["serve.projected_wait_s"] > 0.5


def test_deadline_expires_while_queued(lm):
    """A queued request whose budget lapses is shed before it ever reaches
    prefill — zero tokens were generated for it."""
    cfg, model, params = lm
    engine = ServeEngine(model, params, num_slots=1, max_len=24)
    a = _req(cfg, 0, tokens=4)
    b = _req(cfg, 1, tokens=4, deadline=1e-3)  # cold engine admits it
    assert engine.submit(a) and engine.submit(b)
    time.sleep(5e-3)  # budget lapses while b still waits behind a
    finished = engine.run()
    assert set(map(id, finished)) == {id(a), id(b)}
    assert a.done and not a.shed
    assert b.shed and b.shed_reason == SHED_EXPIRED_QUEUE
    assert b.out_tokens == []  # never prefillled, never decoded
    assert b.latency_s >= 1e-3
    assert engine.obs.value("serve.shed", reason=SHED_EXPIRED_QUEUE) == 1
    _assert_conserved(engine)


def test_deadline_expires_mid_flight(lm):
    """A decoding request whose budget lapses is evicted from its slot:
    partial output is kept, the slot frees for other work, and the shed is
    counted under a mid-flight reason.  With the warmed engine's step-time
    estimate, the speculative pass usually sheds it as ``"early"`` before
    the clock even reaches the deadline; if a slow step lets the deadline
    lapse first, the classic ``"expired_flight"`` reason wins — either way
    it is exactly one mid-flight shed."""
    cfg, model, params = lm
    engine = ServeEngine(model, params, num_slots=1, max_len=128)
    engine.submit(_req(cfg, 99, tokens=2))  # warm: compile prefill + decode
    engine.run()
    r = _req(cfg, 0, tokens=10_000, deadline=0.05)  # cannot finish in budget
    assert engine.submit(r) is True  # idle engine: projected wait 0
    finished = engine.run()
    assert finished == [r]
    assert r.shed and r.shed_reason in (SHED_EXPIRED_FLIGHT, SHED_EARLY)
    assert not r.done
    assert len(r.out_tokens) >= 1  # prefill's first token at minimum
    assert len(r.out_tokens) < 10_000
    assert all(s is None for s in engine.slot_req)  # slot actually freed
    assert engine.obs.value("serve.shed", reason=r.shed_reason) == 1
    _assert_conserved(engine)


def test_speculative_early_expiry(lm):
    """A request whose remaining tokens x measured step time overrun the
    deadline is shed ``"early"`` — long BEFORE the deadline itself lapses.
    The absurd step-time hint makes the projection deterministic: two real
    decode steps cannot drag the median low enough for 40+ owed tokens to
    fit a 5-second budget, yet the wall clock stays far from the deadline."""
    cfg, model, params = lm
    engine = ServeEngine(model, params, num_slots=1, max_len=64,
                         step_time_hint_s=10.0)
    r = _req(cfg, 0, tokens=50, deadline=5.0)
    t0 = time.perf_counter()
    assert engine.submit(r) is True  # empty queue: projected wait 0
    finished = engine.run()
    assert finished == [r]
    assert r.shed and r.shed_reason == SHED_EARLY and r.state == "shed"
    assert not r.done
    assert time.perf_counter() - t0 < 5.0  # shed before the deadline lapsed
    assert len(r.out_tokens) < 50
    assert all(s is None for s in engine.slot_req)  # slot freed
    assert engine.obs.value("serve.shed", reason=SHED_EARLY) == 1
    _assert_conserved(engine)


def test_conservation_holds_mid_run(lm):
    """submitted == completed + shed + in_flight at every step boundary,
    not just at drain — exercised via the public step() API."""
    cfg, model, params = lm
    engine = ServeEngine(model, params, num_slots=1, max_len=24)
    reqs = [_req(cfg, i, tokens=3) for i in range(3)]
    for r in reqs:
        engine.submit(r)
    _assert_conserved(engine)  # 3 submitted, 3 queued
    seen = []
    while engine.busy:
        seen.extend(engine.step())
        _assert_conserved(engine)
    assert engine.obs.value("serve.completed") == 3
    assert _shed_total(engine.obs) == 0
    assert [r.rid for r in seen] == [0, 1, 2]  # FCFS through one slot


def test_shed_requests_stay_out_of_latency_percentiles(lm):
    """serve.request_latency_s sees COMPLETED requests only; shed waits go
    to serve.shed_wait_s — percentiles of admitted traffic must not be
    polluted by rejections."""
    cfg, model, params = lm
    engine = ServeEngine(model, params, num_slots=1, max_len=24,
                         step_time_hint_s=10.0)
    engine.submit(_req(cfg, 0, tokens=3))
    engine.submit(_req(cfg, 1, tokens=3, deadline=0.1))  # rejected
    engine.run()
    hists = engine.metrics()["histograms"]
    assert hists["serve.request_latency_s"]["count"] == 1
    assert hists["serve.shed_wait_s"]["count"] == 1


def test_ingest_drain_is_bounded_and_fair(lm):
    """A saturating ingest backlog must not starve queued queries: at most
    max_ingest_per_step batches apply per scheduler step, the deferral is
    observable, and the decode request completes BEFORE the write backlog
    finishes draining."""
    cfg, model, params = lm
    from repro.serve.retrieval import build_forest_datastore

    keys, values = embedding_datastore(256, cfg.d_model, seed=5)
    ds = build_forest_datastore(keys, values % cfg.vocab_size,
                                stream_capacity=128)
    engine = ServeEngine(model, params, num_slots=1, max_len=24,
                         datastore=ds, max_ingest_per_step=1)
    new_keys = (-keys[:24] + 40.0).astype(np.float32)
    for i in range(12):
        engine.submit(IngestRequest(
            rid=100 + i, keys=new_keys[i * 2:(i + 1) * 2],
            values=np.full(2, 9, np.int32)))
    q = _req(cfg, 0, tokens=4)
    engine.submit(q)
    finished = engine.run()
    ingests = [r for r in finished if isinstance(r, IngestRequest)]
    assert len(ingests) == 12 and all(r.done for r in ingests)
    assert q.done
    # fairness: the query retired before the last ingest ack (the unbounded
    # drain would have applied all 12 writes before the first decode step)
    assert finished.index(q) < finished.index(ingests[-1])
    assert engine.obs.value("serve.ingest_deferred") >= 3
    assert sum(r.accepted for r in ingests) == 24
    _assert_conserved(engine)


def test_max_ingest_per_step_validated(lm):
    cfg, model, params = lm
    with pytest.raises(ValueError, match="max_ingest_per_step"):
        ServeEngine(model, params, max_ingest_per_step=0)


def test_no_deadline_requests_never_shed(lm):
    """deadline_s=None keeps the pre-deadline contract: always admitted,
    never expired, regardless of how slow the engine thinks it is."""
    cfg, model, params = lm
    engine = ServeEngine(model, params, num_slots=1, max_len=24,
                         step_time_hint_s=100.0)
    reqs = [_req(cfg, i, tokens=2) for i in range(3)]
    assert all(engine.submit(r) for r in reqs)
    finished = engine.run()
    assert len(finished) == 3 and all(r.done and not r.shed for r in reqs)
    assert _shed_total(engine.obs) == 0


def test_step_time_estimate_updates_from_measurement(lm):
    """The admission model is measured, not configured: after real decode
    steps the estimate reflects the hardware, so a stale hint cannot shed
    forever."""
    cfg, model, params = lm
    engine = ServeEngine(model, params, num_slots=1, max_len=24,
                         step_time_hint_s=50.0)
    assert engine.step_time_s() == 50.0
    engine.submit(_req(cfg, 0, tokens=6))
    engine.run()
    assert engine.step_time_s() < 50.0  # medians over measured steps now
