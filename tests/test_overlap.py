"""Unit + property tests for the overlap heuristics (paper Defs. 7-11)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # optional dep: degrade to seeded sampling
    from repro.testing.hypothesis_fallback import given, settings
    from repro.testing.hypothesis_fallback import strategies as st

from repro.core import overlap as ovl

finite_radii = st.floats(0.05, 50.0, allow_nan=False, allow_infinity=False)
finite_d = st.floats(0.0, 120.0, allow_nan=False, allow_infinity=False)


def test_ball_volume_known_values():
    # V(n=2, r=1) = pi; V(n=3, r=1) = 4/3 pi; V(n=3, r=2) = 32/3 pi
    assert np.isclose(np.exp(ovl.ball_log_volume(2, jnp.float32(1.0))), np.pi, rtol=1e-5)
    assert np.isclose(np.exp(ovl.ball_log_volume(3, jnp.float32(1.0))), 4 / 3 * np.pi, rtol=1e-5)
    assert np.isclose(np.exp(ovl.ball_log_volume(3, jnp.float32(2.0))), 32 / 3 * np.pi, rtol=1e-5)


def test_cap_half_ball():
    # theta = pi/2 (cos = 0): cap is exactly half the ball.
    for n in (2, 3, 7, 20):
        v = np.exp(ovl.cap_log_volume(n, jnp.float32(1.0), jnp.float32(0.0)))
        half = 0.5 * np.exp(ovl.ball_log_volume(n, jnp.float32(1.0)))
        assert np.isclose(v, half, rtol=1e-4), n


@pytest.mark.parametrize("n_dim", [2, 3, 5])
def test_lens_volume_monte_carlo(n_dim):
    # own deterministic stream: the shared fixture's state depends on test
    # ordering, and in 5 dims the lens is a tiny fraction of the box
    rng = np.random.default_rng(42 + n_dim)
    r1, r2, d = 1.0, 0.8, 1.1
    lo, hi = -1.2, 2.0
    pts = rng.uniform(lo, hi, size=(800_000, n_dim))
    in1 = (pts**2).sum(1) <= r1**2
    c2 = np.zeros(n_dim)
    c2[0] = d
    in2 = ((pts - c2) ** 2).sum(1) <= r2**2
    mc = (in1 & in2).mean() * (hi - lo) ** n_dim
    closed = float(
        jnp.exp(ovl.intersection_log_volume(n_dim, jnp.float32(r1), jnp.float32(r2), jnp.float32(d)))
    )
    assert np.isclose(mc, closed, rtol=0.08), (mc, closed)


def test_dbm_partial_closed_form():
    # partial case: h1 + h2 == r1 + r2 - d  =>  D = (r1 + r2 - d) / d
    r1, r2, d = 2.0, 1.5, 3.0
    got = float(ovl.dbm_rate(jnp.float32(r1), jnp.float32(r2), jnp.float32(d)))
    assert np.isclose(got, (r1 + r2 - d) / d, rtol=1e-5)


@settings(max_examples=200, deadline=None)
@given(r1=finite_radii, r2=finite_radii, d=finite_d)
def test_rates_bounded_and_cases(r1, r2, d):
    """Property (Defs. 7/10): rates live in [0,1]; degenerate cases exact."""
    for fn in (lambda: ovl.vbm_rate(jnp.float32(r1), jnp.float32(r2), jnp.float32(d), 8),
               lambda: ovl.dbm_rate(jnp.float32(r1), jnp.float32(r2), jnp.float32(d))):
        rate = float(fn())
        assert 0.0 <= rate <= 1.0 + 1e-6
        if d >= r1 + r2:
            assert rate == 0.0
        elif d <= abs(r1 - r2):
            assert rate == 1.0


@settings(max_examples=100, deadline=None)
@given(r1=finite_radii, r2=finite_radii, d=finite_d)
def test_vbm_symmetry(r1, r2, d):
    a = float(ovl.vbm_rate(jnp.float32(r1), jnp.float32(r2), jnp.float32(d), 6))
    b = float(ovl.vbm_rate(jnp.float32(r2), jnp.float32(r1), jnp.float32(d), 6))
    assert np.isclose(a, b, atol=1e-5)


def test_vbm_monotone_in_distance():
    """Pulling two fixed balls apart can only shrink the volume rate."""
    r1 = jnp.float32(1.0)
    r2 = jnp.float32(0.7)
    ds = jnp.linspace(0.0, 2.0, 50)
    rates = np.array([float(ovl.vbm_rate(r1, r2, d, 8)) for d in ds])
    assert np.all(np.diff(rates) <= 1e-5)


def test_obm_rate_counts():
    got = float(ovl.obm_rate(jnp.float32(6), jnp.float32(10), jnp.float32(14),
                             jnp.float32(1.0), jnp.float32(1.0), jnp.float32(1.5)))
    assert np.isclose(got, 6 / 24)


def test_overlap_matrix_methods(blob_data):
    x = blob_data[:500]
    pivots = jnp.asarray(np.stack([x[:250].mean(0), x[250:].mean(0)]))
    radii = jnp.asarray(
        np.array(
            [np.linalg.norm(x[:250] - np.asarray(pivots)[0], axis=1).max(),
             np.linalg.norm(x[250:] - np.asarray(pivots)[1], axis=1).max()],
            np.float32,
        )
    )
    assign = jnp.asarray(np.repeat([0, 1], 250).astype(np.int32))
    for method in ("vbm", "dbm", "obm"):
        m = ovl.overlap_matrix(method, pivots, radii, x=jnp.asarray(x), assign=assign)
        m = np.asarray(m)
        assert m.shape == (2, 2)
        assert np.allclose(np.diag(m), 0.0)
        assert np.allclose(m, m.T, atol=1e-5)
        assert (m >= 0).all() and (m <= 1 + 1e-6).all()
