"""Retrieval layer (kNN-LM) + serving engine tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import RetrievalConfig
from repro.data.synthetic import embedding_datastore
from repro.models.model import Model
from repro.serve.engine import IngestRequest, Request, ServeEngine
from repro.serve.retrieval import (
    build_flat_datastore,
    build_forest_datastore,
    knn_interpolate,
    knn_logits,
)


@pytest.fixture(scope="module")
def retrieval_cfg():
    return get_smoke_config("qwen2-0.5b").replace(
        retrieval=RetrievalConfig(enabled=True, k=4, lam=0.5,
                                  temperature=1.0, datastore_size=512))


def test_knn_logits_distribution(retrieval_cfg, rng):
    cfg = retrieval_cfg
    keys, values = embedding_datastore(512, cfg.d_model, seed=0)
    values = values % cfg.vocab_size
    ds = build_flat_datastore(keys, values)
    hidden = jnp.asarray(keys[:6] + 0.01 * rng.normal(size=(6, cfg.d_model)),
                         jnp.float32)
    p = knn_logits(hidden, ds, cfg)
    assert p.shape == (6, cfg.padded_vocab)
    np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, rtol=1e-4)
    # query sitting on a datastore key must put most mass on its token
    top = np.asarray(jnp.argmax(p, axis=-1))
    assert (top == np.asarray(values[:6])).mean() >= 0.5


def test_knn_interpolate_mixes(retrieval_cfg):
    cfg = retrieval_cfg
    rng = np.random.default_rng(11)  # order-independent stream
    keys, values = embedding_datastore(256, cfg.d_model, seed=1)
    values = values % cfg.vocab_size
    ds = build_flat_datastore(keys, values)
    logits = jnp.asarray(rng.normal(size=(3, cfg.padded_vocab)), jnp.float32)
    hidden = jnp.asarray(keys[:3], jnp.float32)
    out = knn_interpolate(logits, hidden, ds, cfg)
    assert out.shape == logits.shape
    p = np.exp(np.asarray(out))
    np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-3)
    # lam=0 must reduce to the LM distribution
    cfg0 = cfg.replace(retrieval=cfg.retrieval.__class__(
        enabled=True, k=4, lam=0.0, temperature=1.0, datastore_size=512))
    out0 = knn_interpolate(logits, hidden, ds, cfg0)
    np.testing.assert_allclose(  # one f32 ulp at |logit|~8 is ~1e-6
        np.asarray(jax.nn.log_softmax(logits)), np.asarray(out0), atol=5e-6)


def test_quantized_datastore_agrees(rng):
    cfg = get_smoke_config("qwen2-0.5b").replace(
        retrieval=RetrievalConfig(enabled=True, k=4, datastore_size=512))
    keys, values = embedding_datastore(512, cfg.d_model, seed=2)
    values = values % cfg.vocab_size
    ds32 = build_flat_datastore(keys, values)
    ds8 = build_flat_datastore(keys, values, quantized=True)
    hidden = jnp.asarray(keys[:8], jnp.float32)
    p32 = np.asarray(jnp.argmax(knn_logits(hidden, ds32, cfg), -1))
    p8 = np.asarray(jnp.argmax(knn_logits(hidden, ds8, cfg), -1))
    assert (p32 == p8).mean() >= 0.75  # int8 keeps neighbor structure


def test_engine_serves_batched_requests(retrieval_cfg, rng):
    cfg = retrieval_cfg
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    keys, values = embedding_datastore(256, cfg.d_model, seed=3)
    ds = build_flat_datastore(keys, values % cfg.vocab_size)
    engine = ServeEngine(model, params, num_slots=2, max_len=32, datastore=ds)
    for rid in range(5):
        engine.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
            max_new_tokens=5))
    finished = engine.run()
    assert len(finished) == 5
    for r in finished:
        assert len(r.out_tokens) >= 5
        assert all(0 <= t < cfg.padded_vocab for t in r.out_tokens)
    # continuous batching actually reused slots (5 reqs > 2 slots)
    assert engine.steps >= 8


def test_engine_mixed_query_ingest_traffic(retrieval_cfg, rng):
    """One engine serves interleaved decode requests and datastore inserts:
    the IoT read+write pattern.  Ingested pairs must become retrievable by
    the very same engine (datastore is a traced argument, not a baked-in
    closure constant)."""
    cfg = retrieval_cfg
    model = Model(cfg)
    params = model.init(jax.random.key(2))
    keys, values = embedding_datastore(256, cfg.d_model, seed=4)
    ds = build_forest_datastore(keys, values % cfg.vocab_size, stream_capacity=64)
    engine = ServeEngine(model, params, num_slots=2, max_len=32, datastore=ds)

    new_keys = (-keys[:12] + 40.0).astype(np.float32)  # far from main keys
    new_vals = np.full(12, 9, np.int32)
    for rid in range(4):
        engine.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
            max_new_tokens=4))
        engine.submit(IngestRequest(
            rid=100 + rid, keys=new_keys[rid * 3:(rid + 1) * 3],
            values=new_vals[rid * 3:(rid + 1) * 3]))
    finished = engine.run()

    decodes = [r for r in finished if isinstance(r, Request)]
    ingests = [r for r in finished if isinstance(r, IngestRequest)]
    assert len(decodes) == 4 and len(ingests) == 4
    assert all(r.done for r in ingests)
    assert sum(r.accepted for r in ingests) == 12
    assert int(np.asarray(engine.datastore.delta.count).sum()) == 12
    for r in decodes:
        assert len(r.out_tokens) >= 4
        assert all(0 <= t < cfg.padded_vocab for t in r.out_tokens)
    # the streamed pairs are live in the SAME engine's retrieval path
    p = knn_logits(jnp.asarray(new_keys[:4]), engine.datastore, cfg)
    assert (np.asarray(jnp.argmax(p, -1)) == 9).all()


def test_engine_fails_single_ingest_not_the_run_loop(rng):
    """An IngestRequest against a non-streaming datastore fails with an
    error ack; in-flight decode requests still complete."""
    cfg = get_smoke_config("smollm-135m")
    model = Model(cfg)
    params = model.init(jax.random.key(3))
    engine = ServeEngine(model, params, num_slots=1, max_len=24)  # no datastore
    engine.submit(Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 4)
                          .astype(np.int32), max_new_tokens=3))
    engine.submit(IngestRequest(rid=1, keys=np.zeros((2, 4), np.float32),
                                values=np.zeros(2, np.int32)))
    finished = engine.run()
    ing = next(r for r in finished if isinstance(r, IngestRequest))
    dec = next(r for r in finished if isinstance(r, Request))
    assert ing.done and ing.accepted == 0 and ing.error
    assert len(dec.out_tokens) >= 3


def test_ingest_keys_never_outruns_values_tail(retrieval_cfg):
    """Regression: ids are issued from the datastore's own high-water mark
    and stop at the preallocated tail, so an accepted streamed key can never
    read a clipped/foreign token value."""
    from repro.serve.retrieval import ingest_keys

    cfg = retrieval_cfg
    keys, values = embedding_datastore(256, cfg.d_model, seed=6)
    ds = build_forest_datastore(keys, values % cfg.vocab_size, stream_capacity=8)
    g = np.random.default_rng(8)
    new_keys = (-keys[:16] + 40.0).astype(np.float32)
    new_vals = (np.arange(16) + 100).astype(np.int32)
    ds, acc1 = ingest_keys(ds, new_keys, new_vals)
    assert acc1 == 8  # tail exhausted exactly at stream_capacity
    ds, acc2 = ingest_keys(ds, new_keys[8:], new_vals[8:])
    assert acc2 == 0  # refused up front, nothing corrupted
    assert int(ds.next_id) == ds.n_main + 8
    # every accepted key retrieves ITS token, not a clipped neighbor's
    p = knn_logits(jnp.asarray(new_keys[:8]), ds, cfg)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(p, -1)), new_vals[:8])


def test_engine_greedy_matches_manual_decode(rng):
    """Engine output must equal a hand-rolled prefill+decode loop."""
    cfg = get_smoke_config("smollm-135m")
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)

    engine = ServeEngine(model, params, num_slots=1, max_len=24)
    engine.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
    got = engine.run()[0].out_tokens

    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                                  max_len=24)
    want = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(5):
        lg, cache = model.decode_step(
            params, jnp.asarray([[want[-1]]], jnp.int32), cache, jnp.int32(pos))
        want.append(int(jnp.argmax(lg[0])))
        pos += 1
    assert got == want
