"""Search correctness: the flattened masked-scan kNN (Alg. 2) vs brute force."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # optional dep: degrade to seeded sampling
    from repro.testing.hypothesis_fallback import given, settings
    from repro.testing.hypothesis_fallback import strategies as st

from repro.core import (
    IndexConfig,
    build_baseline,
    build_index,
    device_forest,
    knn_exact,
    knn_search,
    knn_search_host,
)


@pytest.fixture(scope="module")
def built(blob_data):
    cfg = IndexConfig(method="vbm", eps=1.5, min_pts=8, xi_min=0.3, xi_max=0.7)
    forest, report = build_index(blob_data, cfg)
    return blob_data, forest, report


def test_mode_all_is_exact(built, rng):
    x, forest, _ = built
    q = rng.normal(size=(32, x.shape[1])).astype(np.float32) * 8
    d, i, s = knn_search_host(forest, q, k=12, mode="all")
    de, ie = knn_exact(jnp.asarray(x), jnp.asarray(q), k=12)
    np.testing.assert_allclose(d, np.asarray(de), rtol=1e-4, atol=1e-4)
    # ids may differ on exact ties; distances must agree
    assert (s["buckets_visited"] > 0).all()
    assert (s["buckets_visited"] <= forest.n_buckets).all()


@pytest.mark.parametrize("beam", [1, 4])
def test_beam_equivalence(built, rng, beam):
    x, forest, _ = built
    q = rng.normal(size=(16, x.shape[1])).astype(np.float32) * 8
    d1, _, _ = knn_search_host(forest, q, k=10, mode="all", beam=1)
    db, _, _ = knn_search_host(forest, q, k=10, mode="all", beam=beam)
    np.testing.assert_allclose(d1, db, rtol=1e-5, atol=1e-5)


def test_forest_mode_exact_within_selected(built):
    """Alg. 2 routing: results must be exact kNN over the SELECTED indexes'
    members (the paper's semantics)."""
    x, forest, _ = built
    # own deterministic stream (order-independent of other tests)
    rng = np.random.default_rng(77)
    q = (x[rng.choice(len(x), 24, replace=False)] + 0.05 * rng.normal(size=(24, x.shape[1]))).astype(np.float32)
    d, ids, s = knn_search_host(forest, q, k=8, mode="forest")
    # reconstruct selection per query on host
    centers = forest.index_centers
    nbrs = forest.neighbors
    for qi in range(len(q)):
        # replicate the device's routing arithmetic exactly (f32 expansion
        # ||q||^2+||c||^2-2qc), else near-ties route to different-but-valid
        # indexes and the comparison is vacuous
        qf = q[qi].astype(np.float32)
        dc = ((qf * qf).sum() + (centers * centers).sum(-1)
              - 2.0 * centers @ qf).astype(np.float32)
        c = np.argmin(dc)
        # residual reassociation ties: skip queries with near-equal routes
        if len(dc) > 1 and np.partition(dc, 1)[1] - dc[c] < 1e-2 * (abs(dc[c]) + 1):
            continue
        sel = {int(c)} | {int(n) for n in nbrs[c] if n >= 0}
        # members of selected indexes
        member_mask = np.isin(forest.bucket_index, list(sel))
        mem_ids = forest.bucket_ids[member_mask][forest.bucket_mask[member_mask]]
        if len(mem_ids) < 8:
            # under-filled selection: the scan spills to the next-nearest
            # buckets by design (paper §4.3: "when the required number of
            # objects has not yet been reached") — results come from a
            # SUPERSET of the selection, so they can only be closer
            sub = x[mem_ids]
            d_true = np.sort(np.sqrt(((sub - q[qi]) ** 2).sum(-1)))
            assert np.all(d[qi][: len(mem_ids)] <= d_true + 2e-3)
            assert np.all(np.isfinite(d[qi]))  # spill filled up to k
            continue
        sub = x[mem_ids]
        d_true = np.sort(np.sqrt(((sub - q[qi]) ** 2).sum(-1)))[:8]
        # device path uses the ||q||^2+||x||^2-2qx expansion (f32): ~1e-3 abs
        np.testing.assert_allclose(d[qi], d_true, rtol=2e-3, atol=2e-3)


def test_forest_recall_in_distribution(built, rng):
    x, forest, _ = built
    qi = rng.choice(len(x), 64, replace=False)
    q = (x[qi] + 0.05 * rng.normal(size=(64, x.shape[1]))).astype(np.float32)
    de, ie = knn_exact(jnp.asarray(x), jnp.asarray(q), k=10)
    d, ids, _ = knn_search_host(forest, q, k=10, mode="forest")
    ie = np.asarray(ie)
    recall = np.mean([len(set(ids[j].tolist()) & set(ie[j].tolist())) / 10 for j in range(64)])
    assert recall >= 0.6, recall


def test_pruning_beats_baseline(built, blob_data, rng):
    """The paper's headline claim: fewer distance computations than BCCF."""
    x, forest, _ = built
    bforest, _ = build_baseline(x)
    qi = rng.choice(len(x), 32, replace=False)
    q = x[qi].astype(np.float32)
    _, _, s_f = knn_search_host(forest, q, k=10, mode="forest")
    _, _, s_b = knn_search_host(bforest, q, k=10, mode="all")
    assert s_f["distances"].mean() < s_b["distances"].mean()


def test_fewer_than_k_objects():
    x = np.random.default_rng(0).normal(size=(7, 4)).astype(np.float32)
    forest, _ = build_baseline(x, IndexConfig(c_max=4))
    d, ids, _ = knn_search_host(forest, x[:2], k=20, mode="all")
    assert d.shape[1] == 7  # |X| < k -> returns |X| answers (Def. 4)
    assert (ids >= 0).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(1, 16))
def test_property_exactness_random(seed, k):
    """Property: for random data/queries, mode='all' == brute force."""
    g = np.random.default_rng(seed)
    x = g.normal(size=(150, 5)).astype(np.float32)
    q = g.normal(size=(4, 5)).astype(np.float32)
    forest, _ = build_baseline(x, IndexConfig(c_max=16))
    d, _, _ = knn_search_host(forest, q, k=k, mode="all")
    de, _ = knn_exact(jnp.asarray(x), jnp.asarray(q), k=k)
    np.testing.assert_allclose(d, np.asarray(de), rtol=1e-4, atol=1e-4)


def test_stats_counters_monotone(built, rng):
    """More neighbors requested -> at least as much work."""
    x, forest, _ = built
    q = x[rng.choice(len(x), 16, replace=False)].astype(np.float32)
    _, _, s5 = knn_search_host(forest, q, k=5, mode="forest")
    _, _, s50 = knn_search_host(forest, q, k=50, mode="forest")
    assert s50["buckets_visited"].sum() >= s5["buckets_visited"].sum()
    assert s50["distances"].sum() >= s5["distances"].sum()
