"""MoE execution paths: dense / token-sharded psum / weight-stationary /
all-to-all — all must agree bit-for-bit (same routing, no drops at generous
capacity), and the paper's forest datastore must plug into retrieval."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig, RetrievalConfig
from repro.distributed import context as dctx
from repro.models import moe as moe_lib


def _cfg(n_exp=8, shared=1, a2a=False, cf=8.0):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=64, moe_a2a=a2a,
        moe=MoEConfig(num_experts=n_exp, top_k=2, d_ff_expert=16,
                      capacity_factor=cf, num_shared=shared),
    )


@pytest.fixture(scope="module")
def host_mesh():
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def test_a2a_matches_dense(host_mesh, rng):
    """All-to-all dispatch == dense oracle (tokens above the
    weight-stationary threshold so the a2a path is active)."""
    cfg = _cfg(a2a=True, cf=4.0)
    p = moe_lib.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 2048, 16)), jnp.float32)
    ref, _ = moe_lib.moe_ffn(p, x, cfg.replace(moe_a2a=False))
    with dctx.use_mesh(host_mesh):
        got, _ = moe_lib.moe_ffn(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4)


def test_a2a_gradients_finite(host_mesh, rng):
    cfg = _cfg(a2a=True, cf=4.0)
    p = moe_lib.init_moe(jax.random.key(1), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 4096, 16)), jnp.float32)

    def loss(p_):
        with dctx.use_mesh(host_mesh):
            y, _ = moe_lib.moe_ffn(p_, x, cfg)
        return jnp.sum(y * y)

    g = jax.grad(loss)(p)
    flat = jax.tree.leaves(g)
    assert all(bool(jnp.all(jnp.isfinite(v))) for v in flat)
    assert sum(float(jnp.sum(jnp.abs(v))) for v in flat) > 0


def test_weight_stationary_matches_dense(host_mesh, rng):
    """Small token counts route through the weight-stationary island."""
    cfg = _cfg(cf=8.0)
    p = moe_lib.init_moe(jax.random.key(2), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 4, 16)), jnp.float32)
    ref, _ = moe_lib.moe_ffn(p, x, cfg)
    with dctx.use_mesh(host_mesh):
        got, _ = moe_lib.moe_ffn(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4)


def test_forest_datastore_retrieval(rng):
    """The paper's forest as the kNN-LM datastore: p_knn concentrates on the
    stored token for on-key queries, via Alg. 2 routing."""
    from repro.data.synthetic import embedding_datastore
    from repro.serve.retrieval import build_forest_datastore, knn_logits

    cfg = _cfg().replace(retrieval=RetrievalConfig(enabled=True, k=4, temperature=1.0))
    keys, values = embedding_datastore(2048, 32, n_clusters=8, seed=5)
    values = values % cfg.padded_vocab
    ds = build_forest_datastore(keys, values, method="vbm")
    hidden = jnp.asarray(keys[:8], jnp.float32)
    p = knn_logits(hidden, ds, cfg)
    assert p.shape == (8, cfg.padded_vocab)
    np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, rtol=1e-4)
    top = np.asarray(jnp.argmax(p, axis=-1))
    assert (top == np.asarray(values[:8])).mean() >= 0.5
