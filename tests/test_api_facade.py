"""OverlapIndex facade tests: config-tree validation, overlap-method
registry, plan-cache re-trace behavior, save/load bitwise round-trip, the
baseline pivot-method contract, and the shim-deprecation gate (shim usage
inside src/repro itself fails the build)."""
import re
import warnings
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    Config,
    ConfigError,
    IndexConfig,
    LayoutConfig,
    OverlapIndex,
    RepoDeprecationWarning,
    SearchConfig,
    StreamConfig,
    available_overlap_methods,
    make_backend,
    register_overlap_method,
    unregister_overlap_method,
)
from repro.core import knn_exact
from repro.core.overlap import overlap_matrix
from repro.core.pipeline import build_baseline_core

CFG = Config(
    index=IndexConfig(method="vbm", eps=1.5, min_pts=8, xi_min=0.3, xi_max=0.7),
    stream=StreamConfig(capacity=128),
)


@pytest.fixture(scope="module")
def built(blob_data):
    return OverlapIndex.build(blob_data, CFG)


def _stream_points(x, n, seed):
    g = np.random.default_rng(seed)
    base = x[g.choice(len(x), n)]
    return (base + 0.3 * g.normal(size=base.shape)).astype(np.float32)


# ---------------------------------------------------------------------------
# config tree validation
# ---------------------------------------------------------------------------

BAD_CONFIGS = [
    (lambda: IndexConfig(method="vbmm"), "registered overlap method"),
    (lambda: IndexConfig(xi_min=0.8, xi_max=0.4), "xi_min < xi_max"),
    (lambda: IndexConfig(xi_min=-0.1), "xi_min < xi_max"),
    (lambda: IndexConfig(xi_max=1.5), "xi_min < xi_max"),
    (lambda: IndexConfig(eps=0.0), "eps"),
    (lambda: IndexConfig(min_pts=0), "min_pts"),
    (lambda: IndexConfig(c_max=1), "c_max"),
    (lambda: IndexConfig(pivot_method="median"), "pivot_method"),
    (lambda: IndexConfig(dbscan_block=0), "dbscan_block"),
    (lambda: SearchConfig(k=0), "k="),
    (lambda: SearchConfig(mode="fast"), "mode"),
    (lambda: SearchConfig(beam=0), "beam"),
    (lambda: StreamConfig(capacity=0), "capacity"),
    (lambda: StreamConfig(monitor_method="learned"), "registered overlap method"),
    (lambda: StreamConfig(xi_rebuild=0.0), "xi_rebuild"),
    (lambda: StreamConfig(xi_rebuild=1.5), "xi_rebuild"),
    (lambda: StreamConfig(drift_margin=-0.1), "drift_margin"),
    (lambda: StreamConfig(fill_rebuild=0.0), "fill_rebuild"),
    (lambda: StreamConfig(pivot_method="median"), "pivot_method"),
    (lambda: StreamConfig(c_max=1), "c_max"),
    (lambda: LayoutConfig(kind="mirrored"), "LayoutConfig.kind"),
    (lambda: LayoutConfig(kind="sharded", shards=0), "shards"),
    (lambda: LayoutConfig(shards=2), "kind='sharded'"),
    (lambda: LayoutConfig(axis=""), "axis"),
]


@pytest.mark.parametrize("bad, fragment", BAD_CONFIGS,
                         ids=[f[1] + str(i) for i, f in enumerate(BAD_CONFIGS)])
def test_config_validation_is_actionable(bad, fragment):
    with pytest.raises(ConfigError) as exc:
        bad()
    assert fragment in str(exc.value)


def test_config_nodes_are_type_checked():
    with pytest.raises(ConfigError, match="Config.index"):
        Config(index=SearchConfig())


def test_config_valid_tree_constructs():
    cfg = Config(
        index=IndexConfig(method="obm", c_max=None),
        search=SearchConfig(k=3, mode="all", beam=4),
        stream=StreamConfig(capacity=None, drift_margin=0.1),
    )
    assert cfg.with_(eps=2.0).index.eps == 2.0


# ---------------------------------------------------------------------------
# overlap-method registry
# ---------------------------------------------------------------------------

def test_registry_lists_paper_methods():
    assert set(available_overlap_methods()) >= {"vbm", "dbm", "obm"}


def test_registered_method_flows_through_whole_pipeline(blob_data):
    """A custom heuristic becomes buildable + monitorable by NAME — no
    dispatch site anywhere needs touching."""

    def hybrid(pivots, radii, *, x=None, assign=None):
        v = overlap_matrix("vbm", pivots, radii)
        d = overlap_matrix("dbm", pivots, radii)
        return 0.5 * (v + d)

    register_overlap_method("hybrid-vd", hybrid)
    try:
        cfg = Config(
            index=IndexConfig(method="hybrid-vd", eps=1.5, min_pts=8),
            stream=StreamConfig(monitor_method="hybrid-vd", capacity=64),
        )
        ix = OverlapIndex.build(blob_data, cfg)
        assert ix.forest.n_indexes >= 1
        ix.ingest(_stream_points(blob_data, 16, seed=0))
        rep = ix.check()  # the monitor resolves the same registry entry
        assert np.isfinite(rep.rates).all()
    finally:
        unregister_overlap_method("hybrid-vd")
    with pytest.raises(ConfigError, match="hybrid-vd"):
        IndexConfig(method="hybrid-vd")


def test_registry_duplicate_and_unknown():
    with pytest.raises(ValueError, match="already registered"):
        register_overlap_method("vbm", lambda *a, **k: None)
    with pytest.raises(ValueError, match="registered methods"):
        overlap_matrix("nope", jnp.zeros((2, 3)), jnp.ones((2,)))


# ---------------------------------------------------------------------------
# plan cache: no re-trace on stable shapes
# ---------------------------------------------------------------------------

def test_search_plan_cache_never_retraces_stable_shapes(built, rng):
    ix = built
    q = rng.normal(size=(16, 8)).astype(np.float32) * 8
    r1 = ix.search(q, k=9)
    plan = r1.plan
    assert plan.traces == 1 and len(ix.plans) >= 1
    for _ in range(3):
        r = ix.search(q, k=9)
    assert r.plan is plan
    assert plan.traces == 1, "same options + same shapes must not re-trace"
    assert plan.calls >= 4
    assert ix.plans.hits >= 3

    # a different option tuple is a DIFFERENT plan, original stays warm
    r2 = ix.search(q, k=5, mode="all")
    assert r2.plan is not plan and r2.plan.traces == 1
    assert plan.traces == 1

    # a new batch shape re-specializes within the plan (counted, cached)
    ix.search(q[:7], k=9)
    assert plan.traces == 2
    ix.search(q[:7], k=9)
    assert plan.traces == 2


def test_plan_cache_lru_evicts_and_counts():
    """The cache is bounded: exceeding max_plans drops the least-recently-
    USED plan (a later re-request simply recompiles as a fresh miss)."""
    from repro.api.plan import PlanCache, PlanKey

    def key(k):
        return PlanKey(k=k, mode="forest", beam=1, kernel=True,
                       quantize=False, delta_capacity=None)

    cache = PlanCache(max_plans=2)
    cache.plan(key(1))
    cache.plan(key(2))
    cache.plan(key(1))  # refresh recency: key(2) is now the LRU entry
    cache.plan(key(3))  # over the cap -> evicts key(2)
    assert key(2) not in cache and key(1) in cache and key(3) in cache
    st = cache.stats()
    assert (st["plans"], st["max_plans"]) == (2, 2)
    assert (st["hits"], st["misses"], st["evictions"]) == (1, 3, 1)
    cache.plan(key(2))  # re-request: a plain recompile, not an error
    assert cache.stats()["misses"] == 4 and cache.stats()["evictions"] == 2
    assert len(cache) == 2
    with pytest.raises(ValueError, match="max_plans"):
        PlanCache(max_plans=0)


def test_ingest_executor_never_retraces_ragged_batches(blob_data):
    """Steady-state streaming compiles ONE ingest program: ragged tail
    chunks pad up to a power-of-two shape (rows parked invalid), so only a
    genuinely new padded shape re-traces."""
    ix = OverlapIndex.build(blob_data, CFG)  # capacity=128
    ix.ingest(_stream_points(blob_data, 64, seed=0))
    assert ix.ingest_stats()["traces"] == 1
    ix.ingest(_stream_points(blob_data, 64, seed=1))
    ix.ingest(_stream_points(blob_data, 40, seed=2))  # pads up to 64
    st = ix.ingest_stats()
    assert st["traces"] == 1, f"steady-state ingest re-traced: {st}"
    assert st["calls"] >= 3
    ix.ingest(_stream_points(blob_data, 17, seed=3))  # pads to 32: new shape
    assert ix.ingest_stats()["traces"] == 2


def test_make_backend_strict_raises_clamp_downgrades():
    """An explicit build with more shards than devices fails with the XLA
    override hint; the load path clamps (with a warning) so a snapshot from
    a bigger host still opens here."""
    import jax

    too_many = jax.device_count() + 1
    layout = LayoutConfig(kind="sharded", shards=too_many)
    with pytest.raises(ConfigError, match="xla_force_host_platform_device_count"):
        make_backend(layout)
    with pytest.warns(UserWarning, match="re-sharding"):
        backend = make_backend(layout, clamp=True)
    assert backend.shards == jax.device_count()


def test_search_overrides_are_validated(built, rng):
    """Per-call k/mode/beam get the same actionable errors as the config
    tree — and a bad combination never poisons the plan cache."""
    q = rng.normal(size=(4, 8)).astype(np.float32)
    n_plans = len(built.plans)
    with pytest.raises(ConfigError, match="k=0"):
        built.search(q, k=0)
    with pytest.raises(ConfigError, match="beam=0"):
        built.search(q, k=3, beam=0)
    with pytest.raises(ConfigError, match="mode"):
        built.search(q, k=3, mode="fast")
    assert len(built.plans) == n_plans


def test_search_result_matches_legacy_tuple(built, rng):
    """SearchResult (facade) must agree with the legacy shim output."""
    from repro.core import knn_search_host

    ix = built
    q = rng.normal(size=(8, 8)).astype(np.float32) * 8
    res = ix.search(q, k=7, mode="all")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RepoDeprecationWarning)
        d, i, s = knn_search_host(ix.forest, q, k=7, mode="all")
    np.testing.assert_array_equal(res.dists, d)
    np.testing.assert_array_equal(res.ids, i)
    assert res.stats["steps"] == s["steps"]
    d2, i2, s2 = res  # tuple-unpacking compatibility
    assert d2 is res.dists and i2 is res.ids


# ---------------------------------------------------------------------------
# persistence: build -> ingest -> save -> load is bitwise-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quantize", [False, True])
def test_save_load_roundtrip_bitwise(blob_data, rng, tmp_path, quantize):
    cfg = Config(
        index=IndexConfig(method="vbm", eps=1.5, min_pts=8,
                          xi_min=0.3, xi_max=0.7),
        search=SearchConfig(quantize=quantize),
        stream=StreamConfig(capacity=128),
    )
    ix = OverlapIndex.build(blob_data, cfg)
    ix.ingest(_stream_points(blob_data, 200, seed=3))  # live delta buffers
    q = rng.normal(size=(24, 8)).astype(np.float32) * 8

    path = ix.save(tmp_path / f"index_q{int(quantize)}")
    ix2 = OverlapIndex.load(path)

    assert ix2.cfg == ix.cfg
    assert ix2.n_total == ix.n_total
    np.testing.assert_array_equal(
        np.asarray(ix2.delta.ids), np.asarray(ix.delta.ids)
    )
    # the drift monitor's baseline is the SAVED one, not a recompute over
    # the restart-time dataset (object-based triggers must not shift)
    np.testing.assert_array_equal(
        ix2.monitor.rates_baseline, ix.monitor.rates_baseline
    )
    np.testing.assert_array_equal(
        np.asarray(ix2.device.bucket_x), np.asarray(ix.device.bucket_x)
    )
    for k, mode in ((12, "all"), (5, "forest")):
        a = ix.search(q, k=k, mode=mode)
        b = ix2.search(q, k=k, mode=mode)
        np.testing.assert_array_equal(a.dists, b.dists)
        np.testing.assert_array_equal(a.ids, b.ids)
        for field in ("buckets_visited", "distances", "comparisons"):
            np.testing.assert_array_equal(a.stats[field], b.stats[field])

    # the loaded index is fully alive: ingest + maintain + structure work
    ix2.ingest(_stream_points(blob_data, 32, seed=4))
    ix2.maintain()
    s = ix2.structure()
    assert s["n_objects"] == ix2.n_total == len(blob_data) + 232
    # and exactness holds over everything ever ingested (int8 bucket
    # storage is deliberately approximate: ~0.5% distance error)
    tol = 1e-2 if quantize else 1e-4
    d = ix2.search(q, k=10, mode="all").dists
    de, _ = knn_exact(jnp.asarray(ix2.x_all), jnp.asarray(q), k=10)
    np.testing.assert_allclose(d, np.asarray(de), rtol=tol, atol=tol)


def test_load_refuses_newer_format(built, tmp_path):
    from repro.api import persist

    path = built.save(tmp_path / "v.npz")
    with np.load(path, allow_pickle=False) as z:
        payload = dict(z)
    payload["format_version"] = np.int64(persist.FORMAT_VERSION + 1)
    np.savez(path, **payload)
    with pytest.raises(ValueError, match="newer format"):
        OverlapIndex.load(path)


# ---------------------------------------------------------------------------
# baseline pivot-method contract (was: silently hardcoded 'kmeans')
# ---------------------------------------------------------------------------

def test_baseline_honors_pivot_method_and_warns():
    x = np.random.default_rng(0).normal(size=(300, 5)).astype(np.float32)
    # no config -> the documented 2-means baseline, no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error", UserWarning)
        f_km, rep_km = build_baseline_core(x, None)
    assert rep_km.config.pivot_method == "kmeans"
    # explicit non-kmeans config is honored (cheaper GH build) + warned
    with pytest.warns(UserWarning, match="BCCF baseline"):
        f_gh, rep_gh = build_baseline_core(x, IndexConfig(pivot_method="gh"))
    assert rep_gh.config.pivot_method == "gh"
    assert rep_gh.tree_distances < rep_km.tree_distances, (
        "gh pivots must actually be used (2-means costs strictly more)"
    )
    # explicit kmeans: honored, silent
    with warnings.catch_warnings():
        warnings.simplefilter("error", UserWarning)
        build_baseline_core(x, IndexConfig(pivot_method="kmeans"))


# ---------------------------------------------------------------------------
# deprecation gate: shims warn; src/repro itself must never hit them
# ---------------------------------------------------------------------------

def test_shims_emit_repo_deprecation_warning(blob_data):
    from repro.core import build_baseline, build_index, knn_search
    from repro.core.knn import device_forest
    from repro.stream import StreamingForest

    x = blob_data[:400]
    with pytest.warns(RepoDeprecationWarning, match="build_index"):
        forest, _ = build_index(
            x, IndexConfig(method="vbm", eps=1.5, min_pts=8))
    with pytest.warns(RepoDeprecationWarning, match="knn_search"):
        knn_search(device_forest(forest), jnp.asarray(x[:4]), k=3)
    with pytest.warns(RepoDeprecationWarning, match="build_baseline"):
        build_baseline(x)
    with pytest.warns(RepoDeprecationWarning, match="StreamingForest"):
        StreamingForest(x, IndexConfig(method="vbm", eps=1.5, min_pts=8))


def test_facade_lifecycle_emits_no_deprecation(blob_data, tmp_path):
    """The whole facade surface — build, search, ingest, maintain, save,
    load, to_datastore — must run clean of RepoDeprecationWarning: internal
    code going through a shim fails here (and thereby fails CI)."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", RepoDeprecationWarning)
        ix = OverlapIndex.build(blob_data, CFG)
        ix.search(blob_data[:4], k=3)
        ix.ingest(_stream_points(blob_data, 150, seed=5))
        ix.search(blob_data[:4], k=3, mode="all")
        ix.maintain()
        path = ix.save(tmp_path / "clean")
        ix2 = OverlapIndex.load(path)
        ix2.search(blob_data[:4], k=3)
        ds = ix2.to_datastore(
            np.arange(ix2.n_total, dtype=np.int32) % 50, stream_capacity=16
        )
        # serve-side read+write paths too
        from repro.serve.retrieval import forest_knn, ingest_keys

        d2, vals = forest_knn(jnp.asarray(blob_data[:4]), ds, 3)
        assert vals.shape == (4, 3)
        ds, acc = ingest_keys(
            ds, jnp.asarray(_stream_points(blob_data, 4, seed=6)),
            jnp.arange(4, dtype=jnp.int32),
        )
        assert acc > 0
        baseline = OverlapIndex.baseline(blob_data[:300])
        baseline.search(blob_data[:4], k=3, mode="all")


def test_no_shim_usage_inside_src_repro():
    """Static gate: the deprecated surfaces may be CALLED only by their own
    defining modules; everything else under src/repro goes through the
    facade or the *_core/_impl entry points."""
    src = Path(__file__).resolve().parent.parent / "src" / "repro"
    allowed = {"core/pipeline.py", "core/knn.py", "stream/maintenance.py"}
    pat = re.compile(
        r"\b(build_index|build_baseline|knn_search_host|knn_search|"
        r"StreamingForest)\s*\("
    )
    offenders = []
    for path in sorted(src.rglob("*.py")):
        rel = path.relative_to(src).as_posix()
        if rel in allowed:
            continue
        for ln, line in enumerate(path.read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(f"{rel}:{ln}: {line.strip()}")
    assert not offenders, (
        "deprecated shim usage inside src/repro (use repro.api.OverlapIndex "
        "or the *_core/_impl functions):\n" + "\n".join(offenders)
    )


# ---------------------------------------------------------------------------
# to_datastore contract
# ---------------------------------------------------------------------------

def test_to_datastore_checks_value_count(built):
    with pytest.raises(ValueError, match="one value per indexed object"):
        built.to_datastore(np.zeros(3, np.int32))


def test_to_datastore_carries_live_delta(blob_data):
    ix = OverlapIndex.build(blob_data, CFG)
    xs = _stream_points(blob_data, 8, seed=7)
    ids = ix.ingest(xs)
    vals = (np.arange(ix.n_total) % 97).astype(np.int32)
    ds = ix.to_datastore(vals)
    from repro.serve.retrieval import forest_knn

    _, got = forest_knn(jnp.asarray(xs), ds, 1)
    np.testing.assert_array_equal(np.asarray(got)[:, 0], vals[ids])
