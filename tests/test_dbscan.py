"""DBSCAN correctness: the parallel label-propagation formulation must match
a classic sequential reference on core-point clustering."""
import numpy as np
import pytest

from repro.core import dbscan, partitions_from_labels


def _reference_dbscan(x: np.ndarray, eps: float, min_pts: int):
    """Textbook DBSCAN (Ester et al. 1996), O(n^2), for oracle use."""
    n = len(x)
    d = np.sqrt(((x[:, None, :] - x[None, :, :]) ** 2).sum(-1))
    neigh = [np.where(d[i] <= eps)[0] for i in range(n)]
    core = np.array([len(nb) >= min_pts for nb in neigh])
    labels = np.full(n, -1)
    cid = 0
    for i in range(n):
        if labels[i] != -1 or not core[i]:
            continue
        stack = [i]
        labels[i] = cid
        while stack:
            j = stack.pop()
            if not core[j]:
                continue
            for nb in neigh[j]:
                if labels[nb] == -1:
                    labels[nb] = cid
                    stack.append(nb)
        cid += 1
    return labels, core, cid


def _same_partition(a: np.ndarray, b: np.ndarray) -> bool:
    """Labelings equal up to renaming."""
    pa = {}
    for x_, y_ in zip(a.tolist(), b.tolist()):
        if x_ in pa and pa[x_] != y_:
            return False
        pa[x_] = y_
    return len(set(pa.values())) == len(pa)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("block", [64, 1000])
def test_dbscan_matches_reference(seed, block):
    g = np.random.default_rng(seed)
    centers = g.normal(size=(4, 4)) * 8
    x = np.concatenate(
        [c + g.normal(size=(120, 4)) for c in centers] + [g.uniform(-12, 12, (40, 4))]
    ).astype(np.float32)
    eps, min_pts = 1.2, 6
    ref_labels, ref_core, ref_k = _reference_dbscan(x, eps, min_pts)
    res = dbscan(x, eps, min_pts, block=block)
    assert (res.core_mask == ref_core).all()
    assert res.n_clusters == ref_k
    # Core-point clustering is unique: must match exactly up to renaming.
    c = ref_core
    assert _same_partition(res.labels[c], ref_labels[c])
    # Border points: our tie-break is nearest-core; both must agree on
    # noise-vs-clustered status.
    assert ((res.labels == -1) == (ref_labels == -1)).all()


def test_partitions_cover_everything(blob_data):
    x = blob_data[:800]
    res = dbscan(x, 1.5, 8)
    pivots, radii, assign = partitions_from_labels(x, res.labels, res.n_clusters)
    n_clusters = max(res.n_clusters, 1)
    assert pivots.shape == (n_clusters, x.shape[1])
    assert (assign >= 0).all() and (assign < n_clusters).all()
    # radius covers every assigned object
    d = np.sqrt(((x - pivots[assign]) ** 2).sum(-1))
    assert (d <= radii[assign] + 1e-4).all()


def test_dbscan_all_noise():
    g = np.random.default_rng(3)
    x = g.uniform(-100, 100, size=(50, 6)).astype(np.float32)
    res = dbscan(x, 0.01, 5)
    assert res.n_clusters == 0
    assert (res.labels == -1).all()
    pivots, radii, assign = partitions_from_labels(x, res.labels, res.n_clusters)
    assert pivots.shape[0] == 1  # degenerate single partition
    assert (assign == 0).all()


def test_dbscan_single_cluster():
    g = np.random.default_rng(4)
    x = g.normal(size=(200, 3)).astype(np.float32)
    res = dbscan(x, 3.0, 4)
    assert res.n_clusters == 1
    assert (res.labels == 0).all()
