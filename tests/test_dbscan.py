"""DBSCAN correctness: the parallel label-propagation formulation must match
a classic sequential reference on core-point clustering, and the kernelized
eps-graph path (``kernel=True``, fused reductions in kernels/pairwise_l2.py)
must match the in-place jnp formulation kept here as its oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dbscan, partitions_from_labels
from repro.kernels import ref
from repro.kernels.pairwise_l2 import (
    eps_count_pallas,
    eps_min_label_pallas,
    eps_nearest_core_pallas,
)


def _reference_dbscan(x: np.ndarray, eps: float, min_pts: int):
    """Textbook DBSCAN (Ester et al. 1996), O(n^2), for oracle use."""
    n = len(x)
    d = np.sqrt(((x[:, None, :] - x[None, :, :]) ** 2).sum(-1))
    neigh = [np.where(d[i] <= eps)[0] for i in range(n)]
    core = np.array([len(nb) >= min_pts for nb in neigh])
    labels = np.full(n, -1)
    cid = 0
    for i in range(n):
        if labels[i] != -1 or not core[i]:
            continue
        stack = [i]
        labels[i] = cid
        while stack:
            j = stack.pop()
            if not core[j]:
                continue
            for nb in neigh[j]:
                if labels[nb] == -1:
                    labels[nb] = cid
                    stack.append(nb)
        cid += 1
    return labels, core, cid


def _same_partition(a: np.ndarray, b: np.ndarray) -> bool:
    """Labelings equal up to renaming."""
    pa = {}
    for x_, y_ in zip(a.tolist(), b.tolist()):
        if x_ in pa and pa[x_] != y_:
            return False
        pa[x_] = y_
    return len(set(pa.values())) == len(pa)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("block", [64, 1000])
def test_dbscan_matches_reference(seed, block):
    g = np.random.default_rng(seed)
    centers = g.normal(size=(4, 4)) * 8
    x = np.concatenate(
        [c + g.normal(size=(120, 4)) for c in centers] + [g.uniform(-12, 12, (40, 4))]
    ).astype(np.float32)
    eps, min_pts = 1.2, 6
    ref_labels, ref_core, ref_k = _reference_dbscan(x, eps, min_pts)
    res = dbscan(x, eps, min_pts, block=block)
    assert (res.core_mask == ref_core).all()
    assert res.n_clusters == ref_k
    # Core-point clustering is unique: must match exactly up to renaming.
    c = ref_core
    assert _same_partition(res.labels[c], ref_labels[c])
    # Border points: our tie-break is nearest-core; both must agree on
    # noise-vs-clustered status.
    assert ((res.labels == -1) == (ref_labels == -1)).all()


def test_partitions_cover_everything(blob_data):
    x = blob_data[:800]
    res = dbscan(x, 1.5, 8)
    pivots, radii, assign = partitions_from_labels(x, res.labels, res.n_clusters)
    n_clusters = max(res.n_clusters, 1)
    assert pivots.shape == (n_clusters, x.shape[1])
    assert (assign >= 0).all() and (assign < n_clusters).all()
    # radius covers every assigned object
    d = np.sqrt(((x - pivots[assign]) ** 2).sum(-1))
    assert (d <= radii[assign] + 1e-4).all()


def test_dbscan_all_noise():
    g = np.random.default_rng(3)
    x = g.uniform(-100, 100, size=(50, 6)).astype(np.float32)
    res = dbscan(x, 0.01, 5)
    assert res.n_clusters == 0
    assert (res.labels == -1).all()
    pivots, radii, assign = partitions_from_labels(x, res.labels, res.n_clusters)
    assert pivots.shape[0] == 1  # degenerate single partition
    assert (assign == 0).all()


def test_dbscan_single_cluster():
    g = np.random.default_rng(4)
    x = g.normal(size=(200, 3)).astype(np.float32)
    res = dbscan(x, 3.0, 4)
    assert res.n_clusters == 1
    assert (res.labels == 0).all()


# --- kernelized eps-graph path vs the jnp oracle ---------------------------


def test_dbscan_kernel_path_matches_jnp(monkeypatch):
    """``kernel=True`` (the default, dispatched through kernels/ops — here
    forced onto the Pallas interpret path) must reproduce the in-place jnp
    formulation (``kernel=False``) exactly: same core mask, same clustering.
    """
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    g = np.random.default_rng(7)
    centers = g.normal(size=(3, 4)) * 10
    x = np.concatenate(
        [c + g.normal(size=(70, 4)) for c in centers] + [g.uniform(-15, 15, (30, 4))]
    ).astype(np.float32)
    res_k = dbscan(x, 1.3, 5, block=64)
    monkeypatch.delenv("REPRO_FORCE_PALLAS")
    res_j = dbscan(x, 1.3, 5, block=64, kernel=False)
    assert (res_k.core_mask == res_j.core_mask).all()
    assert res_k.n_clusters == res_j.n_clusters
    assert (res_k.labels == res_j.labels).all()


@pytest.mark.parametrize("qn,n", [(37, 117), (64, 64), (5, 200)])
def test_eps_kernels_match_ref(qn, n):
    """Each fused eps-graph kernel (interpret mode, ragged shapes exercising
    the pad/mask logic) against its pure-jnp oracle in kernels/ref.py."""
    g = np.random.default_rng(qn * 1000 + n)
    x = jnp.asarray(g.normal(size=(n, 6)).astype(np.float32) * 2)
    q = jnp.asarray(np.asarray(x[:qn]))
    labels = jnp.asarray(g.integers(0, n, size=n).astype(np.int32))
    core = jnp.asarray((g.random(n) < 0.6))
    # threshold near the median distance: both <= branches well-populated
    # (nudged off the exact data value so ulp-level reduction-order noise
    # between the tiled kernel and the one-shot reference cannot flip a <=)
    d_all = np.asarray(ref.pairwise_sq_l2_ref(q, x))
    eps_sq = jnp.float32(np.median(d_all) * 1.0009)
    kw = dict(bq=32, bn=32, interpret=True)

    cnt = eps_count_pallas(q, x, eps_sq, **kw)
    assert (np.asarray(cnt) == np.asarray(ref.eps_count_ref(q, x, eps_sq))).all()

    lab = eps_min_label_pallas(q, x, labels, core, eps_sq, **kw)
    ref_lab = ref.eps_min_label_ref(q, x, labels, core, eps_sq)
    assert (np.asarray(lab) == np.asarray(ref_lab)).all()

    dmin, nlab = eps_nearest_core_pallas(q, x, labels, core, **kw)
    rd, rl = ref.eps_nearest_core_ref(q, x, labels, core)
    np.testing.assert_allclose(np.asarray(dmin), np.asarray(rd), rtol=1e-6)
    assert (np.asarray(nlab) == np.asarray(rl)).all()


def test_eps_kernels_no_core_points():
    """Degenerate fleet: zero core points -> sentinel labels, +inf nearest
    distance — the all-noise DBSCAN branch."""
    g = np.random.default_rng(11)
    x = jnp.asarray(g.normal(size=(40, 3)).astype(np.float32))
    labels = jnp.arange(40, dtype=jnp.int32)
    core = jnp.zeros(40, bool)
    kw = dict(bq=32, bn=32, interpret=True)
    lab = eps_min_label_pallas(x, x, labels, core, jnp.float32(1.0), **kw)
    assert (np.asarray(lab) == 40).all()
    dmin, nlab = eps_nearest_core_pallas(x, x, labels, core, **kw)
    assert np.isinf(np.asarray(dmin)).all()
    assert (np.asarray(nlab) == 40).all()
